"""AST analysis implementing the jaxguard rules (JG001–JG007).

One :class:`Analyzer` per file, two phases:

* a module scan that resolves import aliases (``jnp`` → ``jax.numpy``),
  registers module-level jitted bindings (``_prog = jax.jit(fn, ...)``),
  their ``donate_argnums``, and the set of functions whose bodies are
  traced (jit-decorated, jit-wrapped, or passed to ``lax.scan``/``vmap``
  and friends, plus everything lexically nested inside them);
* a rule walk that flags violations, with a per-function linear dataflow
  pass for the order-sensitive rules (JG001 key reuse, JG006 donated
  reads).

The dataflow is deliberately line-ordered and intra-procedural: it does
not follow aliases, attributes, or control-flow joins.  That keeps false
positives rare enough that ``python -m tools.jaxguard src/`` can be a
blocking CI job; the escape hatch for deliberate patterns is a
``# jaxguard: disable=RULE`` comment (suppress.py).  Nested function
bodies are analyzed as their own scopes, not inlined into the enclosing
function's dataflow.
"""
from __future__ import annotations

import ast
import dataclasses

from tools.jaxguard.report import Finding
from tools.jaxguard.suppress import Suppressions

# canonical names --------------------------------------------------------
_JIT = {"jax.jit", "jax.pmap"}
_VMAP = {"jax.vmap"}
_PARTIAL = "functools.partial"
_CACHE_DECOS = {"functools.lru_cache", "functools.cache"}
_SPLIT = "jax.random.split"
# entry points whose function arguments get traced
_TRACE_ENTRY = _JIT | _VMAP | {
    "jax.lax.scan", "jax.lax.map", "jax.lax.while_loop", "jax.lax.cond",
    "jax.lax.fori_loop", "jax.lax.associative_scan", "jax.grad",
    "jax.value_and_grad", "jax.checkpoint", "jax.remat", "jax.linearize",
    "jax.experimental.shard_map.shard_map",
}
# jnp constructors whose all-literal calls are per-iteration h2d transfers
_JNP_CONSTRUCTORS = {
    "array", "asarray", "zeros", "ones", "full", "arange", "eye",
    "float32", "float64", "int32", "int64", "bfloat16", "float16",
}
# callables that are safe as function defaults
_DEFAULT_WHITELIST = {
    "field", "dataclasses.field", "frozenset", "tuple", "property",
    "functools.partial", "partial", "MappingProxyType",
}
_HOST_SYNC_BUILTINS = {"float", "int", "bool", "complex"}


def _dotted(node: ast.AST) -> str | None:
    """Raw dotted name of a Name/Attribute chain (``jnp.asarray``)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class _JitSite:
    """One jax.jit(...) call site with its resolved target + keywords."""

    call: ast.Call
    target: ast.FunctionDef | None
    static_argnames: list[str] | None   # None = present but unresolvable
    static_argnums: list[int] | None
    donate_argnums: list[int] | None
    has_static_names_kw: bool
    has_static_nums_kw: bool


class Analyzer:
    """Per-file rule analysis; ``run()`` returns unsuppressed findings."""

    def __init__(self, path: str, source: str, select: set[str] | None = None):
        self.path = path
        self.source = source
        self.select = select
        self.findings: list[Finding] = []
        self.aliases: dict[str, str] = {}
        self.module_consts: dict[str, ast.expr] = {}
        self.donated: dict[str, list[int]] = {}
        self.cache_exempt: set[ast.AST] = set()
        self.traced: set[ast.AST] = set()
        self._all_defs: list[tuple[tuple[ast.AST, ...], ast.AST]] = []

    # -- name resolution -------------------------------------------------
    def qual(self, node: ast.AST) -> str | None:
        """Canonical dotted name with the head import-alias resolved."""
        raw = _dotted(node)
        if raw is None:
            return None
        head, _, rest = raw.partition(".")
        head = self.aliases.get(head, head)
        return f"{head}.{rest}" if rest else head

    # -- entry point -------------------------------------------------------
    def run(self) -> list[Finding]:
        try:
            tree = ast.parse(self.source, filename=self.path)
        except SyntaxError as e:
            # a file that does not parse cannot be vetted — surface it
            self._emit("JG002", e.lineno or 1, 0,
                       f"file does not parse: {e.msg}")
            return self._filtered()
        self._scan_module(tree)
        self._walk(tree, func_stack=(), loop_stack=(), class_stack=())
        return self._filtered()

    def _filtered(self) -> list[Finding]:
        sup = Suppressions(self.source)
        out = [f for f in self.findings
               if not sup.is_suppressed(f.line, f.code)]
        if self.select is not None:
            out = [f for f in out if f.code in self.select]
        return sorted(out)

    def _emit(self, code: str, line: int, col: int, msg: str) -> None:
        self.findings.append(Finding(path=self.path, line=line, col=col,
                                     code=code, message=msg))

    # =====================================================================
    # phase A: module scan
    # =====================================================================
    def _scan_module(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.aliases[a.asname or a.name] = \
                        f"{node.module}.{a.name}"
        # module-level constant tuples (for static_argnames=_STATICS)
        for stmt in tree.body:
            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)):
                self.module_consts[stmt.targets[0].id] = stmt.value
        # defs in lexical order with their enclosing-scope stack
        def collect(node, stack):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda)):
                    self._all_defs.append((stack, child))
                    collect(child, stack + (child,))
                else:
                    collect(child, stack)
        collect(tree, ())

        # decorated defs: jit/cache exemptions, donation registry
        for _, d in self._all_defs:
            if isinstance(d, ast.Lambda):
                continue
            for deco in d.decorator_list:
                site = self._parse_jit_call(deco, target=d)
                if site is not None:
                    self.traced.add(d)
                    if site.donate_argnums:
                        self.donated[d.name] = site.donate_argnums
                if self._is_cache_deco(deco):
                    self.cache_exempt.add(d)

        # module-level `name = jax.jit(fn, ...)` bindings
        defs_by_name = {d.name: d for _, d in self._all_defs
                        if isinstance(d, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))}
        for stmt in tree.body:
            if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and isinstance(stmt.value, ast.Call)):
                continue
            site = self._parse_jit_call(stmt.value)
            if site is None:
                continue
            args = stmt.value.args
            if args and isinstance(args[0], ast.Name):
                site.target = defs_by_name.get(args[0].id)
                if site.target is not None:
                    self.traced.add(site.target)
            if site.donate_argnums:
                self.donated[stmt.targets[0].id] = site.donate_argnums

        # functions handed to tracing entry points anywhere in the file
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and self.qual(node.func) in _TRACE_ENTRY):
                continue
            for arg in node.args:
                if isinstance(arg, ast.Lambda):
                    self.traced.add(arg)
                elif isinstance(arg, ast.Name):
                    d = self._lookup_def(arg.id, node)
                    if d is not None:
                        self.traced.add(d)
        # closure: everything nested inside a traced def is traced
        changed = True
        while changed:
            changed = False
            for stack, d in self._all_defs:
                if d not in self.traced and any(s in self.traced
                                                for s in stack):
                    self.traced.add(d)
                    changed = True

    def _lookup_def(self, name: str, at: ast.AST):
        """Innermost FunctionDef named ``name`` (lexical heuristic)."""
        best = None
        for _, d in self._all_defs:
            if isinstance(d, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and d.name == name:
                best = d
        return best

    def _is_cache_deco(self, deco: ast.AST) -> bool:
        q = self.qual(deco.func if isinstance(deco, ast.Call) else deco)
        return q in _CACHE_DECOS

    # -- jit call parsing --------------------------------------------------
    def _parse_jit_call(self, node: ast.AST,
                        target: ast.FunctionDef | None = None):
        """A _JitSite if ``node`` is jax.jit(...)/partial(jax.jit, ...) (or
        a bare ``@jax.jit`` decorator when ``target`` is given)."""
        if target is not None and not isinstance(node, ast.Call):
            return (_JitSite(call=None, target=target, static_argnames=[],
                             static_argnums=[], donate_argnums=[],
                             has_static_names_kw=False,
                             has_static_nums_kw=False)
                    if self.qual(node) in _JIT else None)
        if not isinstance(node, ast.Call):
            return None
        q = self.qual(node.func)
        call = node
        if q == _PARTIAL:
            if not (node.args and self.qual(node.args[0]) in _JIT):
                return None
        elif q not in _JIT:
            return None
        names = nums = donate = []
        has_names = has_nums = False
        names, has_names = self._kw_strings(call, "static_argnames")
        nums, has_nums = self._kw_ints(call, "static_argnums")
        donate, _ = self._kw_ints(call, "donate_argnums")
        return _JitSite(call=call, target=target, static_argnames=names,
                        static_argnums=nums, donate_argnums=donate,
                        has_static_names_kw=has_names,
                        has_static_nums_kw=has_nums)

    def _const_value(self, node: ast.expr, depth: int = 0):
        """Fold literals, module-level constant Names, and tuple `+`."""
        if depth > 4 or node is None:
            return None
        if isinstance(node, ast.Constant):
            return node.value
        if isinstance(node, (ast.Tuple, ast.List)):
            vals = [self._const_value(e, depth + 1) for e in node.elts]
            return None if any(v is None for v in vals) else tuple(vals)
        if isinstance(node, ast.Name) and node.id in self.module_consts:
            return self._const_value(self.module_consts[node.id], depth + 1)
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
            left = self._const_value(node.left, depth + 1)
            right = self._const_value(node.right, depth + 1)
            if isinstance(left, tuple) and isinstance(right, tuple):
                return left + right
        return None

    def _kw_strings(self, call: ast.Call, kw: str):
        for k in call.keywords:
            if k.arg == kw:
                v = self._const_value(k.value)
                if isinstance(v, str):
                    return [v], True
                if isinstance(v, tuple) and all(isinstance(x, str)
                                                for x in v):
                    return list(v), True
                return None, True
        return [], False

    def _kw_ints(self, call: ast.Call, kw: str):
        for k in call.keywords:
            if k.arg == kw:
                v = self._const_value(k.value)
                if isinstance(v, int) and not isinstance(v, bool):
                    return [v], True
                if isinstance(v, tuple) and all(
                        isinstance(x, int) and not isinstance(x, bool)
                        for x in v):
                    return list(v), True
                return None, True
        return [], False

    # =====================================================================
    # phase B: rule walk
    # =====================================================================
    def _walk(self, node, func_stack, loop_stack, class_stack) -> None:
        self._walk_nodes(ast.iter_child_nodes(node), func_stack, loop_stack,
                         class_stack)

    def _walk_nodes(self, children, func_stack, loop_stack,
                    class_stack) -> None:
        for child in children:
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_defaults(child, class_stack)
                for deco in child.decorator_list:
                    self._check_jit_site(deco, func_stack, loop_stack,
                                         decorator_target=child)
                self._function_dataflow(child)
                # recurse into the BODY only: decorators and defaults were
                # handled above and must not re-trip the in-function rules
                self._walk_nodes(child.body, func_stack + (child,), (),
                                 class_stack)
            elif isinstance(child, ast.Lambda):
                self._walk(child, func_stack + (child,), loop_stack,
                           class_stack)
            elif isinstance(child, ast.ClassDef):
                self._check_dataclass_fields(child)
                self._walk(child, func_stack, loop_stack,
                           class_stack + (child,))
            elif isinstance(child, (ast.For, ast.AsyncFor, ast.While)):
                self._walk(child, func_stack, loop_stack + (child,),
                           class_stack)
            else:
                if isinstance(child, ast.Call):
                    self._check_jit_site(child, func_stack, loop_stack)
                    self._check_jnp_constant(child, func_stack, loop_stack)
                    self._check_host_sync(child, func_stack)
                self._walk(child, func_stack, loop_stack, class_stack)

    # -- JG002 + JG003 ----------------------------------------------------
    def _check_jit_site(self, node, func_stack, loop_stack,
                        decorator_target=None) -> None:
        # jax.vmap in a loop (vmap has no cache at all) — checked before
        # the jit parse, which returns None for vmap calls
        if (isinstance(node, ast.Call) and self.qual(node.func) in _VMAP
                and loop_stack and decorator_target is None
                and not any(f in self.traced for f in func_stack)):
            self._emit("JG002", node.lineno, node.col_offset,
                       "jax.vmap constructed inside a loop — vmap has no "
                       "cache; each iteration re-traces the mapped function")
        site = self._parse_jit_call(node, target=decorator_target)
        if site is None:
            return
        if site.target is None and site.call is not None \
                and self.qual(site.call.func) != _PARTIAL \
                and site.call.args and isinstance(site.call.args[0], ast.Name):
            site.target = self._lookup_def(site.call.args[0].id, node)
        line, col = node.lineno, node.col_offset
        in_function = any(isinstance(f, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))
                          for f in func_stack)
        exempt = any(f in self.cache_exempt for f in func_stack)
        if decorator_target is None and site.call is not None:
            kind = self.qual(site.call.func)
            kind = "functools.partial(jax.jit, ...)" if kind == _PARTIAL \
                else kind
            if loop_stack:
                self._emit("JG002", line, col,
                           f"{kind} constructed inside a loop — a fresh "
                           f"wrapper per iteration re-traces and "
                           f"re-compiles every time; hoist it out")
            elif in_function and not exempt:
                self._emit("JG002", line, col,
                           f"{kind} constructed inside a function body — "
                           f"each call builds a fresh wrapper with an "
                           f"empty trace cache (per-call re-jit); hoist "
                           f"to module scope, a decorator, or an "
                           f"lru_cache'd builder")
        elif decorator_target is not None and in_function and not exempt:
            self._emit("JG002", line, col,
                       f"jitted function {decorator_target.name!r} defined "
                       f"inside a function body — the decorator runs per "
                       f"enclosing call, so its trace cache never survives; "
                       f"hoist to module scope")
        self._check_statics(site)

    def _check_statics(self, site: _JitSite) -> None:
        if site.call is None:
            return
        line, col = site.call.lineno, site.call.col_offset
        if site.has_static_names_kw and site.static_argnames is None:
            return          # dynamic expression we could not fold — skip
        if site.target is None:
            return          # target signature unknown — nothing to check
        a = site.target.args
        params = ([p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
                  + [p.arg for p in a.kwonlyargs])
        n_positional = len(a.posonlyargs) + len(a.args)
        for name in site.static_argnames or []:
            if name not in params:
                self._emit("JG003", line, col,
                           f"static_argnames names {name!r} but "
                           f"{site.target.name!r} has no such parameter "
                           f"(has: {', '.join(params)}) — the intended "
                           f"static is silently ignored")
        for num in site.static_argnums or []:
            if num >= n_positional or num < -n_positional:
                self._emit("JG003", line, col,
                           f"static_argnums {num} is out of range for "
                           f"{site.target.name!r} ({n_positional} "
                           f"positional parameters)")
        # unhashable default on a parameter declared static
        static_set = set(site.static_argnames or [])
        for num in site.static_argnums or []:
            if 0 <= num < n_positional:
                static_set.add(params[num])
        pos_params = a.posonlyargs + a.args
        defaults = a.defaults
        offset = len(pos_params) - len(defaults)
        pairs = [(p.arg, d) for p, d in zip(pos_params[offset:], defaults)]
        pairs += [(p.arg, d) for p, d in zip(a.kwonlyargs, a.kw_defaults)
                  if d is not None]
        for pname, d in pairs:
            if pname in static_set and isinstance(
                    d, (ast.List, ast.Dict, ast.Set)):
                self._emit("JG003", d.lineno, d.col_offset,
                           f"parameter {pname!r} is declared static but "
                           f"defaults to an unhashable "
                           f"{type(d).__name__.lower()} literal — jit "
                           f"will fail to hash it at call time")

    # -- JG004 ------------------------------------------------------------
    def _check_jnp_constant(self, node: ast.Call, func_stack,
                            loop_stack) -> None:
        if not loop_stack or not node.args:
            return
        if any(f in self.traced for f in func_stack):
            return                      # trace-time loop: compiles once
        q = self.qual(node.func)
        if not (q and q.startswith("jax.numpy.")
                and q.rsplit(".", 1)[1] in _JNP_CONSTRUCTORS):
            return

        def literal(e) -> bool:
            if isinstance(e, ast.Constant):
                return True
            if isinstance(e, (ast.Tuple, ast.List)):
                return all(literal(x) for x in e.elts)
            if isinstance(e, ast.UnaryOp):
                return literal(e.operand)
            return False

        if all(literal(a) for a in node.args):
            self._emit("JG004", node.lineno, node.col_offset,
                       f"{_dotted(node.func)}(...) built from Python "
                       f"literals inside a loop — one host-to-device "
                       f"transfer per iteration for a constant; hoist it "
                       f"above the loop")

    # -- JG005 ------------------------------------------------------------
    def _check_defaults(self, fn, class_stack) -> None:
        a = fn.args
        pos_params = a.posonlyargs + a.args
        offset = len(pos_params) - len(a.defaults)
        pairs = list(zip(pos_params[offset:], a.defaults))
        pairs += [(p, d) for p, d in zip(a.kwonlyargs, a.kw_defaults)
                  if d is not None]
        for p, d in pairs:
            msg = self._mutable_default_msg(d)
            if msg:
                self._emit("JG005", d.lineno, d.col_offset,
                           f"parameter {p.arg!r} of {fn.name!r}: {msg}")

    def _mutable_default_msg(self, d: ast.expr) -> str | None:
        if isinstance(d, (ast.List, ast.Dict, ast.Set)):
            return (f"mutable {type(d).__name__.lower()} literal default — "
                    f"evaluated once at def time and shared across every "
                    f"call; use None and construct in the body")
        if isinstance(d, ast.Call):
            raw = _dotted(d.func)
            if raw is None or raw in _DEFAULT_WHITELIST \
                    or raw.rsplit(".", 1)[-1] in _DEFAULT_WHITELIST:
                return None
            last = raw.rsplit(".", 1)[-1]
            if last in {"list", "dict", "set"} or (last and
                                                   last[0].isupper()):
                return (f"default constructed by calling {raw}() in the "
                        f"signature — the single instance is evaluated "
                        f"once at def time and shared across every call; "
                        f"use None and construct in the body")
        return None

    def _check_dataclass_fields(self, cls: ast.ClassDef) -> None:
        is_dc = any(
            self.qual(d.func if isinstance(d, ast.Call) else d)
            in {"dataclasses.dataclass", "dataclass",
                "flax.struct.dataclass", "chex.dataclass"}
            for d in cls.decorator_list)
        if not is_dc:
            return
        for stmt in cls.body:
            if not (isinstance(stmt, ast.AnnAssign)
                    and stmt.value is not None):
                continue
            v = stmt.value
            bad = None
            if isinstance(v, (ast.List, ast.Dict, ast.Set)):
                bad = f"a mutable {type(v).__name__.lower()} literal"
            elif isinstance(v, ast.Call):
                q = self.qual(v.func) or ""
                raw = _dotted(v.func) or ""
                if raw.rsplit(".", 1)[-1] in {"list", "dict", "set"} or \
                        q.startswith(("numpy.", "jax.numpy.")):
                    bad = f"an array/collection built by {raw}()"
            if bad:
                name = stmt.target.id if isinstance(stmt.target, ast.Name) \
                    else "?"
                self._emit("JG005", v.lineno, v.col_offset,
                           f"pytree dataclass field {name!r} defaults to "
                           f"{bad} — one shared instance across every "
                           f"dataclass instance; use "
                           f"dataclasses.field(default_factory=...)")

    # -- JG007 ------------------------------------------------------------
    def _check_host_sync(self, node: ast.Call, func_stack) -> None:
        if not any(f in self.traced for f in func_stack):
            return
        line, col = node.lineno, node.col_offset

        def is_dynamic(e) -> bool:
            # attribute access is overwhelmingly static-config access
            # (cfg.lr, self.n) — skip it to keep the rule quiet
            return isinstance(e, (ast.Name, ast.Subscript, ast.Call,
                                  ast.BinOp))

        q = self.qual(node.func)
        raw = _dotted(node.func)
        if q in _HOST_SYNC_BUILTINS and len(node.args) == 1 \
                and is_dynamic(node.args[0]):
            self._emit("JG007", line, col,
                       f"{q}(...) on a (possibly traced) value inside a "
                       f"jitted code path — concretizes the tracer: "
                       f"either a trace-time error or a silent "
                       f"device-to-host sync")
        elif q and q.startswith("numpy.") and node.args \
                and is_dynamic(node.args[0]) \
                and q.rsplit(".", 1)[1] in {"asarray", "array", "float32",
                                            "float64", "int32", "int64"}:
            self._emit("JG007", line, col,
                       f"{raw}(...) inside a jitted code path pulls the "
                       f"value to host numpy — use jnp (stays traced) or "
                       f"move the conversion outside the jitted function")
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr == "item" and not node.args:
            self._emit("JG007", line, col,
                       ".item() inside a jitted code path — a forced "
                       "device-to-host sync on a traced value")

    # =====================================================================
    # per-function linear dataflow: JG001 + JG006
    # =====================================================================
    def _function_dataflow(self, fn) -> None:
        own = self._own_nodes(fn)
        stores = [(n.lineno, n.id) for n in own
                  if isinstance(n, ast.Name)
                  and isinstance(n.ctx, (ast.Store, ast.Del))]
        loads = [(n.lineno, n.col_offset, n.id) for n in own
                 if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)]
        loops = [n for n in own
                 if isinstance(n, (ast.For, ast.AsyncFor, ast.While))]
        # return/raise lines: a terminator between consumption and use
        # usually means the two sit in mutually-exclusive branches, which
        # this linear pass cannot tell apart — stay quiet there
        exits = [(n.lineno, n.end_lineno or n.lineno) for n in own
                 if isinstance(n, (ast.Return, ast.Raise))]

        def stored_between(name, lo, hi) -> bool:
            return any(nm == name and lo < ln <= hi for ln, nm in stores)

        def flag_uses_after(name, line, code, msg_fn) -> None:
            flagged = 0
            for ln, col, nm in sorted(loads):
                if nm != name or ln <= line:
                    continue
                if stored_between(name, line, ln):
                    break
                if any(line < ex and ex_end < ln for ex, ex_end in exits):
                    break
                self._emit(code, ln, col, msg_fn(ln))
                flagged += 1
                if flagged >= 2:        # cap the noise per consumption
                    break

        for stmt in own:
            if not isinstance(stmt, ast.Call):
                continue
            # JG001: jax.random.split(key) consumption
            if self.qual(stmt.func) == _SPLIT and stmt.args \
                    and isinstance(stmt.args[0], ast.Name):
                key = stmt.args[0].id
                targets = self._stmt_targets(stmt, fn)
                if key in targets:
                    continue            # `key, sub = split(key)` rebinding
                flag_uses_after(
                    key, stmt.lineno, "JG001",
                    lambda ln, k=key, sl=stmt.lineno: (
                        f"PRNG key {k!r} used again after "
                        f"jax.random.split({k}, ...) consumed it at line "
                        f"{sl} — derived streams are correlated; rebind "
                        f"(`{k}, sub = jax.random.split({k})`) or fold_in"))
                enclosing = [lp for lp in loops
                             if lp.lineno <= stmt.lineno
                             <= (lp.end_lineno or lp.lineno)
                             # `for k in split(key, n):` splits once per
                             # *enclosing* pass, not per iteration — the
                             # header is not inside the loop body
                             and not any(n is stmt for n in ast.walk(
                                 lp.iter if isinstance(
                                     lp, (ast.For, ast.AsyncFor))
                                 else lp.test))]
                if enclosing:
                    loop = enclosing[-1]
                    lo, hi = loop.lineno, loop.end_lineno or loop.lineno
                    if not any(nm == key and lo <= ln <= hi
                               for ln, nm in stores):
                        self._emit(
                            "JG001", stmt.lineno, stmt.col_offset,
                            f"jax.random.split({key!r}, ...) inside a loop "
                            f"without rebinding {key!r} — every iteration "
                            f"derives the SAME streams; rebind the key "
                            f"each pass or split once outside")
            # JG006: donated-buffer reads after a donating call
            callee = _dotted(stmt.func)
            if callee in self.donated:
                targets = self._stmt_targets(stmt, fn)
                for idx in self.donated[callee]:
                    if idx >= len(stmt.args):
                        continue
                    arg = stmt.args[idx]
                    if not isinstance(arg, ast.Name) or arg.id in targets:
                        continue
                    flag_uses_after(
                        arg.id, stmt.lineno, "JG006",
                        lambda ln, a=arg.id, c=callee, sl=stmt.lineno: (
                            f"{a!r} was donated to {c}(...) at line {sl} "
                            f"(donate_argnums) and read again — the "
                            f"buffer may already be aliased by the "
                            f"outputs; copy what you need before the "
                            f"call or rebind the result"))

    def _own_nodes(self, fn) -> list[ast.AST]:
        """Nodes of ``fn``'s body, excluding nested function/class scopes."""
        out = []

        def rec(node):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda, ast.ClassDef)):
                    continue
                out.append(child)
                rec(child)
        for stmt in fn.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            out.append(stmt)
            rec(stmt)
        return out

    def _stmt_targets(self, call: ast.Call, fn) -> set[str]:
        """Names assigned by the statement containing ``call``."""
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                if any(c is call for c in ast.walk(node)):
                    targets = node.targets if isinstance(node, ast.Assign) \
                        else [node.target]
                    names = set()
                    for t in targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                names.add(n.id)
                    return names
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                if any(c is call for c in ast.walk(node.iter)):
                    return {n.id for n in ast.walk(node.target)
                            if isinstance(n, ast.Name)}
        return set()


def analyze_source(path: str, source: str,
                   select: set[str] | None = None) -> list[Finding]:
    return Analyzer(path, source, select=select).run()
