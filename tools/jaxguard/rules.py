"""The jaxguard rule catalog.

Each rule names one JAX-specific silent failure mode.  The catalog is the
single source of truth: the CLI's ``--list-rules``, the ``--select``
validation, docs/static_analysis.md, and the JSON report all key on these
codes.  Detection logic lives in visitors.py; this module is pure data.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    name: str
    summary: str


RULES: dict[str, Rule] = {
    r.code: r for r in (
        Rule("JG001", "key-reuse-after-split",
             "a PRNG key is used again after jax.random.split consumed it "
             "(or is split inside a loop without rebinding) — the derived "
             "streams are correlated, silently breaking seed independence"),
        Rule("JG002", "jit-in-function",
             "jax.jit / jax.pmap constructed inside a function body or "
             "jax.vmap built inside a loop — a fresh wrapper means a fresh "
             "trace cache, so every call re-traces and re-compiles; hoist "
             "to module scope, a decorator, or an lru_cache'd builder"),
        Rule("JG003", "bad-static-args",
             "static_argnames/static_argnums that do not match the jitted "
             "function's signature, or a static parameter with an "
             "unhashable (mutable) default — jit either ignores the "
             "intended static or dies on hashing at call time"),
        Rule("JG004", "scalar-constant-in-loop",
             "a jnp array is constructed from Python literals inside a "
             "Python loop — one host-to-device transfer per iteration for "
             "a value that never changes; hoist it out of the loop"),
        Rule("JG005", "mutable-default",
             "a mutable default argument (list/dict/set display or an "
             "object constructed in the signature) on a function or a "
             "pytree dataclass field — the single instance is shared "
             "across every call/instance"),
        Rule("JG006", "donated-buffer-reuse",
             "an argument passed at a donate_argnums position is read "
             "again after the donating call — the buffer was handed to "
             "XLA and may alias the outputs; copy what you need first"),
        Rule("JG007", "host-sync-in-jit",
             "float()/int()/bool()/.item()/np.asarray on a traced value "
             "inside a jitted (or scan/vmap-traced) function — either a "
             "ConcretizationTypeError at trace time or a silent "
             "device-to-host sync that serializes the program"),
    )
}


def validate_codes(codes) -> set[str]:
    """Normalize + validate a user-supplied code collection."""
    out = set()
    for c in codes:
        c = c.strip().upper()
        if not c:
            continue
        if c not in RULES:
            raise ValueError(
                f"unknown jaxguard rule {c!r}; known: {sorted(RULES)}")
        out.add(c)
    return out
