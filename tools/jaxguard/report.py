"""Findings + report rendering (text for terminals, JSON for CI artifacts).

The JSON schema is versioned and pinned by tests/test_jaxguard.py — bump
``SCHEMA_VERSION`` when a field changes shape so downstream consumers
(the CI artifact, dashboards) can dispatch on it.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

from tools.jaxguard.rules import RULES

SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at one source location (1-indexed line/col)."""

    path: str
    line: int
    col: int
    code: str
    message: str

    @property
    def rule_name(self) -> str:
        return RULES[self.code].name

    def to_dict(self) -> dict:
        return {"code": self.code, "rule": self.rule_name, "path": self.path,
                "line": self.line, "col": self.col, "message": self.message}


def render_text(findings: list[Finding]) -> str:
    lines = [f"{f.path}:{f.line}:{f.col}: {f.code} [{f.rule_name}] "
             f"{f.message}" for f in sorted(findings)]
    counts = count_by_code(findings)
    if findings:
        total = ", ".join(f"{code}={n}" for code, n in sorted(counts.items()))
        lines.append(f"jaxguard: {len(findings)} finding(s) ({total})")
    return "\n".join(lines)


def count_by_code(findings: list[Finding]) -> dict[str, int]:
    counts: dict[str, int] = {}
    for f in findings:
        counts[f.code] = counts.get(f.code, 0) + 1
    return counts


def render_json(findings: list[Finding], roots: list[str],
                files_scanned: int) -> dict:
    return {
        "schema_version": SCHEMA_VERSION,
        "roots": list(roots),
        "files_scanned": files_scanned,
        "findings": [f.to_dict() for f in sorted(findings)],
        "counts": count_by_code(findings),
    }


def write_json(report: dict, path: str | pathlib.Path) -> pathlib.Path:
    out = pathlib.Path(path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2) + "\n")
    return out
