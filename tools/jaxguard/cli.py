"""jaxguard command line: scan paths, print findings, write the JSON
artifact, exit nonzero when anything is flagged.

    python -m tools.jaxguard src/ --json artifacts/jaxguard.json
    python -m tools.jaxguard src/repro/core/agent.py --select JG001,JG006
    python -m tools.jaxguard --list-rules
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from tools.jaxguard.report import (Finding, render_json, render_text,
                                   write_json)
from tools.jaxguard.rules import RULES, validate_codes
from tools.jaxguard.visitors import analyze_source

_SKIP_DIRS = {"__pycache__", ".git", "artifacts"}


def iter_py_files(paths: list[str]) -> list[pathlib.Path]:
    files: list[pathlib.Path] = []
    for p in paths:
        path = pathlib.Path(p)
        if path.is_dir():
            files += [f for f in sorted(path.rglob("*.py"))
                      if not (set(f.parts) & _SKIP_DIRS)]
        elif path.suffix == ".py":
            files.append(path)
        else:
            raise SystemExit(f"jaxguard: not a python file or directory: {p}")
    return files


def scan(paths: list[str],
         select: set[str] | None = None) -> tuple[list[Finding], int]:
    findings: list[Finding] = []
    files = iter_py_files(paths)
    for f in files:
        findings += analyze_source(str(f), f.read_text(), select=select)
    return findings, len(files)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.jaxguard",
        description="JAX-hazard static analysis (rule catalog: "
                    "docs/static_analysis.md)")
    ap.add_argument("paths", nargs="*", help="files or directories to scan")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the versioned JSON report here")
    ap.add_argument("--select", default=None, metavar="CODES",
                    help="comma-separated rule codes to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code, rule in sorted(RULES.items()):
            print(f"{code} [{rule.name}]\n    {rule.summary}")
        return 0
    if not args.paths:
        ap.error("no paths given (or use --list-rules)")

    select = None
    if args.select:
        try:
            select = validate_codes(args.select.split(","))
        except ValueError as e:
            ap.error(str(e))
    findings, n_files = scan(args.paths, select=select)
    text = render_text(findings)
    if text:
        print(text)
    else:
        print(f"jaxguard: {n_files} file(s) clean")
    if args.json:
        out = write_json(render_json(findings, args.paths, n_files),
                         args.json)
        print(f"wrote {out}")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
