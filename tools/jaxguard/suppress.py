"""Suppression-comment handling.

Two forms, both case-insensitive in the ``jaxguard`` tag:

* line-level — a trailing comment on the flagged line:
      x = jax.jit(f)  # jaxguard: disable=JG002
  (multiple codes comma-separated; ``disable=all`` silences every rule on
  that line)
* file-level — anywhere in the file, typically near the top:
      # jaxguard: disable-file=JG004,JG007

Suppressions are matched against the *reported* line of a finding, which
for multi-line calls is the line the call starts on.
"""
from __future__ import annotations

import re

_LINE = re.compile(r"#\s*jaxguard:\s*disable=([A-Za-z0-9,\s]+|all)",
                   re.IGNORECASE)
_FILE = re.compile(r"#\s*jaxguard:\s*disable-file=([A-Za-z0-9,\s]+|all)",
                   re.IGNORECASE)

ALL = "all"


def _codes(raw: str) -> set[str]:
    raw = raw.strip()
    if raw.lower() == ALL:
        return {ALL}
    return {c.strip().upper() for c in raw.split(",") if c.strip()}


class Suppressions:
    """Per-file suppression table: line -> codes, plus file-level codes."""

    def __init__(self, source: str):
        self.by_line: dict[int, set[str]] = {}
        self.file_level: set[str] = set()
        for i, line in enumerate(source.splitlines(), start=1):
            if "#" not in line:
                continue
            m = _FILE.search(line)
            if m:
                self.file_level |= _codes(m.group(1))
                continue
            m = _LINE.search(line)
            if m:
                self.by_line.setdefault(i, set()).update(_codes(m.group(1)))

    def is_suppressed(self, line: int, code: str) -> bool:
        if ALL in self.file_level or code in self.file_level:
            return True
        codes = self.by_line.get(line, ())
        return ALL in codes or code in codes
