from tools.jaxguard.cli import main

raise SystemExit(main())
