"""jaxguard — JAX-hazard static analysis for this repo.

An AST-based lint pass over the JAX-specific silent failure modes that
grow with fleet machinery: PRNG key reuse (JG001), per-call re-jitting
(JG002), broken static-argument declarations (JG003), per-iteration
constant transfers (JG004), shared mutable defaults (JG005), donated
buffers read after the donating call (JG006), and host syncs inside
jitted code (JG007).

    python -m tools.jaxguard src/ --json artifacts/jaxguard.json

Rule catalog + per-rule example diffs: docs/static_analysis.md.  The
runtime counterpart (transfer guards, the jit-cache-miss sentinel, NaN
sweeps) lives in ``repro.diagnostics``.
"""
from tools.jaxguard.report import (Finding, SCHEMA_VERSION, render_json,
                                   render_text)
from tools.jaxguard.rules import RULES, Rule
from tools.jaxguard.visitors import Analyzer, analyze_source
from tools.jaxguard.cli import main, scan

__all__ = ["Analyzer", "Finding", "RULES", "Rule", "SCHEMA_VERSION",
           "analyze_source", "main", "render_json", "render_text", "scan"]
