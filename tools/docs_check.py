"""Documentation health checks (the CI docs job).

Three checks, all runnable locally:

  python tools/docs_check.py                  # link check + examples parse
  python tools/docs_check.py --run-quickstart # + exec the README quickstart
  python tools/docs_check.py --run-examples   # + exec EVERY registered example

* Link check: every relative markdown link in README.md and docs/*.md
  must point at a file or directory that exists in the repo (external
  http(s)/mailto links and pure #anchors are skipped; #fragments on
  relative links are stripped before the existence check).
* Executable examples: EXECUTABLE_DOCS registers markdown files whose
  FIRST ```python fenced block is a living example — currently the
  README quickstart and the docs/elastic_fleets.md lane-lifecycle
  walkthrough.  Each registered block is extracted and parsed on every
  run, and executed by the CI docs job (which pins JAX_PLATFORMS=cpu),
  so the snippets users copy first can never rot.

tests/test_docs.py runs the link check and compiles every registered
example in tier-1; the CI docs job additionally executes them."""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")

# repo-relative markdown files whose first ```python block must stay
# executable (extract-and-exec'd in the CI docs job).  A "#anchor"
# suffix scopes the extraction to the first ```python block AFTER that
# heading (github slug rules), so mid-document snippets register too.
EXECUTABLE_DOCS = (
    "README.md",
    "docs/elastic_fleets.md",
    "docs/graph_policies.md",
    "docs/serving.md",
    "docs/sharded_fleets.md#multi-host-fleets",
    "docs/streaming_agents.md",
)


def _anchor_slug(heading: str) -> str:
    """Github's heading → anchor slug (enough of it for our docs)."""
    slug = re.sub(r"[^\w\- ]", "", heading.strip().lower())
    return slug.replace(" ", "-")


def markdown_files() -> list[pathlib.Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def check_links() -> list[tuple[pathlib.Path, str]]:
    """All broken relative links as (markdown file, link target)."""
    broken = []
    for md in markdown_files():
        for target in _LINK.findall(md.read_text()):
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (md.parent / path).exists():
                broken.append((md, target))
    return broken


def extract_example(entry: str) -> str:
    """The first ```python fenced block of a repo-relative markdown file;
    with a ``#anchor`` suffix, the first block after that heading."""
    rel_path, _, anchor = entry.partition("#")
    text = (REPO / rel_path).read_text()
    if anchor:
        for m in re.finditer(r"^#+\s+(.+?)\s*$", text, re.MULTILINE):
            if _anchor_slug(m.group(1)) == anchor:
                text = text[m.end():]
                break
        else:
            raise SystemExit(f"{rel_path} has no heading with "
                             f"anchor #{anchor}")
    m = re.search(r"```python\n(.*?)```", text, re.DOTALL)
    if m is None:
        raise SystemExit(f"{entry} has no ```python example block")
    return m.group(1)


def extract_quickstart() -> str:
    """The README quickstart (kept for back-compat callers)."""
    return extract_example("README.md")


def _exec_example(rel_path: str, snippet: str) -> None:
    sys.path.insert(0, str(REPO / "src"))
    exec(compile(snippet, rel_path, "exec"), {"__name__": "__example__"})  # noqa: S102
    print(f"{rel_path} example executed ok")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-quickstart", action="store_true",
                    help="extract and exec the README quickstart block "
                         "(needs the package importable; pin "
                         "JAX_PLATFORMS=cpu in CI)")
    ap.add_argument("--run-examples", action="store_true",
                    help="extract and exec EVERY registered executable "
                         "example (EXECUTABLE_DOCS), README quickstart "
                         "included")
    args = ap.parse_args()

    broken = check_links()
    for md, target in broken:
        print(f"BROKEN LINK {md.relative_to(REPO)}: {target}")
    if broken:
        return 1
    print(f"links ok across {len(markdown_files())} markdown files")

    for rel in EXECUTABLE_DOCS:
        snippet = extract_example(rel)
        compile(snippet, rel, "exec")
        print(f"{rel} example parses "
              f"({len(snippet.splitlines())} lines)")
    if args.run_examples:
        for rel in EXECUTABLE_DOCS:
            _exec_example(rel, extract_example(rel))
    elif args.run_quickstart:
        _exec_example("README.md", extract_quickstart())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
