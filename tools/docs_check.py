"""Documentation health checks (the CI docs job).

Two checks, both runnable locally:

  python tools/docs_check.py                  # intra-repo link check
  python tools/docs_check.py --run-quickstart # + exec the README quickstart

* Link check: every relative markdown link in README.md and docs/*.md
  must point at a file or directory that exists in the repo (external
  http(s)/mailto links and pure #anchors are skipped; #fragments on
  relative links are stripped before the existence check).
* Quickstart smoke: the first ```python fenced block in README.md is
  extracted and executed (CI pins JAX_PLATFORMS=cpu), so the 15-line
  example users copy first can never rot.

tests/test_docs.py runs the link check and compiles the quickstart in
tier-1; the CI docs job additionally executes it."""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def markdown_files() -> list[pathlib.Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def check_links() -> list[tuple[pathlib.Path, str]]:
    """All broken relative links as (markdown file, link target)."""
    broken = []
    for md in markdown_files():
        for target in _LINK.findall(md.read_text()):
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (md.parent / path).exists():
                broken.append((md, target))
    return broken


def extract_quickstart() -> str:
    """The first ```python fenced block in README.md."""
    text = (REPO / "README.md").read_text()
    m = re.search(r"```python\n(.*?)```", text, re.DOTALL)
    if m is None:
        raise SystemExit("README.md has no ```python quickstart block")
    return m.group(1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-quickstart", action="store_true",
                    help="extract and exec the README quickstart block "
                         "(needs the package importable; pin "
                         "JAX_PLATFORMS=cpu in CI)")
    args = ap.parse_args()

    broken = check_links()
    for md, target in broken:
        print(f"BROKEN LINK {md.relative_to(REPO)}: {target}")
    if broken:
        return 1
    print(f"links ok across {len(markdown_files())} markdown files")

    snippet = extract_quickstart()
    compile(snippet, "README.md quickstart", "exec")
    print(f"quickstart block parses ({len(snippet.splitlines())} lines)")
    if args.run_quickstart:
        sys.path.insert(0, str(REPO / "src"))
        exec(snippet, {"__name__": "__quickstart__"})   # noqa: S102
        print("quickstart executed ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
