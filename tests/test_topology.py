"""Topology/observation-layer invariants (the graph_policy substrate).

The routing matrix R is the ground truth every layer above trusts — the
flow solver, the latency model, and now the graph observation that
``graph_policy`` message-passes over.  Its invariants are pinned as
properties over randomly-generated component DAGs (via the
``hypothesis_compat`` shim — clean per-test skips when the ``test``
extra isn't installed):

  * row mass: R's row for an executor of component ``c`` sums to
    selectivity(c) x (sum over outgoing edges of the fan-out mass: 1 for
    shuffle/fields/global, P_dst for all-grouping replication);
  * fields grouping: the skewed key split is a valid distribution over
    the downstream executors, identical for every upstream executor;
  * global grouping: everything lands on executor 0 of the downstream
    component;
  * executor expansion: executor ids partition by component exactly at
    the declared parallelisms.

Malformed topologies (cycles, unknown component/grouping names,
duplicate components) must be rejected at construction, and
``to_graph_obs`` must pad without ever touching real entries — the
real-node/edge prefix is bit-identical at every envelope, and a
too-small envelope raises instead of truncating.
"""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.dsdps.topology import (ALL, FIELDS, GLOBAL, SHUFFLE, Component,
                                  Edge, Topology)

GROUPINGS = (SHUFFLE, FIELDS, GLOBAL, ALL)


def _chain(par, groups, skews, sels, tag="chain"):
    """spout -> b1 -> ... chain: one component per level, one edge per
    hop — every generated instance is a DAG by construction and each
    (src, dst) pair carries exactly ONE edge, so per-edge invariants can
    be read straight off R's rows."""
    comps = [Component("c0", par[0], cpu_ms_per_tuple=0.1,
                       selectivity=sels[0], is_spout=True)]
    edges = []
    for i in range(1, len(par)):
        comps.append(Component(f"c{i}", par[i], cpu_ms_per_tuple=0.1,
                               selectivity=sels[i]))
        edges.append(Edge(f"c{i-1}", f"c{i}", GROUPINGS[groups[i - 1]],
                          skew=skews[i - 1]))
    return Topology(name=tag, components=comps, edges=edges)


chain_args = dict(
    par=st.lists(st.integers(min_value=1, max_value=5), min_size=2,
                 max_size=5),
    seed=st.integers(min_value=0, max_value=10),
    data=st.data(),
)


def _draw_chain(par, seed, data):
    k = len(par) - 1
    groups = data.draw(st.lists(st.integers(min_value=0, max_value=3),
                                min_size=k, max_size=k))
    skews = data.draw(st.lists(
        st.floats(min_value=0.0, max_value=2.5, allow_nan=False),
        min_size=k, max_size=k))
    sels = data.draw(st.lists(
        st.floats(min_value=0.05, max_value=4.0, allow_nan=False),
        min_size=len(par), max_size=len(par)))
    return _chain(par, groups, skews, sels), groups, skews, sels, seed


@settings(max_examples=40, deadline=None)
@given(**chain_args)
def test_row_mass_is_selectivity_times_fanout(par, seed, data):
    topo, groups, _, sels, seed = _draw_chain(par, seed, data)
    R = topo.routing_matrix(seed)
    for ci in range(len(par)):
        out_edges = [e for e in topo.edges if e.src == f"c{ci}"]
        mass = sum(
            (topo.component(e.dst).parallelism if e.grouping == ALL else 1.0)
            for e in out_edges)
        for i in topo.executor_slice(f"c{ci}"):
            np.testing.assert_allclose(R[i].sum(), sels[ci] * mass,
                                       rtol=1e-12)


@settings(max_examples=40, deadline=None)
@given(**chain_args)
def test_fields_split_is_a_distribution_shared_by_all_senders(
        par, seed, data):
    topo, groups, _, sels, seed = _draw_chain(par, seed, data)
    R = topo.routing_matrix(seed)
    for e in topo.edges:
        dst_ids = list(topo.executor_slice(e.dst))
        src_ids = list(topo.executor_slice(e.src))
        sel = topo.component(e.src).selectivity
        fracs = np.asarray([R[i, dst_ids] / sel for i in src_ids])
        if e.grouping in (SHUFFLE, FIELDS):
            assert (fracs >= 0.0).all()
            np.testing.assert_allclose(fracs.sum(axis=1), 1.0, rtol=1e-12)
            # the key-hash split is a property of the EDGE: every
            # upstream executor sees the identical (skewed) distribution
            np.testing.assert_allclose(fracs, fracs[:1], rtol=1e-12)
        if e.grouping == SHUFFLE:
            np.testing.assert_allclose(fracs, 1.0 / len(dst_ids), rtol=1e-12)
        if e.grouping == GLOBAL:
            expect = np.zeros(len(dst_ids))
            expect[0] = 1.0
            np.testing.assert_allclose(fracs, expect[None, :], atol=1e-15)
        if e.grouping == ALL:
            np.testing.assert_allclose(fracs, 1.0, rtol=1e-12)


@settings(max_examples=40, deadline=None)
@given(**chain_args)
def test_executor_expansion_matches_parallelism(par, seed, data):
    topo, *_ = _draw_chain(par, seed, data)
    assert topo.num_executors == sum(par)
    comp_of = topo.executor_component
    for ci, p in enumerate(par):
        sl = topo.executor_slice(f"c{ci}")
        assert len(sl) == p
        assert (comp_of[list(sl)] == ci).all()
    # slices partition [0, N): every executor belongs to exactly one comp
    seen = sorted(i for ci in range(len(par))
                  for i in topo.executor_slice(f"c{ci}"))
    assert seen == list(range(topo.num_executors))


@settings(max_examples=25, deadline=None)
@given(**chain_args)
def test_routing_matrix_deterministic_per_seed(par, seed, data):
    topo, *_ = _draw_chain(par, seed, data)
    np.testing.assert_array_equal(topo.routing_matrix(seed),
                                  topo.routing_matrix(seed))


# -- malformed topologies are rejected at construction ----------------------
def _two(edges):
    return Topology(name="bad", components=[
        Component("a", 2, cpu_ms_per_tuple=0.1, is_spout=True),
        Component("b", 2, cpu_ms_per_tuple=0.1),
    ], edges=edges)


def test_cycle_rejected():
    with pytest.raises(ValueError, match="cycle"):
        _two([Edge("a", "b"), Edge("b", "a")])


def test_self_loop_rejected():
    with pytest.raises(ValueError, match="cycle"):
        _two([Edge("a", "b"), Edge("b", "b")])


def test_unknown_component_rejected():
    with pytest.raises(ValueError, match="unknown component"):
        _two([Edge("a", "nope")])


def test_unknown_grouping_rejected():
    with pytest.raises(ValueError, match="unknown grouping"):
        _two([Edge("a", "b", grouping="broadcast")])


def test_duplicate_component_names_rejected():
    with pytest.raises(ValueError, match="duplicate component names"):
        Topology(name="bad", components=[
            Component("a", 2, cpu_ms_per_tuple=0.1, is_spout=True),
            Component("a", 3, cpu_ms_per_tuple=0.1),
        ], edges=[])


# -- to_graph_obs: padding is inert, truncation is an error -----------------
def _diamondish():
    return Topology(name="obs", components=[
        Component("s", 2, cpu_ms_per_tuple=0.05, selectivity=1.0,
                  tuple_bytes=128, is_spout=True),
        Component("f", 3, cpu_ms_per_tuple=0.3, selectivity=2.0,
                  tuple_bytes=256),
        Component("g", 2, cpu_ms_per_tuple=0.2, selectivity=0.0,
                  tuple_bytes=64),
    ], edges=[Edge("s", "f", SHUFFLE), Edge("f", "g", FIELDS, skew=0.7)])


def test_graph_obs_real_prefix_identical_across_envelopes():
    topo = _diamondish()
    n = topo.num_executors
    R = topo.routing_matrix(0)
    e = int(np.count_nonzero(R))
    tight = topo.to_graph_obs(n, e)
    padded = topo.to_graph_obs(n + 9, e + 17)
    assert tight.num_executors == padded.num_executors == n
    assert tight.num_edges == padded.num_edges == e
    for leaf in ("service_ms", "tuple_bytes", "is_spout", "out_mass",
                 "in_mass", "node_mask"):
        np.testing.assert_array_equal(getattr(tight, leaf)[:n],
                                      getattr(padded, leaf)[:n])
        assert (getattr(padded, leaf)[n:] == 0.0).all()
    for leaf in ("edge_src", "edge_dst", "edge_w", "edge_mask"):
        np.testing.assert_array_equal(getattr(tight, leaf)[:e],
                                      getattr(padded, leaf)[:e])
    # padded edges point at the sacrificial segment with zero weight
    assert (padded.edge_src[e:] == n + 9).all()
    assert (padded.edge_dst[e:] == n + 9).all()
    assert (padded.edge_w[e:] == 0.0).all()
    assert (padded.edge_mask[e:] == 0.0).all()


def test_graph_obs_matches_routing_matrix():
    topo = _diamondish()
    R = topo.routing_matrix(0)
    obs = topo.to_graph_obs(topo.num_executors + 3,
                            int(np.count_nonzero(R)) + 5)
    e = obs.num_edges
    np.testing.assert_allclose(
        obs.edge_w[:e],
        R[obs.edge_src[:e], obs.edge_dst[:e]].astype(np.float32))
    dense = np.zeros_like(R)
    dense[obs.edge_src[:e], obs.edge_dst[:e]] = obs.edge_w[:e]
    np.testing.assert_allclose(dense, R, rtol=1e-6)
    np.testing.assert_allclose(obs.out_mass[: topo.num_executors],
                               R.sum(axis=1).astype(np.float32))
    np.testing.assert_allclose(obs.in_mass[: topo.num_executors],
                               R.sum(axis=0).astype(np.float32))


def test_graph_obs_envelope_overflow_raises():
    topo = _diamondish()
    with pytest.raises(ValueError, match="exceeds graph envelope"):
        topo.to_graph_obs(topo.num_executors - 1, 999)
    with pytest.raises(ValueError, match="exceeds graph envelope"):
        topo.to_graph_obs(topo.num_executors, 2)
