"""Functional core API v1: EnvParams pytree, the Agent interface +
registry, scenario fleets, and the params-vmapped fleet runner.

The contract under test: (a) the functional runner reproduces the legacy
per-epoch Python oracles, (b) a heterogeneous-scenario fleet lane i is
bit-identical to a single run built from params lane i, (c) every
registered agent runs end-to-end through the same runner, and (d) the
id(env)-keyed runner cache is gone."""
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.agent as agent_mod
from repro.core import (DDPGConfig, DQNConfig, agent_families, agent_names,
                        ddpg_init, make_agent, run_online_agent,
                        run_online_ddpg_python,
                        run_online_dqn_python, run_online_fleet)
from repro.core import ddpg, dqn
from repro.core.agent import History
from repro.core.placement import ExpertPlacementEnv, build_scenario
from repro.dsdps import (SchedulingEnv, apps, lane_params, params_in_axes,
                         params_stacked, perturb_service, scale_rates,
                         scenarios, stack_env_params, with_noise_sigma,
                         with_straggler)
from repro.dsdps.apps import default_workload


@pytest.fixture(scope="module")
def small_env():
    topo = apps.continuous_queries("small")
    return SchedulingEnv(topo, default_workload(topo))


@pytest.fixture(scope="module")
def ddpg_cfg(small_env):
    return DDPGConfig(n_executors=small_env.N, n_machines=small_env.M,
                      state_dim=small_env.state_dim, k_nn=4)


# --------------------------------------------------------------------------
# EnvParams pytree + functional env surface
# --------------------------------------------------------------------------
def test_default_params_is_jnp_pytree(small_env):
    p = small_env.default_params()
    leaves = jax.tree_util.tree_leaves(p)
    assert leaves, "EnvParams must be a non-empty pytree"
    for leaf in leaves:
        assert isinstance(leaf, jnp.ndarray)
    # stacking (the scenario-fleet representation) keeps the structure
    stacked = stack_env_params([p, with_straggler(p, 0, 0.5)])
    assert stacked.speed.shape == (2, small_env.M)
    assert stacked.noise_sigma.shape == (2,)


def test_explicit_params_match_implicit_defaults(small_env):
    """reset/step/state_vector with params=default_params() must be
    bit-identical to the implicit-default calls (the compat contract)."""
    env = small_env
    p = env.default_params()
    key = jax.random.PRNGKey(0)
    s_a = env.reset(key)
    s_b = env.reset(key, p)
    for a, b in zip(s_a, s_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(env.state_vector(s_a)),
                                  np.asarray(env.state_vector(s_a, p)))
    out_a = env.step(key, s_a, s_a.X)
    out_b = env.step(key, s_a, s_a.X, p)
    np.testing.assert_array_equal(np.asarray(out_a.latency_ms),
                                  np.asarray(out_b.latency_ms))
    np.testing.assert_array_equal(np.asarray(env.evaluate(s_a.X, s_a.w)),
                                  np.asarray(env.evaluate(s_a.X, s_a.w,
                                                          params=p)))


def test_perturbation_helpers(small_env):
    env = small_env
    p = env.default_params()
    w = p.base_rates
    X = env.round_robin_assignment()
    base = float(env.evaluate(X, w, params=p))
    slow = float(env.evaluate(X, w, params=with_straggler(p, 0, 0.3)))
    assert slow > base
    p_svc = perturb_service(p, jax.random.PRNGKey(1), sigma=0.3)
    assert not np.allclose(np.asarray(p_svc.service_ms),
                           np.asarray(p.service_ms))
    p_rate = scale_rates(p, 1.5)
    np.testing.assert_allclose(np.asarray(p_rate.base_rates),
                               np.asarray(p.base_rates) * 1.5, rtol=1e-6)
    assert float(with_noise_sigma(p, 0.2).noise_sigma) == pytest.approx(0.2)


# --------------------------------------------------------------------------
# Functional runner vs the legacy Python oracles
# --------------------------------------------------------------------------
def test_agent_runner_reproduces_python_oracle_ddpg(small_env, ddpg_cfg):
    env, cfg = small_env, ddpg_cfg
    state = ddpg.init_state(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(7)
    _, h_py = run_online_ddpg_python(key, env, cfg, state, T=10,
                                     updates_per_epoch=2)
    agent = make_agent("ddpg", env, cfg=cfg)
    _, h_fn = run_online_agent(key, env, agent, state, T=10,
                               updates_per_epoch=2)
    np.testing.assert_allclose(h_fn.rewards, h_py.rewards,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(h_fn.moved, h_py.moved)
    np.testing.assert_array_equal(h_fn.final_assignment.argmax(-1),
                                  h_py.final_assignment.argmax(-1))


def test_agent_runner_reproduces_python_oracle_dqn(small_env):
    env = small_env
    cfg = DQNConfig(n_executors=env.N, n_machines=env.M,
                    state_dim=env.state_dim)
    state = dqn.init_state(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(5)
    _, h_py = run_online_dqn_python(key, env, cfg, state, T=10)
    agent = make_agent("dqn", env, cfg=cfg)
    _, h_fn = run_online_agent(key, env, agent, state, T=10)
    np.testing.assert_allclose(h_fn.rewards, h_py.rewards,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(h_fn.moved, h_py.moved)


# --------------------------------------------------------------------------
# Heterogeneous-scenario fleet: lane i == single run from params lane i
# --------------------------------------------------------------------------
def test_heterogeneous_fleet_matches_single_runs(small_env, ddpg_cfg):
    env, cfg = small_env, ddpg_cfg
    p = env.default_params()
    lanes = [
        p,                                            # nominal
        with_straggler(p, 2, 0.3),                    # one slow machine
        scale_rates(p, 1.4),                          # heavier workload
        with_noise_sigma(perturb_service(
            p, jax.random.PRNGKey(3), 0.2), 0.1),     # jittery + noisy
    ]
    params = stack_env_params(lanes)
    F, T = len(lanes), 8
    agent = make_agent("ddpg", env, cfg=cfg)
    states = ddpg.init_fleet(jax.random.PRNGKey(1), cfg, F)
    keys = jax.random.split(jax.random.PRNGKey(2), F)
    _, h_fleet = run_online_fleet(keys, env, agent, states, T=T,
                                  env_params=params)
    assert h_fleet.rewards.shape == (F, T)
    for i in range(F):
        st_i = jax.tree.map(lambda x, i=i: x[i], states)
        _, h_i = run_online_agent(keys[i], env, agent, st_i, T=T,
                                  env_params=lanes[i])
        np.testing.assert_array_equal(h_fleet.rewards[i], h_i.rewards)
        np.testing.assert_array_equal(h_fleet.latencies[i], h_i.latencies)
        np.testing.assert_array_equal(h_fleet.moved[i], h_i.moved)
        np.testing.assert_array_equal(h_fleet.final_assignment[i],
                                      h_i.final_assignment)
    # the scenarios really differ: straggler lane must be slower, heavier
    # workload lane must be slower than nominal
    assert h_fleet.latencies[1].mean() > h_fleet.latencies[0].mean()
    assert h_fleet.latencies[2].mean() > h_fleet.latencies[0].mean()


def test_params_in_axes_and_lane_params(small_env):
    """Per-leaf broadcast stacking: invariant leaves stay single-copy, the
    axes helper maps them to in_axes=None, and lane extraction reassembles
    a full single-scenario pytree."""
    env = small_env
    p = env.default_params()
    lanes = [with_straggler(p, i % env.M, 0.5 + 0.1 * i) for i in range(3)]
    full = stack_env_params(lanes)
    bc = stack_env_params(lanes, broadcast_invariant=True)
    # single-scenario params: nothing stacked
    assert params_in_axes(p, p) is None
    assert not params_stacked(p, p)
    # fully stacked: every leaf rides axis 0
    ax_full = params_in_axes(full, p)
    assert all(a == 0 for a in ax_full)
    # broadcast stack: only the perturbed leaf is stacked
    ax = params_in_axes(bc, p)
    assert ax.speed == 0
    assert ax.routing is None and ax.flow_solve is None
    assert bc.routing.shape == p.routing.shape          # single copy
    assert bc.speed.shape == (3, env.M)
    assert params_stacked(bc, p)
    # lane extraction works for both stack flavors and passes singles through
    for params in (full, bc):
        lp = lane_params(params, p, 1)
        np.testing.assert_array_equal(np.asarray(lp.speed),
                                      np.asarray(lanes[1].speed))
        np.testing.assert_array_equal(np.asarray(lp.routing),
                                      np.asarray(p.routing))
    np.testing.assert_array_equal(np.asarray(lane_params(p, p, 0).speed),
                                  np.asarray(p.speed))


def test_broadcast_invariant_fleet_matches_stacked(small_env, ddpg_cfg):
    """A broadcast-invariant scenario fleet must be numerically identical
    to the fully-stacked fleet — the per-leaf in_axes=None path only drops
    duplicated memory, never changes results."""
    env, cfg = small_env, ddpg_cfg
    F, T = 3, 6
    full = scenarios.build("one_slow_machine", env, F)
    bc = scenarios.build("one_slow_machine", env, F, broadcast_invariant=True)
    assert full.routing.ndim == 3 and bc.routing.ndim == 2
    agent = make_agent("ddpg", env, cfg=cfg)
    states = ddpg.init_fleet(jax.random.PRNGKey(0), cfg, F)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    _, h_full = run_online_fleet(keys, env, agent, states, T=T,
                                 env_params=full)
    _, h_bc = run_online_fleet(keys, env, agent, states, T=T, env_params=bc)
    # trajectory (actions taken) is identical; measured rewards may differ
    # in the last float32 ulp because XLA lowers a broadcast matmul and a
    # batched matmul differently
    np.testing.assert_array_equal(h_bc.moved, h_full.moved)
    np.testing.assert_array_equal(h_bc.final_assignment,
                                  h_full.final_assignment)
    np.testing.assert_allclose(h_bc.rewards, h_full.rewards,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h_bc.latencies, h_full.latencies,
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# Params-aware model-based baseline: every lane profiles ITS cluster
# --------------------------------------------------------------------------
def test_model_based_fleet_is_params_aware(small_env):
    """In a straggler-scenario fleet the model-based baseline must fit and
    search the lane's cluster: per-lane thetas differ, and lane i of the
    fleet bit-matches a single run configured with lane i's EnvParams."""
    env = small_env
    F, T = 3, 6
    params = scenarios.build("one_slow_machine", env, F, factor=0.3)
    agent = make_agent("model_based", env, fit_samples=60)
    key = jax.random.PRNGKey(0)
    states = agent.init_fleet(key, F, env_params=params, env=env)
    thetas = np.asarray(states)
    # the straggler sits on a different machine per lane, so each lane's
    # profiled model must differ
    assert not np.allclose(thetas[0], thetas[1])
    assert not np.allclose(thetas[1], thetas[2])
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    _, h_fleet = run_online_fleet(keys, env, agent, states, T=T,
                                  env_params=params)
    init_keys = jax.random.split(key, F)
    for i in range(F):
        lane_p = lane_params(params, env.default_params(), i)
        # single run configured with lane i's EnvParams and lane i's fitted
        # model: bit-matches fleet lane i.  (The fit itself is a vmapped
        # ill-conditioned ridge solve, so the lane state — not a re-fit —
        # is the single-run starting point.)
        st_i = jax.tree.map(lambda x, i=i: x[i], states)
        _, h_i = run_online_agent(keys[i], env, agent, st_i, T=T,
                                  env_params=lane_p)
        np.testing.assert_array_equal(h_fleet.rewards[i], h_i.rewards)
        np.testing.assert_array_equal(h_fleet.final_assignment[i],
                                      h_i.final_assignment)
        # and a from-scratch single fit under lane i's params yields a model
        # in the same regime (same search behavior on the lane's cluster)
        st_refit = agent.init(init_keys[i], lane_p)
        assert np.asarray(st_refit).shape == thetas[i].shape
        assert np.isfinite(np.asarray(st_refit)).all()


# --------------------------------------------------------------------------
# Placement-env scenario fleets (PlacementParams joins the fleet story)
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def placement_env():
    return ExpertPlacementEnv(num_experts=6, num_devices=3,
                              flops_per_token=1e9, bytes_per_token=1024,
                              tokens_per_step=4096)


def test_placement_scenarios_build(placement_env):
    env = placement_env
    from repro.core.placement import PLACEMENT_SCENARIOS
    for name in PLACEMENT_SCENARIOS:
        params = build_scenario(name, env, 4)
        assert params.base_load.shape[0] == 4, name
    slow = build_scenario("one_slow_device", env, 3, factor=0.5)
    sp = np.asarray(slow.speed)
    for i in range(3):
        assert sp[i, i % env.M] == pytest.approx(0.5)
    with pytest.raises(KeyError):
        build_scenario("nope", env, 2)
    # the generic dispatcher reaches both envs' scenario tables
    assert "one_slow_device" in scenarios.scenario_names(env)
    params = scenarios.build_for(env, "one_slow_device", 2)
    assert params.speed.shape == (2, env.M)


def test_placement_scenario_fleet_runs(placement_env):
    env = placement_env
    F, T = 3, 5
    params = build_scenario("one_slow_device", env, F,
                            broadcast_invariant=True)
    agent = make_agent("ddpg", env, k_nn=4)
    states = agent.init_fleet(jax.random.PRNGKey(0), F, env_params=params,
                              env=env)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    _, hist = run_online_fleet(keys, env, agent, states, T=T,
                               env_params=params)
    assert hist.rewards.shape == (F, T)
    assert np.isfinite(hist.rewards).all()
    # lanes straggle different devices → traces differ
    assert len({hist.latencies[i].tobytes() for i in range(F)}) == F


def test_named_scenarios_build_and_differ(small_env):
    env = small_env
    F = 4
    for name in scenarios.SCENARIOS:
        params = scenarios.build(name, env, F)
        assert params.base_rates.shape[0] == F, name
        assert params.speed.shape == (F, env.M), name
    slow = scenarios.build("one_slow_machine", env, F, factor=0.25)
    # lane i slows machine i
    sp = np.asarray(slow.speed)
    for i in range(F):
        assert sp[i, i % env.M] == pytest.approx(0.25)
    with pytest.raises(KeyError):
        scenarios.build("nope", env, F)


# --------------------------------------------------------------------------
# Registry: every agent runs end-to-end through the same fleet runner
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["ddpg", "dqn", "round_robin",
                                  "model_based", "stream_q", "stream_ac",
                                  "graph_policy"])
def test_registry_agent_runs_five_epochs(small_env, name):
    env = small_env
    overrides = {"model_based": {"fit_samples": 40},
                 "ddpg": {"k_nn": 4}}.get(name, {})
    agent = make_agent(name, env, **overrides)
    assert agent.name == name
    F = 2
    states = agent.init_fleet(jax.random.PRNGKey(0), F)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    _, hist = run_online_fleet(keys, env, agent, states, T=5)
    assert hist.rewards.shape == (F, 5)
    assert np.isfinite(hist.rewards).all()


def test_registry_lists_builtins_and_rejects_unknown(small_env):
    names = agent_names()
    for expected in ("ddpg", "dqn", "round_robin", "model_based",
                     "stream_q", "stream_ac", "graph_policy"):
        assert expected in names
    with pytest.raises(KeyError):
        make_agent("nope", small_env)


def test_registry_completeness_on_both_env_families(small_env):
    """EVERY registered name round-trips make_agent → init_fleet → one
    fused epoch step on each env family it declares — a future agent
    that breaks the fleet contract (or forgets to declare its family)
    fails here, not in a launcher.  Family declarations themselves are
    pinned: the learning/baseline agents run on both the DSDPS scheduling
    env and the TPU placement instantiation, model_based only speaks the
    queueing model, and the serving-only action-space policies declare
    no steppable family at all."""
    placement_env = ExpertPlacementEnv(
        num_experts=6, num_devices=3, flops_per_token=1e9,
        bytes_per_token=1024, tokens_per_step=4096)
    envs = {"scheduling": small_env, "placement": placement_env}
    overrides = {"model_based": {"fit_samples": 40}, "ddpg": {"k_nn": 4}}
    for name in agent_names():
        fams = agent_families(name)
        assert set(fams) <= set(envs), (name, fams)
        for fam in fams:
            env = envs[fam]
            agent = make_agent(name, env, **overrides.get(name, {}))
            F = 2
            states = agent.init_fleet(jax.random.PRNGKey(0), F)
            keys = jax.random.split(jax.random.PRNGKey(1), F)
            _, hist = run_online_fleet(keys, env, agent, states, T=1)
            assert hist.rewards.shape == (F, 1), (name, fam)
            assert np.isfinite(np.asarray(hist.rewards)).all(), (name, fam)
    for name in ("ddpg", "dqn", "round_robin", "stream_q", "stream_ac"):
        assert set(agent_families(name)) == {"scheduling", "placement"}
    assert agent_families("model_based") == ("scheduling",)
    # graph_policy message-passes over a topology DAG — scheduling only
    assert agent_families("graph_policy") == ("scheduling",)
    assert agent_families("rate_control") == ()
    assert agent_families("auto_tune") == ()
    with pytest.raises(KeyError):
        agent_families("nope")


def test_agents_with_equal_configs_are_equal(small_env, ddpg_cfg):
    """Agent bundles must be value-equal for jit's static-arg cache to
    replace the old id(env) runner cache."""
    a = make_agent("ddpg", small_env, cfg=ddpg_cfg)
    b = make_agent("ddpg", small_env, cfg=ddpg_cfg)
    assert a == b and hash(a) == hash(b)


def test_runner_cache_is_gone():
    assert not hasattr(agent_mod, "_RUNNER_CACHE")
    assert not hasattr(agent_mod, "_compiled_runner")


def test_deprecation_window_closed(small_env, ddpg_cfg):
    """PR-2's compatibility surface is retired: the bare-config wrappers
    are gone and the runners reject bare configs with a pointed error."""
    assert not hasattr(agent_mod, "run_online_ddpg")
    assert not hasattr(agent_mod, "run_online_dqn")
    assert not hasattr(agent_mod, "as_agent")
    env, cfg = small_env, ddpg_cfg
    states = ddpg.init_fleet(jax.random.PRNGKey(0), cfg, 2)
    keys = jax.random.split(jax.random.PRNGKey(1), 2)
    with pytest.raises(TypeError, match="make_agent"):
        run_online_fleet(keys, env, cfg, states, T=2)
    with pytest.raises(TypeError, match="make_agent"):
        run_online_agent(keys[0], env, cfg,
                         jax.tree.map(lambda x: x[0], states), T=2)


# --------------------------------------------------------------------------
# History.smoothed_rewards degrades gracefully without scipy
# --------------------------------------------------------------------------
def _noisy_history(T=120, fleet=3, seed=0):
    rng = np.random.default_rng(seed)
    r = np.cumsum(rng.normal(size=(fleet, T)), axis=-1)
    return History(rewards=r, latencies=-r, moved=np.zeros_like(r),
                   final_assignment=np.zeros((fleet, 4, 2)))


def test_smoothed_rewards_numpy_fallback(monkeypatch):
    hist = _noisy_history()
    with_scipy = hist.smoothed_rewards()
    monkeypatch.setitem(sys.modules, "scipy", None)
    monkeypatch.setitem(sys.modules, "scipy.signal", None)
    fallback = hist.smoothed_rewards()
    assert fallback.shape == with_scipy.shape
    assert np.isfinite(fallback).all()
    # it actually smooths: epoch-to-epoch wiggle shrinks vs the raw curve
    raw = hist.normalized_rewards()
    assert np.abs(np.diff(fallback, axis=-1)).mean() < \
        np.abs(np.diff(raw, axis=-1)).mean()
    # and stays close to the scipy filtfilt result
    assert np.abs(fallback - with_scipy).mean() < 0.1
    mean, std = hist.seed_band()
    assert mean.shape == (120,) and np.isfinite(std).all()


def test_smoothed_rewards_fallback_short_series(monkeypatch):
    monkeypatch.setitem(sys.modules, "scipy", None)
    monkeypatch.setitem(sys.modules, "scipy.signal", None)
    hist = _noisy_history(T=10)
    assert hist.smoothed_rewards().shape == (3, 10)
