"""End-to-end behaviour of the paper's system (replaces the placeholder).

These are the integration-level claims: the DRL control loop runs against
the simulated DSDPS, learns something, deploys with minimal deltas, and
the TPU placement instantiation responds to stragglers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DDPGConfig, ddpg_init, jamba_placement_env,
                        make_agent, run_online_agent)
from repro.core import ddpg
from repro.core.ddpg import offline_pretrain
from repro.core.exploration import EpsilonSchedule
from repro.core.spaces import hamming_moves, is_feasible
from repro.dsdps import SchedulingEnv, apps
from repro.dsdps.apps import default_workload


@pytest.fixture(scope="module")
def trained_small():
    topo = apps.continuous_queries("small")
    env = SchedulingEnv(topo, default_workload(topo))
    cfg = DDPGConfig(n_executors=env.N, n_machines=env.M,
                     state_dim=env.state_dim, k_nn=8,
                     eps=EpsilonSchedule(decay_epochs=150))
    state = ddpg_init(jax.random.PRNGKey(0), cfg)
    state = offline_pretrain(jax.random.PRNGKey(1), state, cfg, env,
                             n_samples=600, n_updates=200)
    state, hist = run_online_agent(jax.random.PRNGKey(2), env,
                                   make_agent("ddpg", env, cfg=cfg), state,
                                   T=150, updates_per_epoch=2)
    return env, cfg, state, hist


def test_online_learning_improves_over_start(trained_small):
    env, cfg, state, hist = trained_small
    w = env.workload.init()
    final = float(env.evaluate(jnp.asarray(hist.final_assignment), w))
    # must at least match round-robin-with-one-process and random schedules
    rr = float(env.evaluate(env.round_robin_assignment(), w))
    rand = np.mean([
        float(env.evaluate(env.random_assignment(jax.random.PRNGKey(i)), w))
        for i in range(10)])
    assert final <= rand, "trained agent worse than random assignments"
    assert final <= rr * 1.05, "trained agent much worse than round-robin"


def test_reward_trace_has_paper_normalization(trained_small):
    _, _, _, hist = trained_small
    r = hist.normalized_rewards()
    assert r.min() >= 0.0 and r.max() <= 1.0
    s = hist.smoothed_rewards()
    assert len(s) == len(r)


def test_actions_always_feasible(trained_small):
    env, cfg, state, _ = trained_small
    s = env.reset(jax.random.PRNGKey(9))
    for i in range(5):
        a = ddpg.select_action_jit(jax.random.PRNGKey(i), state, cfg,
                                   env.state_vector(s), explore=True)
        assert bool(is_feasible(a))


def test_minimal_delta_deployment(trained_small):
    """§3.1: only changed executors are re-assigned; consecutive greedy
    actions of a converged policy move (almost) nothing."""
    env, cfg, state, hist = trained_small
    s = env.reset(jax.random.PRNGKey(3))
    a1 = ddpg.select_action_jit(jax.random.PRNGKey(0), state, cfg,
                                env.state_vector(s), explore=False)
    out = env.step(jax.random.PRNGKey(1), s, a1)
    a2 = ddpg.select_action_jit(jax.random.PRNGKey(2), state, cfg,
                                env.state_vector(out.state), explore=False)
    assert int(hamming_moves(a1, a2)) <= env.N // 4


def test_placement_env_straggler_response():
    """TPU instantiation: a straggler device must raise the cost of
    schedules that keep load there, and moving its experts away helps."""
    env = jamba_placement_env()
    s = env.reset(jax.random.PRNGKey(0))
    X = env.round_robin_assignment()
    hot = int(jnp.argmax(s.w))            # most-loaded expert
    dev = int(jnp.argmax(X[hot]))
    t_ok = float(env.step_time_ms(X, s.w, s.speed))
    slow = s.speed.at[dev].set(0.25)
    t_slow = float(env.step_time_ms(X, s.w, slow))
    assert t_slow > t_ok
    # move the hot expert to the least-loaded device
    dev_tokens = (X * s.w[:, None]).sum(0)
    cold = int(jnp.argmin(dev_tokens + 1e12 * (jnp.arange(env.M) == dev)))
    moved = X.at[hot].set(jax.nn.one_hot(cold, env.M))
    assert float(env.step_time_ms(moved, s.w, slow)) < t_slow


def test_placement_env_prefers_balanced_load():
    env = jamba_placement_env()
    s = env.reset(jax.random.PRNGKey(0))
    balanced = env.round_robin_assignment()
    skewed = jnp.zeros_like(balanced).at[:, 0].set(1.0)   # all on device 0
    assert float(env.step_time_ms(balanced, s.w)) < \
        float(env.step_time_ms(skewed, s.w))


def test_workload_shift_reflected_in_state():
    """Fig 12 setup: after the +50% shift epoch the state's workload block
    changes, which is what lets the agent react."""
    from repro.dsdps.workload import WorkloadProcess
    wl = WorkloadProcess(base_rates=(100.0, 100.0), jitter=0.0, revert=1.0,
                         shift_epoch=5, shift_factor=1.5)
    w = wl.init()
    w_after = wl.step(jax.random.PRNGKey(0), w, jnp.asarray(5))
    assert float(w_after.mean()) > float(w.mean()) * 1.4
