"""Serving control plane (repro/serve/control.py) + the action-space
registry and decision policies behind it.

Acceptance gates pinned here: batched plane decisions bit-match
per-cluster single selects (explore=False) for a params-INSENSITIVE agent
(ddpg placement) and a params-SENSITIVE one (auto_tune — wrong cluster
gathering would flip its argmin); admission/eviction is strict FIFO under
a full slot pool; the latency percentiles are deterministic nearest-rank;
and steady-state serving over a fixed cluster registry compiles exactly
once."""
import jax
import numpy as np
import pytest

from repro.core import make_agent, spaces
from repro.dsdps import SchedulingEnv, apps, scenarios
from repro.dsdps.actions import RATE_LEVELS, TUNE_GRID
from repro.dsdps.apps import default_workload
from repro.serve.control import (ControlPlane, ControlService,
                                 DecisionRequest, latency_stats,
                                 nearest_rank_percentile,
                                 single_select_program)


@pytest.fixture(scope="module")
def env():
    topo = apps.continuous_queries("small")
    return SchedulingEnv(topo, default_workload(topo))


def _load(env, names, n, seed=0):
    """(rid, cluster, s_vec) synthetic request triples."""
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(n):
        X = np.eye(env.M, dtype=np.float32)[rng.integers(0, env.M, env.N)]
        w = np.exp(rng.normal(0.0, 0.25, env.workload.num_spouts))
        out.append((rid, names[rid % len(names)],
                    np.concatenate([X.reshape(-1), w.astype(np.float32)])))
    return out


def _plane(env, kind, agent_name, n_clusters=3, n_slots=3, seed=0, **kw):
    agent = make_agent(agent_name, env, **kw)
    plane = ControlPlane(env, agent, agent.init(jax.random.PRNGKey(seed)),
                         kind=kind, n_slots=n_slots, donate=False)
    key = jax.random.PRNGKey(seed + 1)
    for c in range(n_clusters):
        key, k = jax.random.split(key)
        plane.register_cluster(f"c{c}", scenarios.sample_perturbed(env, k))
    return plane


# --------------------------------------------------------------------------
# Action-space registry
# --------------------------------------------------------------------------
def test_action_space_registry(env):
    assert {"placement", "rate_control", "auto_tune"} \
        <= set(spaces.action_space_names())
    assert spaces.action_space("placement").shape_fn(env) == (env.N, env.M)
    assert spaces.action_space("placement").default_agent == "ddpg"
    assert spaces.action_space("rate_control").shape_fn(env) == \
        (env.workload.num_spouts, len(RATE_LEVELS))
    assert spaces.action_space("auto_tune").shape_fn(env) == (len(TUNE_GRID),)
    with pytest.raises(KeyError):
        spaces.action_space("no_such_space")


def test_decision_policies_feasible_one_hot(env):
    s_vec = env.state_vector(env.reset(jax.random.PRNGKey(0)))
    for name in ("rate_control", "auto_tune"):
        agent = make_agent(name, env)
        state = agent.init(jax.random.PRNGKey(1))
        action, _ = agent.select(jax.random.PRNGKey(2), state, s_vec, None,
                                 env.default_params(), explore=False)
        shape = spaces.action_space(name).shape_fn(env)
        assert action.shape == shape
        assert bool(spaces.action_space(name).feasible_fn(action))


# --------------------------------------------------------------------------
# Nearest-rank percentile math (fixed trace)
# --------------------------------------------------------------------------
def test_nearest_rank_percentile_fixed_trace():
    trace = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert nearest_rank_percentile(trace, 50.0) == 3.0
    assert nearest_rank_percentile(trace, 1.0) == 1.0
    assert nearest_rank_percentile(trace, 99.0) == 5.0
    assert nearest_rank_percentile(trace, 100.0) == 5.0
    # 10 samples: nearest rank = ceil(q/100 * n), no interpolation
    t10 = list(range(1, 11))
    assert nearest_rank_percentile(t10, 50.0) == 5
    assert nearest_rank_percentile(t10, 90.0) == 9
    assert nearest_rank_percentile(t10, 91.0) == 10
    with pytest.raises(ValueError):
        nearest_rank_percentile([], 50.0)


def test_latency_stats_schema():
    s = latency_stats([2.0, 1.0, 3.0])
    assert s["n"] == 3
    assert s["p50_ms"] == 2.0 and s["p99_ms"] == 3.0
    assert s["mean_ms"] == pytest.approx(2.0)


# --------------------------------------------------------------------------
# FIFO admission / eviction under a full slot pool
# --------------------------------------------------------------------------
def test_fifo_admission_under_full_slot_pool(env):
    plane = _plane(env, "rate_control", "rate_control", n_slots=2)
    load = _load(env, plane.clusters, 7)
    for rid, c, s in load:
        plane.submit(DecisionRequest(rid=rid, cluster=c, s_vec=s))
    assert plane.pending == 7

    key = jax.random.PRNGKey(3)
    batches = []
    while plane.pending:
        key, k = jax.random.split(key)
        batches.append([r.rid for r in plane.step(k)])
        # decisions are one-step: every served slot retires immediately
        assert plane.active == 0
    # strict FIFO admission, batch width = min(n_slots, backlog)
    assert batches == [[0, 1], [2, 3], [4, 5], [6]]
    assert [r.rid for r in plane._finished] == list(range(7))
    assert all(r.done and r.latency_ms > 0.0 for r in plane._finished)
    # queueing delay is billed: later requests waited through more steps
    lats = [r.latency_ms for r in plane._finished]
    assert lats[6] > lats[0]
    assert plane.decision_stats()["n"] == 7


def test_reset_stats_guards_in_flight(env):
    plane = _plane(env, "rate_control", "rate_control", n_slots=2)
    rid, c, s = _load(env, plane.clusters, 1)[0]
    plane.submit(DecisionRequest(rid=rid, cluster=c, s_vec=s))
    with pytest.raises(RuntimeError):
        plane.reset_stats()
    plane.run(jax.random.PRNGKey(0))
    plane.reset_stats()
    assert not plane._finished
    with pytest.raises(ValueError):
        plane.decision_stats()                   # empty trace again


# --------------------------------------------------------------------------
# Batched decisions bit-match per-cluster single selects
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kind,agent_name,kw", [
    ("placement", "ddpg", {"k_nn": 4}),
    ("auto_tune", "auto_tune", {}),   # params-sensitive: gathers matter
])
def test_batched_bitmatches_single_selects(env, kind, agent_name, kw):
    agent = make_agent(agent_name, env, **kw)
    state = agent.init(jax.random.PRNGKey(4))
    plane = ControlPlane(env, agent, state, kind=kind, n_slots=3,
                         donate=False)
    key = jax.random.PRNGKey(5)
    raw_params = {}
    for c in range(3):
        key, k = jax.random.split(key)
        raw_params[f"c{c}"] = scenarios.sample_perturbed(env, k)
        plane.register_cluster(f"c{c}", raw_params[f"c{c}"])
    load = _load(env, plane.clusters, 7)
    for rid, c, s in load:
        plane.submit(DecisionRequest(rid=rid, cluster=c, s_vec=s))
    done = {r.rid: r for r in plane.run(jax.random.PRNGKey(6))}
    assert len(done) == 7

    # explore=False decisions are key-independent: each batched action
    # must equal the single select on that cluster's RAW (unstacked)
    # params, bit for bit
    prog = single_select_program(agent, False)
    for rid, c, s in load:
        single = np.asarray(prog(jax.random.PRNGKey(7), state, s,
                                 raw_params[c]))
        np.testing.assert_array_equal(np.asarray(done[rid].action), single)
        assert bool(plane.space.feasible_fn(done[rid].action))


# --------------------------------------------------------------------------
# Steady-state compile discipline
# --------------------------------------------------------------------------
def test_steady_state_compiles_exactly_once(env):
    from repro.diagnostics import guards
    from repro.serve.control import batched_select_program

    # the program builder is lru_cached module-wide: earlier tests may
    # have compiled this (agent, axes) pair already — start truly cold
    batched_select_program.cache_clear()
    plane = _plane(env, "rate_control", "rate_control", n_slots=2)
    load = _load(env, plane.clusters, 9)
    k_cold, k_steady = jax.random.split(jax.random.PRNGKey(8))

    # cold: the FIRST dispatch compiles the batched program — exactly once
    with guards(track=(plane.program,), label="serve_cold") as g:
        for rid, c, s in load[:5]:
            plane.submit(DecisionRequest(rid=rid, cluster=c, s_vec=s))
        plane.run(k_cold)
    g.counter.assert_compiles(1)

    # steady state: a new request mix over the SAME cluster registry
    # (partial final batch included) reuses the executable
    with guards(track=(plane.program,), label="serve_steady") as g2:
        for rid, c, s in load[5:]:
            plane.submit(DecisionRequest(rid=100 + rid, cluster=c, s_vec=s))
        plane.run(k_steady)
    g2.counter.assert_compiles(0)
    assert len(plane._finished) == 9


# --------------------------------------------------------------------------
# Multi-kind service routing + error cases
# --------------------------------------------------------------------------
def test_service_routes_kinds_to_planes(env):
    kinds = ("placement", "rate_control", "auto_tune")
    planes = {}
    for kind in kinds:
        space = spaces.action_space(kind)
        kw = {"k_nn": 4} if space.default_agent == "ddpg" else {}
        agent = make_agent(space.default_agent, env, **kw)
        planes[kind] = ControlPlane(env, agent,
                                    agent.init(jax.random.PRNGKey(10)),
                                    kind=kind, n_slots=2, donate=False)
    svc = ControlService(planes)
    assert svc.kinds == tuple(sorted(kinds))
    svc.register_cluster("c0", env.default_params())
    svc.register_cluster("c1")
    load = _load(env, ("c0", "c1"), 6)
    for rid, c, s in load:
        svc.submit(DecisionRequest(rid=rid, cluster=c, s_vec=s,
                                   kind=kinds[rid % 3]))
    done = svc.run(jax.random.PRNGKey(11))
    assert len(done) == 6
    for r in done:
        shape = spaces.action_space(r.kind).shape_fn(env)
        assert np.asarray(r.action).shape == shape
    stats = svc.decision_stats()
    assert set(stats) == set(kinds)
    assert all(st["n"] == 2 for st in stats.values())


def test_error_cases(env):
    agent = make_agent("rate_control", env)
    state = agent.init(jax.random.PRNGKey(12))
    with pytest.raises(KeyError):
        ControlPlane(env, agent, state, kind="no_such_space")
    with pytest.raises(ValueError):
        ControlPlane(env, agent, state, kind="rate_control", n_slots=0)

    plane = ControlPlane(env, agent, state, kind="rate_control", n_slots=2)
    with pytest.raises(RuntimeError):        # no clusters registered
        plane.program
    plane.register_cluster("c0")
    with pytest.raises(ValueError):          # duplicate
        plane.register_cluster("c0")
    s = np.zeros(env.state_dim, np.float32)
    with pytest.raises(KeyError):            # unregistered cluster
        plane.submit(DecisionRequest(rid=0, cluster="ghost", s_vec=s))
    with pytest.raises(ValueError):          # kind mismatch
        plane.submit(DecisionRequest(rid=0, cluster="c0", s_vec=s,
                                     kind="placement"))

    with pytest.raises(ValueError):          # plane under the wrong key
        ControlService({"placement": plane})
    svc = ControlService({"rate_control": plane})
    with pytest.raises(ValueError):          # service needs kind=
        svc.submit(DecisionRequest(rid=0, cluster="c0", s_vec=s))
    with pytest.raises(KeyError):            # no plane for that kind
        svc.submit(DecisionRequest(rid=0, cluster="c0", s_vec=s,
                                   kind="auto_tune"))
