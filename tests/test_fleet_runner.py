"""Fleet-batched online runner (core/agent.py): the vmapped scan must be
indistinguishable from sequential single runs, and the scan-based single
runner must reproduce the legacy Python-loop trace."""
import jax
import numpy as np
import pytest

from repro.core import ddpg, dqn, make_agent
from repro.core.agent import (run_online_agent, run_online_ddpg_python,
                              run_online_dqn_python, run_online_fleet)
from repro.core.ddpg import DDPGConfig
from repro.core.dqn import DQNConfig
from repro.dsdps import SchedulingEnv, apps
from repro.dsdps.apps import default_workload


@pytest.fixture(scope="module")
def small_env():
    topo = apps.continuous_queries("small")
    return SchedulingEnv(topo, default_workload(topo))


@pytest.fixture(scope="module")
def ddpg_cfg(small_env):
    return DDPGConfig(n_executors=small_env.N, n_machines=small_env.M,
                      state_dim=small_env.state_dim, k_nn=4)


@pytest.fixture(scope="module")
def ddpg_agent(small_env, ddpg_cfg):
    return make_agent("ddpg", small_env, cfg=ddpg_cfg)


def test_fleet_bitmatches_sequential_singles(small_env, ddpg_cfg, ddpg_agent):
    """fleet=4 in one XLA program == four sequential single-env runs with
    the same per-lane keys and initial states, bit for bit."""
    env, cfg = small_env, ddpg_cfg
    F, T = 4, 10
    states = ddpg.init_fleet(jax.random.PRNGKey(3), cfg, F)
    keys = jax.random.split(jax.random.PRNGKey(11), F)

    _, h_fleet = run_online_fleet(keys, env, ddpg_agent, states, T=T,
                                  updates_per_epoch=1)
    assert h_fleet.fleet == F
    assert h_fleet.rewards.shape == (F, T)

    for i in range(F):
        st_i = jax.tree.map(lambda x, i=i: x[i], states)
        _, h_i = run_online_agent(keys[i], env, ddpg_agent, st_i, T=T,
                                  updates_per_epoch=1)
        np.testing.assert_array_equal(h_fleet.rewards[i], h_i.rewards)
        np.testing.assert_array_equal(h_fleet.latencies[i], h_i.latencies)
        np.testing.assert_array_equal(h_fleet.moved[i], h_i.moved)
        np.testing.assert_array_equal(h_fleet.final_assignment[i],
                                      h_i.final_assignment)
        lane = h_fleet.lane(i)
        np.testing.assert_array_equal(lane.rewards, h_i.rewards)


def test_scan_runner_reproduces_python_loop_ddpg(small_env, ddpg_cfg,
                                                 ddpg_agent):
    """Regression: the jitted scan runner follows the legacy Python loop's
    trace.  Fusing select/step/store/update into one XLA program changes
    float32 rounding at the last ulp, so exact equality is not guaranteed —
    but the trajectory (assignments, moves) and the traces must agree to
    float32 precision over a short horizon."""
    env, cfg = small_env, ddpg_cfg
    state = ddpg.init_state(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(7)
    _, h_py = run_online_ddpg_python(key, env, cfg, state, T=12,
                                     updates_per_epoch=2)
    _, h_sc = run_online_agent(key, env, ddpg_agent, state, T=12,
                               updates_per_epoch=2)
    np.testing.assert_allclose(h_sc.rewards, h_py.rewards,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h_sc.latencies, h_py.latencies,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(h_sc.moved, h_py.moved)
    np.testing.assert_array_equal(h_sc.final_assignment.argmax(-1),
                                  h_py.final_assignment.argmax(-1))


def test_scan_runner_reproduces_python_loop_dqn(small_env):
    env = small_env
    cfg = DQNConfig(n_executors=env.N, n_machines=env.M,
                    state_dim=env.state_dim)
    state = dqn.init_state(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(5)
    _, h_py = run_online_dqn_python(key, env, cfg, state, T=12)
    _, h_sc = run_online_agent(key, env, make_agent("dqn", env, cfg=cfg),
                               state, T=12)
    np.testing.assert_allclose(h_sc.rewards, h_py.rewards,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(h_sc.moved, h_py.moved)
    np.testing.assert_array_equal(h_sc.final_assignment.argmax(-1),
                                  h_py.final_assignment.argmax(-1))


def test_fleet_dqn_runs_and_stacks(small_env):
    env = small_env
    cfg = DQNConfig(n_executors=env.N, n_machines=env.M,
                    state_dim=env.state_dim)
    F, T = 3, 6
    states = dqn.init_fleet(jax.random.PRNGKey(1), cfg, F)
    keys = jax.random.split(jax.random.PRNGKey(2), F)
    states_out, hist = run_online_fleet(keys, env,
                                        make_agent("dqn", env, cfg=cfg),
                                        states, T=T)
    assert hist.rewards.shape == (F, T)
    assert hist.final_assignment.shape == (F, env.N, env.M)
    assert np.isfinite(hist.rewards).all()
    # lanes evolved independently: distinct final assignments or traces
    assert len({hist.rewards[i].tobytes() for i in range(F)}) == F


def test_fleet_straggler_scenarios(small_env, ddpg_cfg, ddpg_agent):
    """Per-lane straggler speed factors flow through reset_fleet into the
    scan carry: slowed lanes must measure higher latency."""
    env, cfg = small_env, ddpg_cfg
    F, T = 2, 5
    states = ddpg.init_fleet(jax.random.PRNGKey(4), cfg, F)
    keys = jax.random.split(jax.random.PRNGKey(5), F)
    speed = np.ones((F, env.M), np.float32)
    speed[1, 0] = 0.25                      # lane 1: machine 0 straggles
    env_states = env.reset_fleet(keys, speed_factors=speed)
    _, hist = run_online_fleet(keys, env, ddpg_agent, states, T=T,
                               env_states=env_states)
    assert hist.latencies[1].mean() > hist.latencies[0].mean()


def test_history_band_shapes(small_env, ddpg_cfg, ddpg_agent):
    env, cfg = small_env, ddpg_cfg
    F, T = 3, 20
    states = ddpg.init_fleet(jax.random.PRNGKey(8), cfg, F)
    keys = jax.random.split(jax.random.PRNGKey(9), F)
    _, hist = run_online_fleet(keys, env, ddpg_agent, states, T=T)
    norm = hist.normalized_rewards()
    assert norm.shape == (F, T)
    assert norm.min() >= 0.0 and norm.max() <= 1.0 + 1e-9
    mean, std = hist.seed_band()
    assert mean.shape == (T,) and std.shape == (T,)
    assert (std >= 0).all()
