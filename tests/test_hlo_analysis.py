"""Trip-count-corrected HLO analysis vs analytically-known programs."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.hlo_analysis import analyze, parse_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def _xla_cost(compiled) -> dict:
    """cost_analysis() returns a dict on new jaxlib, [dict] on older ones."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return cost


def test_scan_flops_counted_with_trip_count():
    W = jnp.ones((128, 128), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ W, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y.sum()

    res = analyze(_compile(f, jnp.ones((128, 128))).as_text())
    expected = 10 * 2 * 128 ** 3
    assert abs(res["flops"] - expected) / expected < 0.01


def test_nested_scan_flops():
    W = jnp.ones((64, 64), jnp.float32)

    def g(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ W, None
            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y.sum()

    res = analyze(_compile(g, jnp.ones((64, 64))).as_text())
    expected = 20 * 2 * 64 ** 3
    assert abs(res["flops"] - expected) / expected < 0.01


def test_xla_cost_analysis_undercounts_scans():
    """Documents WHY the corrected analyzer exists: XLA counts while bodies
    once."""
    W = jnp.ones((128, 128), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ W, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y.sum()

    compiled = _compile(f, jnp.ones((128, 128)))
    xla_flops = _xla_cost(compiled)["flops"]
    ours = analyze(compiled.as_text())["flops"]
    assert ours > 5 * xla_flops          # 10x trip count vs body-once


def test_unrolled_matches_xla():
    W = jnp.ones((64, 64), jnp.float32)

    def h(x):
        for _ in range(4):
            x = x @ W
        return x.sum()

    compiled = _compile(h, jnp.ones((64, 64)))
    ours = analyze(compiled.as_text())["flops"]
    xla = _xla_cost(compiled)["flops"]
    assert abs(ours - xla) / xla < 0.05


def test_parse_hlo_finds_entry():
    def f(x):
        return (x @ x).sum()

    comps, entry = parse_hlo(_compile(f, jnp.ones((32, 32))).as_text())
    assert entry is not None and entry in comps
