"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.knn_topk import row_top2_regret, row_top2_regret_ref
from repro.kernels.rwkv6_scan import wkv6, wkv6_ref

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# -- flash attention -----------------------------------------------------------
@pytest.mark.parametrize("S,H,Hkv,hd,causal,dtype", [
    (128, 4, 4, 64, True, jnp.float32),      # MHA causal
    (128, 4, 2, 64, True, jnp.float32),      # GQA 2:1
    (256, 8, 2, 32, True, jnp.float32),      # GQA 4:1, longer
    (128, 4, 1, 64, True, jnp.float32),      # MQA
    (128, 4, 2, 64, False, jnp.float32),     # bidirectional (encoder)
    (128, 4, 2, 64, True, jnp.bfloat16),     # bf16 inputs
])
def test_flash_attention_vs_ref(S, H, Hkv, hd, causal, dtype):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, q_blk=64, kv_blk=64)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOLS[dtype], rtol=TOLS[dtype])


def test_flash_attention_block_shape_invariance():
    B, S, H, Hkv, hd = 1, 256, 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    o1 = flash_attention(q, k, v, q_blk=64, kv_blk=64)
    o2 = flash_attention(q, k, v, q_blk=128, kv_blk=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-5, rtol=1e-5)


def test_jnp_chunked_attention_matches_ref():
    """models/attention.flash_attention (the XLA path used in the dry-run)
    against the same oracle."""
    from repro.models.attention import flash_attention as fa_jnp
    B, S, H, Hkv, hd = 2, 256, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    out = fa_jnp(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_matches_full():
    from repro.models.attention import decode_attention
    B, S, H, Hkv, hd = 2, 33, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q_all = jax.random.normal(ks[0], (B, S, H, hd))
    k_all = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v_all = jax.random.normal(ks[2], (B, S, Hkv, hd))
    full = attention_ref(q_all, k_all, v_all, causal=True)
    dec = decode_attention(q_all[:, -1:], k_all, v_all,
                           jnp.asarray(S, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-5, rtol=2e-5)


# -- rwkv6 ----------------------------------------------------------------------
@pytest.mark.parametrize("T,H,hd,chunk,dtype", [
    (64, 2, 16, 16, jnp.float32),
    (128, 3, 16, 32, jnp.float32),
    (96, 2, 8, 32, jnp.float32),       # T not a multiple of 64
    (64, 2, 16, 16, jnp.bfloat16),
])
def test_wkv6_vs_ref(T, H, hd, chunk, dtype):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    w = (jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, H, hd))) * 0.5
         + 0.45).astype(dtype)
    r = jax.random.normal(ks[1], (B, T, H, hd), dtype)
    k = jax.random.normal(ks[2], (B, T, H, hd), dtype)
    v = jax.random.normal(ks[3], (B, T, H, hd), dtype)
    u = (jax.random.normal(ks[4], (H, hd)) * 0.1).astype(dtype)
    out = wkv6(w, r, k, v, u, chunk=chunk)
    ref, _ = wkv6_ref(w, r, k, v, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=TOLS[jnp.float32 if dtype == jnp.float32
                                         else jnp.bfloat16] * 5, rtol=1e-2)


def test_wkv6_chunk_invariance():
    B, T, H, hd = 1, 64, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    w = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, H, hd))) * 0.5 + 0.45
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) for i in (1, 2, 3))
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    o1 = wkv6(w, r, k, v, u, chunk=16)
    o2 = wkv6(w, r, k, v, u, chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-5, rtol=1e-5)


# -- knn_topk --------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 60), st.integers(2, 16))
def test_knn_topk_vs_ref(seed, n, m):
    proto = jax.random.uniform(jax.random.PRNGKey(seed), (n, m))
    b, s, r = row_top2_regret(proto, row_blk=16)
    br, sr, rr = row_top2_regret_ref(proto)
    assert bool(jnp.all(b == br))
    assert bool(jnp.all(s == sr))
    np.testing.assert_allclose(np.asarray(r), np.asarray(rr),
                               atol=1e-6, rtol=1e-6)


def test_knn_topk_regret_nonnegative():
    proto = jax.random.uniform(jax.random.PRNGKey(1), (50, 10))
    _, _, r = row_top2_regret(proto)
    assert bool(jnp.all(r >= 0))
