"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm

B, S = 2, 32


def make_batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.bfloat16)
    if cfg.family == "vlm":
        P = cfg.frontend_positions
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, P, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    cfg = get_config(arch_id, smoke=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    batch = make_batch(cfg, key)
    loss_fn = jax.jit(lm.train_loss(cfg))
    loss, metrics = loss_fn(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch_id}: non-finite loss"
    assert float(loss) > 0
    grads = jax.jit(jax.grad(lambda p, b: lm.train_loss(cfg)(p, b)[0]))(
        params, batch)
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in gleaves), f"{arch_id}: non-finite grads"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_decode_step(arch_id):
    cfg = get_config(arch_id, smoke=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    enc_len = S if cfg.family == "encdec" else 0
    cache = lm.init_cache(cfg, batch=B, max_seq=S, enc_len=enc_len)
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
        cache = lm.prefill_encoder(cfg, params, cache, frames)
    step = jax.jit(lm.serve_step(cfg))
    tok = jax.random.randint(key, (B, 1), 1, cfg.vocab_size)
    logits, cache = step(params, cache, tok)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch_id}: non-finite logits"
    assert int(cache["len"]) == 1
    tok2 = (tok + 7) % cfg.vocab_size
    logits2, cache = step(params, cache, tok2)
    assert int(cache["len"]) == 2
    # decoding is stateful: a different token must change the logits
    assert not bool(jnp.allclose(logits, logits2))


def test_param_counts_match_scale():
    """Full configs should land near their advertised sizes."""
    expect = {
        "command-r-plus-104b": (104e9, 0.25),
        "llama3-8b": (8e9, 0.15),
        "qwen1.5-110b": (110e9, 0.15),
        "yi-34b": (34e9, 0.15),
        "jamba-1.5-large-398b": (398e9, 0.25),
        "granite-moe-3b-a800m": (3.3e9, 0.35),
        "qwen2-moe-a2.7b": (14.3e9, 0.35),   # 14.3B total / 2.7B active
        "rwkv6-7b": (7e9, 0.4),
        "phi-3-vision-4.2b": (4.2e9, 0.25),  # incl. the (stubbed) CLIP tower
        "seamless-m4t-medium": (1.2e9, 0.5),
    }
    for arch, (target, tol) in expect.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, \
            f"{arch}: {n / 1e9:.1f}B vs expected {target / 1e9:.0f}B"


def test_block_programs():
    jamba = get_config("jamba-1.5-large-398b")
    prog = jamba.block_program()
    assert len(prog) == 8
    assert sum(m == "attn" for m, _ in prog) == 1      # 1:7 attn:mamba
    assert sum(f == "moe" for _, f in prog) == 4       # MoE every 2nd layer
    rwkv = get_config("rwkv6-7b")
    assert all(m == "rwkv" for m, _ in rwkv.block_program())
    assert rwkv.sub_quadratic and jamba.sub_quadratic
    assert not get_config("llama3-8b").sub_quadratic
