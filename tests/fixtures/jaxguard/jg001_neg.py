"""JG001 negative: rebinding, loop-header splits, and exclusive branches
are all fine."""
import jax


def rebind(key):
    key, sub = jax.random.split(key)
    noise = jax.random.normal(sub, (3,))
    draw = jax.random.uniform(key, (3,))      # fine: `key` was rebound
    return noise, draw


def loop_header(key, n):
    outs = []
    for k in jax.random.split(key, n):        # splits once per call
        outs.append(jax.random.normal(k, (2,)))
    return outs


def exclusive_branches(key, flag):
    if flag:
        a, _ = jax.random.split(key, 2)
        return a
    c, _ = jax.random.split(key, 2)           # other branch returned already
    return c
