"""JG004 negative: hoisted constants, dynamic shapes, and trace-time
loops (unrolled once at trace time) are fine."""
import jax
import jax.numpy as jnp

HOISTED = jnp.ones((3, 3))


def hoisted_loop(xs):
    out = 0.0
    for x in xs:
        out = out + x * HOISTED               # constant built once
    return out


def dynamic_shape(xs, n):
    y = None
    for x in xs:
        y = jnp.zeros(n)                      # shape is data, not a literal
    return y


@jax.jit
def trace_time_loop(x):
    for _ in range(3):                        # unrolled during tracing
        x = x + jnp.ones((3,))
    return x
