"""JG007 negative: host conversions outside traced code, and static
config access inside it."""
import jax
import numpy as np


def host_side(x):
    return float(x) + np.asarray(x).sum()     # not traced: fine


@jax.jit
def static_config(x, cfg):
    scale = float(cfg.lr)                     # attribute access: static conf
    return x * scale
