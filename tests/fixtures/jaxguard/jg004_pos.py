"""JG004 positive: all-literal jnp constructors inside host loops — one
h2d transfer per iteration for a constant."""
import jax.numpy as jnp


def hot_loop(xs):
    out = 0.0
    for x in xs:
        out = out + x * jnp.ones((3, 3))      # JG004: hoist above the loop
    return out


def while_loop(n):
    acc = None
    while n > 0:
        acc = jnp.zeros(4)                    # JG004
        n -= 1
    return acc
