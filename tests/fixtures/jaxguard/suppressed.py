"""Suppression handling: line-level disables silence exactly the named
rule on that line."""
import jax


def aot_lowering(f, x):
    # deliberate per-call construction: the wrapper exists only to lower
    jitted = jax.jit(f)  # jaxguard: disable=JG002
    return jitted.lower(x)


def silence_everything(f, x):
    step = jax.jit(f)  # jaxguard: disable=all
    return step(x)


def not_suppressed(f, x):
    return jax.jit(f)(x)                      # JG002 still fires here
