"""JG005 negative: None defaults, immutable scalars, and field
factories."""
import dataclasses
from functools import partial


def fine(xs=None, n=3, name="x", fn=partial(print, "ok")):
    return xs if xs is not None else []


@dataclasses.dataclass
class Record:
    tags: list = dataclasses.field(default_factory=list)
    n: int = 0
    label: str = "lane"
