# jaxguard: disable-file=JG002
"""File-level suppression: every JG002 in this file is silenced."""
import jax


def per_call(f, x):
    return jax.jit(f)(x)


def another(f, x):
    step = jax.jit(f)
    return step(x)
