"""JG005 positive: shared mutable defaults in signatures and pytree
dataclass fields."""
import dataclasses

import numpy as np


class Options:
    pass


def mutable_literal(xs=[]):                   # JG005
    return xs


def shared_instance(opts=Options()):          # JG005: one instance forever
    return opts


@dataclasses.dataclass
class Record:
    tags: list = []                           # JG005: shared list
    buf: np.ndarray = np.zeros(3)             # JG005: shared array
