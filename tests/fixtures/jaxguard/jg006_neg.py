"""JG006 negative: rebinding the donated name, or copying what is needed
before the donating call."""
import jax
import numpy as np


def _step(state):
    return state


prog = jax.jit(_step, donate_argnums=(0,))


def rebound(state):
    state = prog(state)                       # donated name rebound: fine
    return state.sum()


def copied_first(state):
    norm = np.asarray(state).sum()            # read BEFORE donation: fine
    state = prog(state)
    return state, norm
