"""JG002 negative: module-level jits, @partial decorators, and
lru_cache'd builders are the sanctioned forms."""
import functools
from functools import partial

import jax


@jax.jit
def decorated(x):
    return x * 2


@partial(jax.jit, static_argnames=("n",))
def decorated_partial(x, n):
    return x * n


def _impl(x):
    return x + 1


module_level = jax.jit(_impl)


@functools.lru_cache(maxsize=None)
def builder(n):
    # once-per-config construction: the lru_cache IS the jit cache's owner
    return jax.jit(lambda x: x * n)
