"""JG006 positive: a buffer donated to a module-level jitted program is
read after the donating call."""
import jax


def _step(state):
    return state


prog = jax.jit(_step, donate_argnums=(0,))


def run(state):
    new_state = prog(state)
    norm = state.sum()                        # JG006: state may be aliased
    return new_state, norm
