"""JG003 negative: correct statics, including module-constant tuples and
tuple concatenation."""
import jax

_BASE = ("n",)
_STATICS = _BASE + ("flag",)


def step(state, n, flag):
    return state


by_const = jax.jit(step, static_argnames=_STATICS)
by_nums = jax.jit(step, static_argnums=(1, 2))
by_literal = jax.jit(step, static_argnames=("n",))
