"""JG001 positive: key reused after split; split in a loop, key never
rebound."""
import jax


def reuse_after_split(key):
    keys = jax.random.split(key, 4)
    noise = jax.random.normal(key, (3,))      # JG001: `key` already consumed
    return keys, noise


def split_in_loop(key, xs):
    out = []
    for x in xs:
        ks = jax.random.split(key, 2)         # JG001: same streams each pass
        out.append(ks)
    return out
