"""JG003 positive: static declarations that silently miss or cannot
hash."""
import jax


def step(state, n):
    return state


wrong_name = jax.jit(step, static_argnames=("m",))       # JG003: no param m
out_of_range = jax.jit(step, static_argnums=(5,))        # JG003: 2 params


def run(state, opts=[1, 2]):
    return state


unhashable_static = jax.jit(run, static_argnames=("opts",))   # JG003
