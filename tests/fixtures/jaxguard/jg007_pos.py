"""JG007 positive: host syncs on traced values inside jitted paths."""
import jax
import numpy as np


@jax.jit
def jitted(x):
    if float(x[0]) > 0:                       # JG007: concretizes the tracer
        x = x + 1
    y = np.asarray(x)                         # JG007: host pull while traced
    z = x.item()                              # JG007: forced d2h sync
    return y, z


def scan_body_traced(xs):
    def body(carry, x):
        return carry + int(x), None           # JG007: body is scan-traced
    total, _ = jax.lax.scan(body, 0, xs)
    return total
