"""JG002 positive: per-call jit construction, jitted def in a function
body, jit and vmap built inside loops."""
import jax


def per_call(f, x):
    step = jax.jit(f)                         # JG002: fresh cache per call
    return step(x)


def nested_jitted_def(x):
    @jax.jit
    def inner(y):                             # JG002: decorator runs per call
        return y + 1
    return inner(x)


def jit_in_loop(f, xs):
    outs = []
    for x in xs:
        g = jax.jit(f)                        # JG002: re-jit per iteration
        outs.append(g(x))
    return outs


def vmap_in_loop(f, xs):
    h = None
    for x in xs:
        h = jax.vmap(f)                       # JG002: vmap has no cache
    return h
