"""Property-based ring-buffer semantics for core/replay.py.

The replay buffer is the thing the streaming agents delete, so its
semantics are pinned here as properties rather than examples: after any
number of ``replay_add`` calls the buffer holds exactly the newest
``min(n, capacity)`` transitions (wraparound overwrites oldest-first),
the write pointer is ``n mod capacity``, and ``replay_sample`` only ever
returns indices inside the filled prefix — including the degenerate
cases ``batch > size`` (sampling with replacement over what exists) and
sampling an EMPTY buffer (index 0 against the zero-filled slot, never
out of bounds)."""
import jax
import jax.numpy as jnp
import numpy as np

from hypothesis_compat import given, settings, st

from repro.core.replay import replay_add, replay_init, replay_sample


def _fill(capacity: int, n: int, state_dim: int = 3):
    """Add transitions tagged 1..n (state leaf constant at the tag)."""
    buf = replay_init(capacity, state_dim, 1)
    for t in range(1, n + 1):
        buf = replay_add(buf,
                         jnp.full((state_dim,), float(t)),
                         jnp.asarray([float(t)]),
                         jnp.asarray(float(t)),
                         jnp.full((state_dim,), float(-t)))
    return buf


@settings(max_examples=40, deadline=None)
@given(capacity=st.integers(min_value=1, max_value=12),
       n=st.integers(min_value=0, max_value=30))
def test_add_wraparound_keeps_newest_min_n_cap(capacity, n):
    buf = _fill(capacity, n)
    assert int(buf.size) == min(n, capacity)
    assert int(buf.ptr) == n % capacity
    stored = set(np.asarray(buf.rewards[: int(buf.size)]).tolist())
    newest = set(float(t) for t in range(max(1, n - capacity + 1), n + 1))
    assert stored == newest
    # slots beyond the filled prefix are still the zero init
    assert (np.asarray(buf.rewards[int(buf.size):]) == 0.0).all()
    # all four leaves wrap in lockstep: the tag agrees across leaves
    for i in range(int(buf.size)):
        tag = float(buf.rewards[i])
        assert float(buf.states[i, 0]) == tag
        assert float(buf.actions[i, 0]) == tag
        assert float(buf.next_states[i, 0]) == -tag


@settings(max_examples=40, deadline=None)
@given(capacity=st.integers(min_value=1, max_value=12),
       n=st.integers(min_value=0, max_value=30),
       batch=st.integers(min_value=1, max_value=64),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_sample_indices_stay_inside_filled_prefix(capacity, n, batch, seed):
    """Even when ``batch`` exceeds the filled entries, every sampled row
    must come from the filled prefix (with replacement) — and an empty
    buffer samples the zero-filled slot 0, never uninitialized garbage."""
    buf = _fill(capacity, n)
    s, a, r, s_next = replay_sample(jax.random.PRNGKey(seed), buf, batch)
    assert s.shape == (batch, 3) and r.shape == (batch,)
    if n == 0:
        assert (np.asarray(r) == 0.0).all()
        return
    valid = set(np.asarray(buf.rewards[: int(buf.size)]).tolist())
    for tag in np.asarray(r).tolist():
        assert tag in valid
    # leaves sampled at the same index stay consistent
    np.testing.assert_array_equal(np.asarray(s[:, 0]), np.asarray(r))
    np.testing.assert_array_equal(np.asarray(s_next[:, 0]),
                                  -np.asarray(r))


@settings(max_examples=20, deadline=None)
@given(capacity=st.integers(min_value=2, max_value=8),
       extra=st.integers(min_value=1, max_value=20))
def test_overwritten_transitions_never_resurface(capacity, extra):
    """After wrapping, a large sample must never contain an overwritten
    tag — the off-by-one this guards: ptr advancing before vs after the
    slot write."""
    n = capacity + extra
    buf = _fill(capacity, n)
    _, _, r, _ = replay_sample(jax.random.PRNGKey(0), buf, 256)
    overwritten = set(float(t) for t in range(1, n - capacity + 1))
    assert not (set(np.asarray(r).tolist()) & overwritten)
