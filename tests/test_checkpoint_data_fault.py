"""Checkpointing (sync/async/atomic/integrity/elastic), data-pipeline
determinism, heartbeat + straggler + elastic-mesh planning."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import AsyncCheckpointer, Checkpointer
from repro.data.pipeline import DataConfig, PrefetchIterator, batch_at, \
    pack_sequences
from repro.fault.elastic import plan_mesh
from repro.fault.heartbeat import HeartbeatMonitor
from repro.fault.straggler import StragglerDetector


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros(8, jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    st = _state()
    ck.save(7, st)
    restored = ck.restore(st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keeps_latest_k(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(s))
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_detects_corruption(tmp_path):
    ck = Checkpointer(tmp_path)
    path = ck.save(1, _state())
    leaf = next(path.glob("leaf_*.npy"))
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0xFF
    leaf.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        ck.restore(_state())


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    st = _state()
    ck.save_async(5, st)
    ck.save_async(10, st)
    ck.wait()
    assert ck.all_steps() == [5, 10]
    restored = ck.restore(st, step=10)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
    ck.close()


def test_checkpoint_atomicity(tmp_path):
    """A stale .tmp dir from a crashed writer must not shadow real ckpts."""
    ck = Checkpointer(tmp_path)
    (tmp_path / ".tmp_step_00000009").mkdir()
    ck.save(3, _state())
    assert ck.latest_step() == 3


def test_elastic_restore_to_new_topology(tmp_path):
    """Restore places leaves with explicit shardings (single device here,
    but exercises the code path used after re-meshing)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    ck = Checkpointer(tmp_path)
    st = _state()
    ck.save(1, st)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
    restored = ck.restore(st, shardings=sh)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())


# -- data pipeline ---------------------------------------------------------------
def test_data_deterministic_replay():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4)
    b1 = batch_at(cfg, 12)
    b2 = batch_at(cfg, 12)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = batch_at(cfg, 13)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_data_host_sharding_disjoint():
    a = batch_at(DataConfig(1000, 16, 8, num_hosts=2, host_id=0), 5)
    b = batch_at(DataConfig(1000, 16, 8, num_hosts=2, host_id=1), 5)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(b["tokens"]))


def test_prefetch_iterator_matches_direct():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    it = PrefetchIterator(cfg, start_step=3)
    got = [next(it) for _ in range(3)]
    it.close()
    for i, b in enumerate(got):
        np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                      np.asarray(batch_at(cfg, 3 + i)["tokens"]))


def test_pack_sequences():
    docs = [np.arange(1, 6, dtype=np.int32), np.arange(10, 13, dtype=np.int32)]
    out = pack_sequences(docs, seq_len=4)
    assert out.shape == (2, 4)
    np.testing.assert_array_equal(out[0], [1, 2, 3, 4])
    np.testing.assert_array_equal(out[1], [5, 10, 11, 12])


# -- fault tolerance ---------------------------------------------------------------
def test_heartbeat_detection():
    t = [0.0]
    mon = HeartbeatMonitor(4, timeout_s=5.0, clock=lambda: t[0])
    t[0] = 3.0
    for w in (0, 1, 2):
        mon.beat(w)
    t[0] = 7.0
    assert mon.dead_workers() == {3}
    assert mon.newly_dead() == {3}
    assert mon.newly_dead() == set()          # reported once
    assert mon.alive == [0, 1, 2]
    mon.beat(3)
    assert mon.dead_workers() == set()


def test_straggler_detector():
    det = StragglerDetector(4)
    for step in range(10):
        for w in range(4):
            det.observe(w, 1.0 if w != 2 else 2.5)
    assert det.stragglers() == [2]
    f = det.speed_factors()
    assert f[2] < 0.6 and abs(f[0] - 1.0) < 0.1


def test_elastic_mesh_planning():
    assert plan_mesh(512, 16, multi_pod=True).shape == (2, 16, 16)
    assert plan_mesh(496, 16).shape == (31, 16)     # lost a host: dp shrinks
    assert plan_mesh(256, 16).shape == (16, 16)
    p = plan_mesh(8, 16)                            # fewer chips than TP
    assert p.device_count <= 8
