"""Replay-free streaming agents: Stream Q(λ)/AC(λ) (arXiv 2410.14606).

The acceptance contract of the streaming lanes:

  * the building blocks are exact — sparse init zeroes precisely the
    configured fraction per output unit, the Welford normalizer matches
    numpy statistics, ObGD is bounded and a consumed TD error is a bit-
    exact no-op (so ``updates_per_epoch > 1`` cannot double-apply);
  * fleet lane *i* of a heterogeneous streaming fleet bit-matches a
    single streaming run built from params lane *i*;
  * the sharded fleet program compiles exactly once for a heterogeneous
    4-lane fleet (and zero times warm) with traces in the carry;
  * ``maybe_check_finite`` passes at chunk boundaries — trace carries
    stay finite under ObGD;
  * trace carries checkpoint/restore bit-neutrally through
    ``FleetCheckpoint`` (kill + resume == uninterrupted);
  * the headline parity pin: stream_q/stream_ac reach ≥95% of the
    DQN/DDPG final smoothed reward on the cq_small paper workload, with
    a replay-free carry ≥50× smaller per lane;
  * ``fleet_bench --streaming`` rows report zero replay bytes and carry
    the agent kind in their provenance blocks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.fleet import FleetCheckpoint
from repro.core import agent as agent_mod
from repro.core import make_agent, run_online_agent, run_online_fleet
from repro.core import networks as nets
from repro.core.agent import reset_fleet_states
from repro.core.streaming import (norm_apply, norm_init, norm_update,
                                  obgd_step, trace_zeros_like)
from repro.diagnostics import guards
from repro.dsdps import (SchedulingEnv, apps, scenarios, stack_env_params,
                         with_straggler, scale_rates)
from repro.dsdps.apps import default_workload
from repro.launch.mesh import make_host_mesh

STREAM_NAMES = ("stream_q", "stream_ac")


@pytest.fixture(scope="module")
def small_env():
    topo = apps.continuous_queries("small")
    return SchedulingEnv(topo, default_workload(topo))


def _fleet(env, agent, F, seed=0):
    states = agent.init_fleet(jax.random.PRNGKey(seed), F)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), F)
    return keys, states


def _trees_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------
def test_sparse_init_zero_fraction_and_scale():
    sizes = (202, 8, 8, 5)
    sparsity = 0.5
    p = nets.sparse_init(jax.random.PRNGKey(0), sizes, sparsity=sparsity)
    for w, (din, _dout) in zip(p.weights, zip(sizes[:-1], sizes[1:])):
        zeros_per_unit = (np.asarray(w) == 0.0).sum(axis=0)
        # exactly round(sparsity * fan_in) zeros in every output unit
        # (a continuous-uniform draw is never exactly zero on its own)
        assert (zeros_per_unit == round(sparsity * din)).all()
        assert np.abs(np.asarray(w)).max() <= 1.0 / np.sqrt(din)
    for b in p.biases:
        assert (np.asarray(b) == 0.0).all()
    with pytest.raises(ValueError):
        nets.sparse_init(jax.random.PRNGKey(0), sizes, sparsity=1.0)


def test_welford_normalizer_matches_numpy():
    rng = np.random.default_rng(0)
    xs = rng.normal(3.0, 2.5, size=(50, 7)).astype(np.float32)
    norm = norm_init(7)
    for x in xs:
        norm = norm_update(norm, jnp.asarray(x))
    assert float(norm.count) == 50
    np.testing.assert_allclose(np.asarray(norm.mean), xs.mean(axis=0),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(norm.m2) / 50, xs.var(axis=0),
                               rtol=1e-4)
    z = np.asarray(norm_apply(norm, jnp.asarray(xs[0])))
    expect = (xs[0] - xs.mean(axis=0)) / np.sqrt(xs.var(axis=0) + 1e-8)
    np.testing.assert_allclose(z, np.clip(expect, -10, 10), rtol=1e-3,
                               atol=1e-5)


def test_obgd_zero_delta_is_bit_exact_noop_and_step_is_bounded():
    p = nets.init_mlp(jax.random.PRNGKey(0), (6, 4, 3))
    z = jax.tree.map(lambda x: jnp.ones_like(x) * 2.0, trace_zeros_like(p))
    same = obgd_step(p, z, jnp.zeros(()), lr=1.0, kappa=2.0)
    _trees_equal(p, same)
    # a huge TD error cannot move the params past the overshoot bound:
    # total movement α_eff·|δ|·‖z‖₁ ≤ 1/κ once the bound engages
    kappa = 2.0
    moved = obgd_step(p, z, jnp.asarray(1e6), lr=1.0, kappa=kappa)
    total = sum(float(jnp.abs(m - q).sum())
                for m, q in zip(jax.tree_util.tree_leaves(moved),
                                jax.tree_util.tree_leaves(p)))
    assert total <= 1.0 / kappa + 1e-5


@pytest.mark.parametrize("name", STREAM_NAMES)
def test_update_applies_each_transition_exactly_once(small_env, name):
    """update consumes the pending TD error, so updates_per_epoch=3 must
    bit-match updates_per_epoch=1 — the fused epoch body's update loop
    cannot triple-apply a streaming TD step."""
    env = small_env
    agent = make_agent(name, env)
    keys, states = _fleet(env, agent, 2)
    s1, h1 = run_online_fleet(keys, env, agent, states, T=4,
                              updates_per_epoch=1)
    s3, h3 = run_online_fleet(keys, env, agent, states, T=4,
                              updates_per_epoch=3)
    np.testing.assert_array_equal(h1.rewards, h3.rewards)
    _trees_equal(s1, s3)


def test_streaming_carry_is_replay_free(small_env):
    for name in STREAM_NAMES:
        agent = make_agent(name, small_env)
        state = agent.init(jax.random.PRNGKey(0))
        assert not hasattr(state, "replay")
        assert not hasattr(state, "target")
        assert not hasattr(state, "opt")


# --------------------------------------------------------------------------
# Fleet-stack invariants
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", STREAM_NAMES)
def test_heterogeneous_fleet_lane_bitmatches_single_run(small_env, name):
    env = small_env
    p = env.default_params()
    lanes = [p, with_straggler(p, 2, 0.3), scale_rates(p, 1.4),
             with_straggler(p, 0, 0.6)]
    params = stack_env_params(lanes)
    F, T = len(lanes), 8
    agent = make_agent(name, env)
    states = agent.init_fleet(jax.random.PRNGKey(1), F,
                              env_params=params, env=env)
    keys = jax.random.split(jax.random.PRNGKey(2), F)
    _, h_fleet = run_online_fleet(keys, env, agent, states, T=T,
                                  env_params=params)
    assert h_fleet.rewards.shape == (F, T)
    for i in range(F):
        st_i = jax.tree.map(lambda x, i=i: x[i], states)
        _, h_i = run_online_agent(keys[i], env, agent, st_i, T=T,
                                  env_params=lanes[i])
        np.testing.assert_array_equal(h_fleet.rewards[i], h_i.rewards)
        np.testing.assert_array_equal(h_fleet.latencies[i], h_i.latencies)
        np.testing.assert_array_equal(h_fleet.final_assignment[i],
                                      h_i.final_assignment)


@pytest.mark.parametrize("name", STREAM_NAMES)
def test_sharded_streaming_fleet_compiles_exactly_once(small_env, name):
    """Heterogeneous 4-lane streaming fleet on the host mesh: one
    compilation cold, zero warm — traces in the carry don't break the
    one-XLA-program contract."""
    env = small_env
    F = 4
    env_params = scenarios.build_for(env, "mixed", F)
    mesh = make_host_mesh()
    agent = make_agent(name, env)
    keys, states = _fleet(env, agent, F)
    with guards(track=(agent_mod._fleet_program_sharded,)) as g:
        _, hist = run_online_fleet(keys, env, agent, states, T=3,
                                   env_params=env_params, mesh=mesh)
    assert hist.rewards.shape == (F, 3)
    g.counter.assert_compiles(1)
    with guards(track=(agent_mod._fleet_program_sharded,)) as g2:
        run_online_fleet(keys, env, agent, states, T=3,
                         env_params=env_params, mesh=mesh)
    g2.counter.assert_compiles(0)


@pytest.mark.parametrize("name", STREAM_NAMES)
def test_finite_guard_passes_at_chunk_boundaries(small_env, name):
    """Chunked runs sweep (states, rewards) through maybe_check_finite at
    every chunk boundary; ObGD keeps traces/params finite so the guarded
    run completes — and the final carry really is finite everywhere."""
    env = small_env
    agent = make_agent(name, env)
    keys, states = _fleet(env, agent, 3)

    class Cadence:                        # checkpoint stub: cadence only
        every = 3

        def save(self, *a, **k):
            pass

    with guards(nan_check=True):
        states, _ = run_online_fleet(keys, env, agent, states, T=7,
                                     checkpoint=Cadence())
    for leaf in jax.tree_util.tree_leaves(states):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("name", STREAM_NAMES)
def test_trace_carry_checkpoints_bit_neutrally(tmp_path, small_env, name):
    """Kill + FleetCheckpoint resume == uninterrupted, down to the last
    trace/normalizer bit (the carry is a plain pytree of arrays, so the
    checkpoint machinery needs no special cases)."""
    env = small_env
    agent = make_agent(name, env)
    keys, states = _fleet(env, agent, 2)
    T, every, crash = 6, 2, 4

    ck_a = FleetCheckpoint(tmp_path / "a", every=every, use_async=False)
    s_ref, h_ref = run_online_fleet(keys, env, agent, states, T=T,
                                    checkpoint=ck_a)

    ck_b = FleetCheckpoint(tmp_path / "b", every=every, use_async=False)
    run_online_fleet(keys, env, agent, states, T=crash, checkpoint=ck_b)

    ck_b2 = FleetCheckpoint(tmp_path / "b", every=every, use_async=False)
    like_env = reset_fleet_states(keys, env)
    epoch, res_states, env_states, res_keys = ck_b2.restore(
        states, like_env, keys)
    assert epoch == crash
    s_res, h_res = run_online_fleet(res_keys, env, agent, res_states,
                                    T=T - epoch, env_states=env_states,
                                    checkpoint=ck_b2, start_epoch=epoch)
    np.testing.assert_array_equal(h_res.rewards, h_ref.rewards[:, epoch:])
    _trees_equal(s_res, s_ref)


# --------------------------------------------------------------------------
# The headline pins: reward parity + the replay-free memory shrink
# --------------------------------------------------------------------------
def test_streaming_parity_and_memory_vs_replay_agents(small_env):
    """stream_q/stream_ac reach ≥95% of the DQN/DDPG final smoothed
    (per-lane min-max-normalized, filtfilt) reward on cq_small, from a
    per-lane carry ≥50× smaller.  Seeds are pinned; the thresholds held
    with margin across seed sweeps when the defaults were chosen."""
    env = small_env
    F, T, k = 4, 300, 20

    def final_and_bytes(name):
        agent = make_agent(name, env)
        states = agent.init_fleet(jax.random.PRNGKey(0), F)
        keys = jax.random.split(jax.random.PRNGKey(1), F)
        states, hist = run_online_fleet(keys, env, agent, states, T=T)
        nbytes = sum(x.size * x.dtype.itemsize
                     for x in jax.tree_util.tree_leaves(states)) // F
        return float(hist.smoothed_rewards()[:, -k:].mean()), nbytes

    for replay_name, stream_name in (("dqn", "stream_q"),
                                     ("ddpg", "stream_ac")):
        base, base_bytes = final_and_bytes(replay_name)
        stream, stream_bytes = final_and_bytes(stream_name)
        assert stream >= 0.95 * base, (
            f"{stream_name} final smoothed {stream:.4f} < 95% of "
            f"{replay_name}'s {base:.4f}")
        assert stream_bytes * 50 <= base_bytes, (
            f"{stream_name} carry {stream_bytes}B not ≥50× below "
            f"{replay_name}'s {base_bytes}B")


# --------------------------------------------------------------------------
# fleet_bench --streaming rows
# --------------------------------------------------------------------------
def test_fleet_bench_streaming_rows():
    from benchmarks.fleet_bench import run_streaming
    rows = run_streaming(fleet=2, epochs=8)
    by_name = {r[0]: r for r in rows}
    assert len(rows) == 6
    for stream_name, replay_name in (("stream_q", "dqn"),
                                     ("stream_ac", "ddpg")):
        mem = by_name[
            f"fleet_bench_cq_small_streaming_memory_{stream_name}_f2"]
        derived = dict(kv.split("=") for kv in mem[2].split(";"))
        assert derived["replay_bytes_per_lane"] == "0"
        assert int(derived["trace_bytes_per_lane"]) > 0
        assert int(derived["carry_bytes_per_lane"]) * 50 <= int(
            derived[f"{replay_name}_carry_bytes_per_lane"])
        # provenance carries the agent kind on every streaming row
        for row in rows:
            if stream_name in row[0]:
                assert row[3]["agent"] == stream_name
        width = by_name[
            f"fleet_bench_cq_small_fleet_width_ceiling_{stream_name}"]
        wd = dict(kv.split("=") for kv in width[2].split(";"))
        assert (int(wd[f"max_fleet_width_{stream_name}"])
                > int(wd[f"max_fleet_width_{replay_name}"]))
