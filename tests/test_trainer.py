"""Training substrate: optimizer math, grad-accum equivalence, loss
decrease, int8 EF compression properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.train import optimizer as opt_lib
from repro.train.compression import (compression_error, ef_compress_grads,
                                     quantize_int8)
from repro.train.trainer import TrainSetup, init_train_state, make_train_step


def test_adamw_converges_on_quadratic():
    opt = opt_lib.adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, state = opt.update(grads, state, params)
        params = opt_lib.apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
    total = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert total == pytest.approx(1.0, rel=1e-4)


def test_warmup_cosine_schedule():
    sched = opt_lib.warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)


def test_train_loss_decreases():
    cfg = get_config("llama3-8b", smoke=True)
    setup = TrainSetup(micro_batches=2, learning_rate=1e-2, warmup_steps=2,
                       total_steps=30, clip_norm=1.0)
    state = init_train_state(cfg, setup, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, setup))
    # one fixed batch -> loss must drop markedly (memorization)
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
    }
    first = None
    for i in range(25):
        state, m = step(state, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first * 0.7, (first, float(m["loss"]))
    assert int(state.step) == 25


def test_grad_accum_equivalence():
    """micro_batches=1 vs 4 must produce (near-)identical updates."""
    cfg = get_config("llama3-8b", smoke=True)
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
    }
    outs = []
    for micro in (1, 4):
        setup = TrainSetup(micro_batches=micro, learning_rate=1e-3,
                           warmup_steps=0, total_steps=10)
        state = init_train_state(cfg, setup, jax.random.PRNGKey(42))
        step = jax.jit(make_train_step(cfg, setup))
        state, m = step(state, batch)
        outs.append((float(m["loss"]),
                     np.asarray(jax.tree.leaves(state.params)[0],
                                np.float32)))
    # microbatch means are averaged identically; bf16 params leave tiny noise
    assert outs[0][0] == pytest.approx(outs[1][0], rel=2e-2)
    np.testing.assert_allclose(outs[0][1], outs[1][1], atol=2e-2)


def test_compressed_training_still_learns():
    cfg = get_config("llama3-8b", smoke=True)
    setup = TrainSetup(micro_batches=1, learning_rate=1e-2, warmup_steps=1,
                       total_steps=30, compress_grads=True)
    state = init_train_state(cfg, setup, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, setup))
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
    }
    first = None
    for _ in range(20):
        state, m = step(state, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first * 0.85


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_int8_roundtrip_error_bounded(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * \
        (10.0 ** jax.random.randint(jax.random.PRNGKey(seed + 1), (), -3, 3))
    err = float(compression_error(g))
    assert err < 0.01          # int8 symmetric: ~0.4% typical, <1% worst


def test_error_feedback_accumulates_residual():
    g = {"w": jnp.asarray([1.0, 1e-4, -1e-4, 0.5])}
    res = {"w": jnp.zeros(4, jnp.bfloat16)}
    cg, new_res = ef_compress_grads(g, res)
    # residual carries what quantization lost
    lost = np.asarray(g["w"]) - np.asarray(cg["w"], np.float32)
    np.testing.assert_allclose(np.asarray(new_res["w"], np.float32), lost,
                               atol=1e-2)


def test_quantize_int8_range():
    q, s = quantize_int8(jnp.asarray([-3.0, 0.0, 7.0]))
    assert q.dtype == jnp.int8
    assert int(q.max()) == 127
