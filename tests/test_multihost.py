"""Multi-host mega-fleet contracts (subprocess harness).

Everything here runs REAL multi-process jax jobs: N localhost worker
processes joined through ``launch.mesh.init_distributed`` (coordinator +
gloo CPU collectives), each exposing K emulated CPU devices
(``--xla_force_host_platform_device_count``), sharding one fleet over a
process-SPANNING mesh (``make_fleet_mesh(spanning=True)``).

Pinned contracts:

* **bit-match** — the same total lane grid produces BIT-identical
  traces whether the (4, 1) fleet mesh lives in 1 process x 4 devices
  or 2 processes x 2 devices (lanes are independent, shard_map bodies
  have no collectives, per-device partitions are identical);
* **host-elastic restore** — an elastic-lifecycle run checkpointed by a
  2-process job (per-process shard layout, ``step_N/proc_P/`` +
  ``meta.json``) restores on a SINGLE process via ``restore_elastic``
  with the surviving-lane accounting intact, and completes.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np

from repro.launch.multihost import free_port, worker_env

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _launch(script: str, n_procs: int, devices_per_proc: int,
            extra_env: dict | None = None, timeout: int = 900,
            sentinel: str = "MH_OK") -> list[str]:
    """Run ``script`` as ``n_procs`` coordinated worker processes; assert
    every rank exits 0 and prints the sentinel; return their outputs."""
    coordinator = f"127.0.0.1:{free_port()}"
    base = dict(os.environ)
    base["PYTHONPATH"] = _SRC + os.pathsep + base.get("PYTHONPATH", "")
    base.update(extra_env or {})
    procs = [subprocess.Popen(
        [sys.executable, "-c", script],
        env=worker_env(base, coordinator, n_procs, pid, devices_per_proc),
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
        for pid in range(n_procs)]
    outs = []
    for pid, p in enumerate(procs):
        out, _ = p.communicate(timeout=timeout)
        outs.append(out)
        assert p.returncode == 0, \
            f"rank {pid}/{n_procs} failed:\n{out}"
        assert sentinel in out, f"rank {pid}/{n_procs}:\n{out}"
    return outs


_FLEET_TRACE_SCRIPT = textwrap.dedent("""
    import os
    from repro.launch.mesh import init_distributed, make_fleet_mesh
    pid, n = init_distributed()
    import jax, numpy as np
    from repro.core import make_agent, run_online_fleet
    from repro.dsdps import SchedulingEnv, apps, scenarios
    from repro.dsdps.apps import default_workload

    topo = apps.continuous_queries("small")
    env = SchedulingEnv(topo, default_workload(topo))
    agent = make_agent("ddpg", env, k_nn=4)
    F, T = 4, 6
    params = scenarios.build("one_slow_machine", env, F,
                             broadcast_invariant=True)
    states = agent.init_fleet(jax.random.PRNGKey(0), F, env_params=params,
                              env=env)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    mesh = make_fleet_mesh(spanning=True)
    assert mesh.devices.size == 4, mesh
    _, h = run_online_fleet(keys, env, agent, states, T=T,
                            env_params=params, mesh=mesh)
    # fleet_host made the full traces identical on every process; any
    # rank could write — rank 0 does
    if pid == 0:
        np.savez(os.environ["MH_OUT"], rewards=h.rewards,
                 latencies=h.latencies, moved=h.moved,
                 X=h.final_assignment)
    print("MH_OK")
""")


def test_two_process_bit_match(tmp_path):
    """The tentpole acceptance gate: 2 procs x 2 devices == 1 proc x 4
    devices, bit for bit, on the same total lane grid."""
    out_1p = tmp_path / "one_proc.npz"
    out_2p = tmp_path / "two_proc.npz"
    _launch(_FLEET_TRACE_SCRIPT, n_procs=1, devices_per_proc=4,
            extra_env={"MH_OUT": str(out_1p)})
    _launch(_FLEET_TRACE_SCRIPT, n_procs=2, devices_per_proc=2,
            extra_env={"MH_OUT": str(out_2p)})
    a, b = np.load(out_1p), np.load(out_2p)
    for name in ("rewards", "latencies", "moved", "X"):
        np.testing.assert_array_equal(a[name], b[name], err_msg=name)


_ELASTIC_SAVE_SCRIPT = textwrap.dedent("""
    import os
    from repro.launch.mesh import init_distributed, make_fleet_mesh
    pid, n = init_distributed()
    assert n == 2
    import jax, numpy as np
    from repro.checkpoint.fleet import FleetCheckpoint
    from repro.core import make_agent
    from repro.fleet.lifecycle import run_online_fleet_elastic
    from repro.dsdps import SchedulingEnv, apps, scenarios
    from repro.dsdps.apps import default_workload

    topo = apps.continuous_queries("small")
    env = SchedulingEnv(topo, default_workload(topo))
    agent = make_agent("ddpg", env, k_nn=4)
    F, T = 4, 6
    params = scenarios.build("one_slow_machine", env, F,
                             broadcast_invariant=True)
    states = agent.init_fleet(jax.random.PRNGKey(0), F, env_params=params,
                              env=env)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    mesh = make_fleet_mesh(spanning=True)

    def stop_lane0(rewards_so_far, t):
        done = np.zeros(rewards_so_far.shape[0], bool)
        if t == 2:
            done[0] = True            # lane 0 "converges" at the first cut
        return done

    ck = FleetCheckpoint(os.environ["MH_CK"], every=2, use_async=False)
    res = run_online_fleet_elastic(keys, env, agent, states, T=T,
                                   env_params=params, mesh=mesh,
                                   checkpoint=ck, stop_fn=stop_lane0)
    ck.close()
    assert res.epochs_run.tolist() == [2, T, T, T], res.epochs_run
    # the published snapshots use the per-process shard layout
    assert ck.is_multihost(), "expected multihost step layout"
    assert ck.has_lane_map(), "expected an elastic lane map"
    if pid == 0:
        np.savez(os.environ["MH_OUT"], rewards=res.history.rewards,
                 epochs_run=res.epochs_run, lane_ids=res.lane_ids)
    print("MH_OK")
""")

_ELASTIC_RESTORE_SCRIPT = textwrap.dedent("""
    import os
    import jax, numpy as np
    from repro.checkpoint.fleet import FleetCheckpoint
    from repro.core import make_agent, reset_fleet_states
    from repro.fleet.lifecycle import restore_elastic, run_online_fleet_elastic
    from repro.dsdps import SchedulingEnv, apps, scenarios
    from repro.dsdps.apps import default_workload

    assert jax.process_count() == 1       # single-process restore side
    topo = apps.continuous_queries("small")
    env = SchedulingEnv(topo, default_workload(topo))
    agent = make_agent("ddpg", env, k_nn=4)
    F, T = 4, 6
    params = scenarios.build("one_slow_machine", env, F,
                             broadcast_invariant=True)
    states = agent.init_fleet(jax.random.PRNGKey(0), F, env_params=params,
                              env=env)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    like_env = reset_fleet_states(keys, env, params)

    ck = FleetCheckpoint(os.environ["MH_CK"], every=2, use_async=False)
    assert ck.is_multihost(), "snapshot should be in multihost layout"
    # the 2-process run published steps 2/4/6; resume from the mid-run
    # snapshot so there are epochs left to complete single-process
    epoch, keys2, states2, env_states2, params2, ids = restore_elastic(
        ck, states, like_env, keys, env_params=params,
        ref=env.default_params(), epoch=4)
    # lane 0 stopped during the 2-process run: only lanes 1..3 survive,
    # named by their ORIGINAL ids
    assert ids.tolist() == [1, 2, 3], ids
    assert int(np.asarray(keys2).shape[0]) == 3
    never = lambda rewards_so_far, t: np.zeros(rewards_so_far.shape[0], bool)
    res = run_online_fleet_elastic(keys2, env, agent, states2,
                                   T=T - epoch, env_params=params2,
                                   env_states=env_states2,
                                   start_epoch=epoch, lane_ids=ids,
                                   stop_fn=never)
    assert res.lane_ids.tolist() == [1, 2, 3]
    assert res.history.rewards.shape == (3, T - epoch)
    print("MH_OK")
""")


def test_elastic_checkpoint_restores_across_host_counts(tmp_path):
    """A 2-process elastic run writes per-process shard checkpoints; a
    1-process job restores them, keeps the surviving-lane accounting
    (original lane ids), and completes the remaining epochs."""
    ck_dir = tmp_path / "mh_ck"
    out = tmp_path / "elastic_run.npz"
    _launch(_ELASTIC_SAVE_SCRIPT, n_procs=2, devices_per_proc=2,
            extra_env={"MH_CK": str(ck_dir), "MH_OUT": str(out)})
    run = np.load(out)
    assert run["epochs_run"].tolist() == [2, 6, 6, 6]
    assert run["lane_ids"].tolist() == [0, 1, 2, 3]
    # the step directories really are the per-process shard layout
    steps = sorted(p.name for p in ck_dir.glob("step_*"))
    assert steps, "no checkpoints published"
    newest = ck_dir / steps[-1]
    assert (newest / "meta.json").exists()
    meta = json.loads((newest / "meta.json").read_text())
    assert meta["process_count"] == 2
    assert sorted(p.name for p in newest.glob("proc_*")) == \
        ["proc_00000", "proc_00001"]
    # restore + resume on ONE process (4 local devices not required:
    # the un-meshed vmap path finishes the run)
    _launch(_ELASTIC_RESTORE_SCRIPT, n_procs=1, devices_per_proc=1,
            extra_env={"MH_CK": str(ck_dir)})


def test_worker_env_wiring(tmp_path):
    """worker_env forces the CPU platform, the emulated device count, and
    the three REPRO_* coordinates init_distributed reads."""
    env = worker_env({"XLA_FLAGS": "--foo"}, "127.0.0.1:1234", 2, 1, 8)
    assert env["REPRO_COORDINATOR"] == "127.0.0.1:1234"
    assert env["REPRO_NUM_PROCESSES"] == "2"
    assert env["REPRO_PROCESS_ID"] == "1"
    assert env["JAX_PLATFORMS"] == "cpu"
    assert "--foo" in env["XLA_FLAGS"]
    assert "--xla_force_host_platform_device_count=8" in env["XLA_FLAGS"]
