"""Fault-tolerance building blocks: heartbeat failure detection and
elastic mesh planning (repro/fault/*), plus the device-count-derived
production mesh (launch.mesh.make_production_mesh) that plan_mesh now
backs — the pieces the multi-host driver (repro.launch.multihost)
composes into its kill/heal loop."""
import jax
import pytest

from repro.fault.elastic import plan_mesh
from repro.fault.heartbeat import HeartbeatMonitor
from repro.launch.mesh import make_production_mesh


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# --------------------------------------------------------------------------
# HeartbeatMonitor
# --------------------------------------------------------------------------
def test_newly_dead_fires_once_per_death():
    clk = FakeClock()
    mon = HeartbeatMonitor(num_workers=3, timeout_s=1.0, clock=clk)
    clk.t = 2.0
    assert mon.newly_dead() == {0, 1, 2}
    # idempotent: an already-reported death is not re-reported — the
    # driver must not re-trigger recovery on every poll
    assert mon.newly_dead() == set()
    assert mon.newly_dead() == set()
    # the cumulative view still sees them
    assert mon.dead_workers() == {0, 1, 2}
    assert mon.alive == []


def test_revival_after_rebeat_rearms_detection():
    clk = FakeClock()
    mon = HeartbeatMonitor(num_workers=2, timeout_s=1.0, clock=clk)
    clk.t = 2.0
    assert mon.newly_dead() == {0, 1}
    # worker 1 comes back (a relaunched process beats again): it leaves
    # the dead set AND its death detection re-arms
    mon.beat(1)
    assert mon.dead_workers() == {0}
    assert mon.alive == [1]
    assert mon.newly_dead() == set()
    # ... so a SECOND death of the same worker is reported again
    clk.t = 4.0
    assert mon.newly_dead() == {1}
    assert mon.newly_dead() == set()


def test_beats_keep_workers_alive():
    clk = FakeClock()
    mon = HeartbeatMonitor(num_workers=2, timeout_s=1.0, clock=clk)
    for step in range(5):
        clk.t = step * 0.5
        mon.beat(0)
        mon.beat(1)
        assert mon.newly_dead() == set()
    assert mon.alive == [0, 1]


# --------------------------------------------------------------------------
# plan_mesh degenerate cases
# --------------------------------------------------------------------------
def test_plan_mesh_single_device():
    plan = plan_mesh(1)
    assert plan.shape == (1, 1)
    assert plan.axes == ("data", "model")
    assert plan.device_count == 1


def test_plan_mesh_indivisible_counts():
    # 3 survivors with model_parallel=16: TP degrades to the largest
    # power of two that fits (2), data takes the rest (1) — one device
    # is left out rather than crashing
    plan = plan_mesh(3, model_parallel=16)
    assert plan.shape == (1, 2)
    # 7 survivors, data-only: every device used
    assert plan_mesh(7, model_parallel=1).shape == (7, 1)


def test_plan_mesh_data_only_fleet_plans():
    # model_parallel=1 is the multi-host fleet driver's call shape: the
    # grid must be (n, 1) for every survivor count, including 1
    for n in (1, 2, 3, 5, 8):
        plan = plan_mesh(n, model_parallel=1)
        assert plan.shape == (n, 1)
        assert plan.device_count == n


def test_plan_mesh_rejects_no_survivors():
    with pytest.raises(ValueError, match="alive"):
        plan_mesh(0)
    with pytest.raises(ValueError, match="alive"):
        plan_mesh(-2, model_parallel=1)


# --------------------------------------------------------------------------
# make_production_mesh derives from the visible device count
# --------------------------------------------------------------------------
def test_production_mesh_fits_small_hosts():
    # the old hard-coded 16x16 crashed on anything under 256 devices;
    # now the mesh is planned over whatever jax actually sees
    mesh = make_production_mesh()
    assert set(mesh.axis_names) == {"data", "model"}
    assert mesh.devices.size <= jax.device_count()
    assert mesh.devices.size >= 1


def test_production_mesh_multi_pod_degrades_gracefully():
    # multi_pod only adds the leading pod axis when the data extent is
    # even; on a small host it falls back to the flat (data, model) grid
    mesh = make_production_mesh(multi_pod=True)
    assert mesh.axis_names in (("data", "model"), ("pod", "data", "model"))
    assert mesh.devices.size <= jax.device_count()
