import os
import sys

# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see the single real device (the 512-device flag
# belongs to launch/dryrun.py only).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
