"""jaxguard static-analysis pass (tools/jaxguard).

One positive + one negative fixture per rule (tests/fixtures/jaxguard/),
suppression handling, the versioned JSON report schema, and CLI exit
codes.  The fixtures double as the rule catalog's executable examples."""
import json
import pathlib
import subprocess
import sys

import pytest

from tools.jaxguard import (RULES, SCHEMA_VERSION, analyze_source,
                            render_json, scan)
from tools.jaxguard.cli import main
from tools.jaxguard.report import Finding, count_by_code
from tools.jaxguard.suppress import Suppressions

FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "jaxguard"
REPO = pathlib.Path(__file__).resolve().parent.parent

ALL_CODES = ("JG001", "JG002", "JG003", "JG004", "JG005", "JG006", "JG007")


def run_on(name: str, select: set[str] | None = None):
    path = FIXTURES / name
    return analyze_source(str(path), path.read_text(), select=select)


# --------------------------------------------------------------------------
# per-rule fixtures: positive flags, negative is silent
# --------------------------------------------------------------------------
@pytest.mark.parametrize("code,n_expected", [
    ("JG001", 2),   # use-after-split + split-in-loop
    ("JG002", 4),   # jit-in-function, jitted def, jit-in-loop, vmap-in-loop
    ("JG003", 3),   # unknown name, out-of-range num, unhashable static
    ("JG004", 2),   # for-loop + while-loop literal constructors
    ("JG005", 4),   # literal default, instance default, 2 dataclass fields
    ("JG006", 1),   # donated read-after
    ("JG007", 4),   # float(), np.asarray, .item(), int() in scan body
])
def test_positive_fixture_flags(code, n_expected):
    findings = run_on(f"{code.lower()}_pos.py", select={code})
    assert len(findings) == n_expected, findings
    assert all(f.code == code for f in findings)


@pytest.mark.parametrize("code", ALL_CODES)
def test_negative_fixture_is_silent(code):
    findings = run_on(f"{code.lower()}_neg.py", select={code})
    assert findings == [], findings


def test_rule_catalog_is_complete():
    assert tuple(sorted(RULES)) == ALL_CODES
    for code, rule in RULES.items():
        assert rule.code == code and rule.name and rule.summary


# --------------------------------------------------------------------------
# suppression
# --------------------------------------------------------------------------
def test_line_suppression_silences_named_rule_only():
    findings = run_on("suppressed.py")
    # the two suppressed sites are silent; the unsuppressed one fires
    assert [f.line for f in findings if f.code == "JG002"] == [18]


def test_file_level_suppression():
    assert run_on("suppressed_file.py") == []


def test_suppression_parsing():
    sup = Suppressions(
        "x = 1  # jaxguard: disable=JG001,jg002\n"
        "y = 2  # JAXGUARD: disable=all\n"
        "# jaxguard: disable-file=JG007\n")
    assert sup.is_suppressed(1, "JG001") and sup.is_suppressed(1, "JG002")
    assert not sup.is_suppressed(1, "JG003")
    assert sup.is_suppressed(2, "JG006")          # `all`
    assert sup.is_suppressed(99, "JG007")         # file-level, any line


# --------------------------------------------------------------------------
# JSON report schema (pinned: bump SCHEMA_VERSION on shape changes)
# --------------------------------------------------------------------------
def test_json_report_schema_is_stable():
    findings, n = scan([str(FIXTURES / "jg001_pos.py")])
    report = render_json(findings, ["tests/fixtures"], n)
    assert report["schema_version"] == SCHEMA_VERSION == 1
    assert set(report) == {"schema_version", "roots", "files_scanned",
                           "findings", "counts"}
    assert report["files_scanned"] == 1
    for f in report["findings"]:
        assert set(f) == {"code", "rule", "path", "line", "col", "message"}
        assert f["code"] in RULES and f["rule"] == RULES[f["code"]].name
    assert report["counts"] == count_by_code(findings)
    json.dumps(report)                            # round-trips


def test_findings_sort_stably():
    a = Finding("b.py", 1, 0, "JG001", "x")
    b = Finding("a.py", 9, 0, "JG002", "y")
    c = Finding("a.py", 2, 0, "JG002", "y")
    assert sorted([a, b, c]) == [c, b, a]


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------
def test_cli_exit_codes_and_artifact(tmp_path, capsys):
    art = tmp_path / "report.json"
    rc = main([str(FIXTURES / "jg002_pos.py"), "--json", str(art)])
    assert rc == 1
    data = json.loads(art.read_text())
    assert data["counts"] == {"JG002": 4}
    rc = main([str(FIXTURES / "jg002_neg.py")])
    assert rc == 0
    out = capsys.readouterr().out
    assert "clean" in out


def test_cli_select_and_list_rules(capsys):
    rc = main([str(FIXTURES / "jg003_pos.py"), "--select", "JG003"])
    assert rc == 1
    out = capsys.readouterr().out
    assert "JG005" not in out and "JG003" in out
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for code in ALL_CODES:
        assert code in out


def test_cli_rejects_unknown_code():
    with pytest.raises(SystemExit):
        main([str(FIXTURES / "jg001_pos.py"), "--select", "JG999"])


# --------------------------------------------------------------------------
# the blocking CI contract: today's src/ scans clean
# --------------------------------------------------------------------------
def test_src_tree_scans_clean():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.jaxguard", "src/"],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_unparseable_file_is_surfaced():
    findings = analyze_source("bad.py", "def broken(:\n")
    assert findings and findings[0].code == "JG002"
    assert "does not parse" in findings[0].message
