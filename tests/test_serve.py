"""Serving: engine generation, prefill/train consistency, continuous
batching scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.continuous import ContinuousBatcher, Request
from repro.serve.engine import Engine, SamplingParams, sample_token


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("llama3-8b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_generate_shapes(dense):
    cfg, params = dense
    eng = Engine(cfg, params, max_seq=64, batch_size=2)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 1,
                                 cfg.vocab_size)
    out = eng.generate(jax.random.PRNGKey(2), prompts, max_new_tokens=5)
    assert out.shape == (2, 5)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_decode_matches_teacher_forcing(dense):
    """Token-by-token decode logits must equal the training forward's
    logits at the same positions (cache consistency)."""
    cfg, params = dense
    B, T = 1, 10
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 1,
                              cfg.vocab_size)
    # train-path logits
    x, *_ = lm._embed_inputs(cfg, params, {"tokens": toks, "targets": toks})
    pos = jnp.arange(T)[None, :]
    h, _ = lm._scan_blocks(cfg, params["layers"], x, pos, causal=True)
    h = lm.nn.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    train_logits = (h @ lm._head_table_T(cfg, params)).astype(jnp.float32)
    # decode-path logits
    cache = lm.init_cache(cfg, batch=B, max_seq=T + 1)
    step = jax.jit(lm.serve_step(cfg))
    dec = []
    for t in range(T):
        logits, cache = step(params, cache, toks[:, t:t + 1])
        dec.append(logits)
    dec = jnp.stack(dec, axis=1)                     # [B, T, V]
    np.testing.assert_allclose(np.asarray(dec), np.asarray(train_logits),
                               atol=0.08, rtol=0.05)   # bf16 matmul noise


def test_sampling_greedy_vs_temperature():
    logits = jnp.asarray([[0.0, 5.0, 1.0]])
    g = sample_token(jax.random.PRNGKey(0), logits, SamplingParams())
    assert int(g[0]) == 1
    # temperature sampling stays in top-k support
    s = sample_token(jax.random.PRNGKey(0), logits,
                     SamplingParams(temperature=1.0, top_k=2))
    assert int(s[0]) in (1, 2)


def test_continuous_batcher_completes_all(dense):
    cfg, params = dense
    cb = ContinuousBatcher(cfg, params, max_seq=64, n_slots=2, eos_id=-1)
    for rid in range(5):
        cb.submit(Request(rid=rid, prompt=[1 + rid, 2, 3],
                          max_new_tokens=4))
    done = cb.run(jax.random.PRNGKey(0), max_steps=200)
    assert len(done) == 5
    assert all(len(r.out) == 4 for r in done)
    assert cb.active == 0


def test_continuous_batcher_recycles_slots(dense):
    cfg, params = dense
    cb = ContinuousBatcher(cfg, params, max_seq=64, n_slots=1, eos_id=-1)
    cb.submit(Request(rid=0, prompt=[5], max_new_tokens=2))
    cb.submit(Request(rid=1, prompt=[9], max_new_tokens=2))
    key = jax.random.PRNGKey(0)
    # slot count 1 forces strictly sequential service
    for i in range(12):
        key, k = jax.random.split(key)
        cb.step(k)
        if len(cb._finished) == 2:
            break
    assert [r.rid for r in cb._finished] == [0, 1]


def test_encdec_generation():
    cfg = get_config("seamless-m4t-medium", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_seq=32, batch_size=2, enc_len=16)
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                               jnp.bfloat16)
    prompts = jnp.ones((2, 2), jnp.int32)
    out = eng.generate(jax.random.PRNGKey(2), prompts, max_new_tokens=3,
                       frames=frames)
    assert out.shape == (2, 3)
