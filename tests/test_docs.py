"""Documentation health: the README/docs suite stays truthful.

Tier-1 runs the intra-repo link check and parses (but does not execute)
the README quickstart; the CI docs job additionally executes the
quickstart under JAX_PLATFORMS=cpu (tools/docs_check.py
--run-quickstart)."""
import pathlib

from tools.docs_check import check_links, extract_quickstart, markdown_files

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_docs_suite_exists():
    assert (REPO / "README.md").exists()
    assert (REPO / "docs" / "core_api.md").exists()
    assert (REPO / "docs" / "sharded_fleets.md").exists()
    assert len(markdown_files()) >= 3


def test_no_broken_intra_repo_links():
    broken = check_links()
    assert not broken, f"broken markdown links: {broken}"


def test_quickstart_block_parses_and_uses_v1_api():
    src = extract_quickstart()
    compile(src, "README.md quickstart", "exec")      # SyntaxError = fail
    # the quickstart must showcase the v1 surface, not retired wrappers
    assert "make_agent" in src and "run_online_fleet" in src
    assert "run_online_ddpg" not in src
    # ~15 lines as promised by ISSUE 4 (allow a little slack for comments)
    assert len([ln for ln in src.splitlines() if ln.strip()]) <= 20
