"""Documentation health: the README/docs suite stays truthful.

Tier-1 runs the intra-repo link check and parses (but does not execute)
every registered executable example; the CI docs job additionally
executes them under JAX_PLATFORMS=cpu (tools/docs_check.py
--run-examples)."""
import pathlib

from tools.docs_check import (EXECUTABLE_DOCS, check_links, extract_example,
                              extract_quickstart, markdown_files)

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_docs_suite_exists():
    assert (REPO / "README.md").exists()
    assert (REPO / "docs" / "core_api.md").exists()
    assert (REPO / "docs" / "sharded_fleets.md").exists()
    assert (REPO / "docs" / "elastic_fleets.md").exists()
    assert (REPO / "docs" / "benchmarks.md").exists()
    assert len(markdown_files()) >= 5


def test_no_broken_intra_repo_links():
    broken = check_links()
    assert not broken, f"broken markdown links: {broken}"


def test_registered_examples_parse():
    assert "docs/elastic_fleets.md" in EXECUTABLE_DOCS
    for rel in EXECUTABLE_DOCS:
        src = extract_example(rel)
        compile(src, rel, "exec")                     # SyntaxError = fail


def test_quickstart_block_parses_and_uses_v1_api():
    src = extract_quickstart()
    compile(src, "README.md quickstart", "exec")      # SyntaxError = fail
    # the quickstart must showcase the v1 surface, not retired wrappers
    assert "make_agent" in src and "run_online_fleet" in src
    assert "run_online_ddpg" not in src
    # ~15 lines as promised by ISSUE 4 (allow a little slack for comments)
    assert len([ln for ln in src.splitlines() if ln.strip()]) <= 20


def test_elastic_example_uses_the_lifecycle_api():
    src = extract_example("docs/elastic_fleets.md")
    assert "StopRule" in src and "run_online_fleet_elastic" in src
    # stays inside the CI-executed budget (a quickstart-sized snippet)
    assert len([ln for ln in src.splitlines() if ln.strip()]) <= 25
