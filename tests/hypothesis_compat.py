"""Graceful degradation when the ``test`` extra isn't installed.

``from hypothesis import given, ...`` at module top made four test modules
uncollectable (a collection ERROR aborts the whole tier-1 run).  Importing
the same names from this shim keeps the example-based tests in those
modules running and turns each property-based test into a clean skip —
``pytest.importorskip("hypothesis")`` semantics applied per-test rather
than per-module."""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    class _AnyStrategy:
        """Stands in for ``strategies``: every attribute is a no-op factory
        (the values are only consumed by ``@given``, which skips)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            def _skipped():
                pytest.importorskip("hypothesis")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
