"""DDPG (Algorithm 1), DQN baseline, model-based baseline — learning
machinery correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DDPGConfig, DQNConfig, ModelBasedScheduler,
                        ddpg_init, dqn_init, round_robin)
from repro.core import ddpg, dqn
from repro.core.replay import replay_add, replay_init, replay_sample
from repro.dsdps import SchedulingEnv, apps
from repro.dsdps.apps import default_workload


@pytest.fixture(scope="module")
def small_env():
    topo = apps.continuous_queries("small")
    return SchedulingEnv(topo, default_workload(topo))


def test_replay_ring_buffer_semantics():
    buf = replay_init(4, 3, 2)
    for i in range(6):
        buf = replay_add(buf, jnp.full(3, i), jnp.full(2, i),
                         jnp.float32(i), jnp.full(3, i + 1))
    assert int(buf.size) == 4
    assert int(buf.ptr) == 2
    # oldest entries (0, 1) were overwritten by (4, 5)
    stored = set(float(r) for r in buf.rewards)
    assert stored == {2.0, 3.0, 4.0, 5.0}
    s, a, r, sn = replay_sample(jax.random.PRNGKey(0), buf, 16)
    assert s.shape == (16, 3) and r.shape == (16,)


def test_ddpg_select_action_feasible(small_env):
    env = small_env
    cfg = DDPGConfig(n_executors=env.N, n_machines=env.M,
                     state_dim=env.state_dim, k_nn=4)
    state = ddpg_init(jax.random.PRNGKey(0), cfg)
    s = env.reset(jax.random.PRNGKey(1))
    a = ddpg.select_action(jax.random.PRNGKey(2), state, cfg,
                           env.state_vector(s), explore=False,
                           exact_host_knn=True)
    from repro.core.spaces import is_feasible
    assert bool(is_feasible(a))
    a2 = ddpg.select_action_jit(jax.random.PRNGKey(2), state, cfg,
                                env.state_vector(s), explore=False)
    assert bool(is_feasible(a2))


def test_ddpg_update_reduces_critic_loss(small_env):
    env = small_env
    cfg = DDPGConfig(n_executors=env.N, n_machines=env.M,
                     state_dim=env.state_dim, k_nn=4, lr_critic=3e-3)
    key = jax.random.PRNGKey(0)
    state = ddpg_init(key, cfg)
    # fill replay with synthetic transitions having a learnable value fn
    for i in range(80):
        k = jax.random.fold_in(key, i)
        s = jax.random.uniform(k, (cfg.state_dim,))
        a = jax.random.uniform(k, (cfg.action_dim,))
        r = -s.mean()
        state = ddpg.store(state, s, a, r, s)
    losses = []
    for i in range(60):
        state, aux = ddpg.update_step(jax.random.fold_in(key, 1000 + i),
                                      state, cfg)
        losses.append(float(aux["critic_loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10])


def test_ddpg_target_network_soft_update(small_env):
    env = small_env
    cfg = DDPGConfig(n_executors=env.N, n_machines=env.M,
                     state_dim=env.state_dim, k_nn=2)
    state = ddpg_init(jax.random.PRNGKey(0), cfg)
    for i in range(3):
        k = jax.random.fold_in(jax.random.PRNGKey(1), i)
        s = jax.random.uniform(k, (cfg.state_dim,))
        state = ddpg.store(state, s, jax.random.uniform(k, (cfg.action_dim,)),
                           jnp.float32(-1.0), s)
    w_before = state.target_critic.weights[0]
    state2, _ = ddpg.update_step(jax.random.PRNGKey(2), state, cfg)
    w_after = state2.target_critic.weights[0]
    online = state2.critic.weights[0]
    expected = (1 - cfg.tau) * w_before + cfg.tau * online
    np.testing.assert_allclose(np.asarray(w_after), np.asarray(expected),
                               rtol=1e-5, atol=1e-6)


def test_dqn_move_semantics():
    X = jax.nn.one_hot(jnp.array([0, 1, 2]), 4)
    X2 = dqn.apply_move(X, jnp.asarray(1 * 4 + 3), 4)  # executor 1 -> machine 3
    assert int(X2[1].argmax()) == 3
    assert int(X2[0].argmax()) == 0 and int(X2[2].argmax()) == 2


def test_dqn_update_runs(small_env):
    env = small_env
    cfg = DQNConfig(n_executors=env.N, n_machines=env.M,
                    state_dim=env.state_dim)
    key = jax.random.PRNGKey(0)
    state = dqn_init(key, cfg)
    for i in range(40):
        k = jax.random.fold_in(key, i)
        s = jax.random.uniform(k, (cfg.state_dim,))
        state = dqn.store(state, s, i % cfg.num_actions, jnp.float32(-2.0), s)
    state, aux = dqn.update_step(jax.random.PRNGKey(1), state, cfg)
    assert np.isfinite(float(aux["loss"]))


def test_model_based_predictor_correlates(small_env):
    env = small_env
    sched = ModelBasedScheduler(env).fit(jax.random.PRNGKey(0), n_samples=250)
    w = env.workload.init()
    preds, trues = [], []
    for i in range(40):
        X = env.random_assignment(jax.random.PRNGKey(1000 + i))
        preds.append(float(sched.predict(X, w)))
        trues.append(float(env.evaluate(X, w)))
    r = np.corrcoef(preds, trues)[0, 1]
    assert r > 0.6, f"model-based predictor correlation too low: {r:.3f}"


def test_model_based_schedule_beats_round_robin(small_env):
    env = small_env
    sched = ModelBasedScheduler(env).fit(jax.random.PRNGKey(0), n_samples=250)
    w = env.workload.init()
    X = sched.schedule(w, sweeps=2)
    rr = float(env.evaluate(env.round_robin_assignment(), w))
    mb = float(env.evaluate(X, w))
    assert mb < rr * 1.02   # at least matches RR (usually clearly better)


def test_model_based_no_retrace_across_calls():
    """Regression: ``fit`` used to build a fresh ``jax.jit`` wrapper per
    call and ``schedule`` re-defined + re-jitted its move search per call —
    every invocation retraced.  Both now go through module-level jitted
    programs; the diagnostics jit-cache-miss sentinel must see exactly one
    compilation each on first use and ZERO across repeat calls with the
    same static args."""
    from repro.core import model_based as mb
    from repro.diagnostics import CompileCounter
    # fresh env instance => fresh static jit key => compilation is observable
    topo = apps.continuous_queries("small")
    env = SchedulingEnv(topo, default_workload(topo))
    w = env.workload.init()
    with CompileCounter(mb._fit_theta_jit, label="fit") as cc_fit, \
            CompileCounter(mb.sweep_schedule, label="schedule") as cc_sched:
        sched = ModelBasedScheduler(env).fit(jax.random.PRNGKey(0),
                                             n_samples=50)
        X1 = sched.schedule(w, sweeps=2)
    cc_fit.assert_compiles(1)
    cc_sched.assert_compiles(1)
    # same static args (env, n_samples, sweeps), new traced values: the
    # cached executables run without re-tracing
    with CompileCounter(mb._fit_theta_jit, mb.sweep_schedule,
                        label="repeat") as cc:
        sched.fit(jax.random.PRNGKey(1), n_samples=50)
        X2 = sched.schedule(w * 1.1, sweeps=2)
        X3 = sched.schedule(w, X0=X1, sweeps=2)
    cc.assert_compiles(0)
    assert X2.shape == X1.shape == X3.shape


def test_ddpg_select_pallas_knn_matches_default(small_env):
    """The Pallas-backed K-NN projection is a drop-in for the lax.top_k
    beam inside the DDPG select path (interpret mode on CPU)."""
    env = small_env
    kw = dict(n_executors=env.N, n_machines=env.M,
              state_dim=env.state_dim, k_nn=4)
    cfg = DDPGConfig(**kw)
    cfg_pl = DDPGConfig(**kw, use_pallas_knn=True)
    state = ddpg_init(jax.random.PRNGKey(0), cfg)
    s = env.reset(jax.random.PRNGKey(1))
    a = ddpg.select_action(jax.random.PRNGKey(2), state, cfg,
                           env.state_vector(s), explore=False)
    a_pl = ddpg.select_action(jax.random.PRNGKey(2), state, cfg_pl,
                              env.state_vector(s), explore=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_pl))


def test_round_robin_skips_dead_machines():
    X = round_robin(10, 4, alive=np.array([True, False, True, True]))
    used = set(np.asarray(X).argmax(-1).tolist())
    assert 1 not in used
    assert np.allclose(np.asarray(X).sum(-1), 1.0)
