"""Elastic lane lifecycle (repro/fleet/lifecycle.py) + History helpers.

The ISSUE-5 acceptance gates: a lane whose reward plateaus stops within
one chunk of the plateau becoming visible to the rule, and compaction is
loss-free — on the host mesh, surviving lanes of a compacted run
bit-match the same lanes of the uncompacted fixed-grid run."""
import jax
import numpy as np
import pytest

from repro.core import History, make_agent
from repro.core.agent import run_online_fleet
from repro.dsdps import SchedulingEnv, apps, scenarios
from repro.dsdps.apps import default_workload
from repro.fleet.lifecycle import (StopRule, compact_lanes,
                                   plateau_converged,
                                   run_online_fleet_elastic,
                                   search_scenarios)


@pytest.fixture(scope="module")
def small_env():
    topo = apps.continuous_queries("small")
    return SchedulingEnv(topo, default_workload(topo))


@pytest.fixture(scope="module")
def rr_agent(small_env):
    return make_agent("round_robin", small_env)


# --------------------------------------------------------------------------
# History helpers
# --------------------------------------------------------------------------
def _fleet_history(F=3, T=40, seed=0):
    rng = np.random.default_rng(seed)
    rewards = rng.normal(-2.0, 0.1, (F, T)).astype(np.float32)
    return History(rewards=rewards,
                   latencies=-rewards,
                   moved=np.zeros((F, T), np.float32),
                   final_assignment=np.zeros((F, 4, 2), np.float32))


def test_history_lane_slices_one_run():
    h = _fleet_history()
    assert h.fleet == 3
    lane1 = h.lane(1)
    assert lane1.fleet is None
    np.testing.assert_array_equal(lane1.rewards, h.rewards[1])
    np.testing.assert_array_equal(lane1.final_assignment,
                                  h.final_assignment[1])
    with pytest.raises(ValueError):
        lane1.lane(0)


def test_history_normalized_rewards_per_lane():
    h = _fleet_history()
    norm = h.normalized_rewards()
    assert norm.shape == h.rewards.shape
    assert np.all(norm >= 0.0) and np.all(norm <= 1.0)
    # per-lane normalization: every lane spans [0, 1]
    np.testing.assert_allclose(norm.min(axis=1), 0.0, atol=1e-7)
    np.testing.assert_allclose(norm.max(axis=1), 1.0, atol=1e-7)
    # monotone map of the raw rewards within a lane
    order_raw = np.argsort(h.rewards[0])
    np.testing.assert_array_equal(order_raw, np.argsort(norm[0]))


def test_history_seed_band_shapes_and_flat_band():
    h = _fleet_history()
    mean, std = h.seed_band()
    assert mean.shape == (h.rewards.shape[1],)
    assert std.shape == mean.shape
    assert np.all(std >= 0.0)
    # identical lanes -> zero band
    same = History(rewards=np.tile(h.rewards[:1], (3, 1)),
                   latencies=h.latencies, moved=h.moved,
                   final_assignment=h.final_assignment)
    _, std0 = same.seed_band()
    np.testing.assert_allclose(std0, 0.0, atol=1e-6)


# --------------------------------------------------------------------------
# Stopping rule
# --------------------------------------------------------------------------
def test_plateau_rule_flat_stops_improving_does_not():
    rule = StopRule(window=4, rel_tol=0.01)
    recent = np.zeros((3, 8), np.float32)
    recent[0] = -2.0                                  # flat -> plateau
    recent[1] = np.linspace(-3.0, -1.0, 8)            # improving -> run on
    recent[2] = np.linspace(-1.0, -3.0, 8)            # degrading -> plateau
    done = np.asarray(plateau_converged(jax.numpy.asarray(recent), rule))
    assert done.tolist() == [True, False, True]


def test_plateau_rule_single_lane_shape():
    rule = StopRule(window=2)
    done = plateau_converged(jax.numpy.zeros(4), rule)
    assert bool(done)


def test_stoprule_warmup():
    assert StopRule(window=8, min_epochs=4).warmup == 16
    assert StopRule(window=2, min_epochs=10).warmup == 10


# --------------------------------------------------------------------------
# Early stopping + compaction
# --------------------------------------------------------------------------
def test_plateaued_lane_stops_within_one_chunk(small_env, rr_agent):
    """Round-robin lanes plateau from epoch 0; the rule must fire at the
    FIRST boundary past its warmup — one chunk after the plateau is
    observable, not later."""
    F, T = 3, 16
    rule = StopRule(window=2, rel_tol=0.05, min_epochs=4, check_every=4)
    states = rr_agent.init_fleet(jax.random.PRNGKey(0), F)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    res = run_online_fleet_elastic(keys, small_env, rr_agent, states, T,
                                   rule=rule)
    assert res.epochs_run.tolist() == [rule.warmup] * F
    assert res.executed_lane_epochs == F * rule.warmup
    assert res.executed_lane_epochs < res.fixed_grid_lane_epochs
    assert 0.0 < res.savings < 1.0
    # padded tails repeat the final reward
    np.testing.assert_array_equal(
        res.history.rewards[:, rule.warmup:],
        np.repeat(res.history.rewards[:, rule.warmup - 1:rule.warmup],
                  T - rule.warmup, axis=1))


def test_compacted_run_bitmatches_fixed_grid(small_env):
    """The loss-free contract on the host mesh: force lane 1 to stop at
    the first boundary (real compaction, 3 -> 2 lanes) and the surviving
    lanes' full trajectories + final agent states must bit-match the
    uncompacted fixed-grid run; the stopped lane's prefix must too."""
    env = small_env
    agent = make_agent("ddpg", env, k_nn=4)
    F, T, stop_at = 3, 12, 4
    params = scenarios.build("one_slow_machine", env, F,
                             broadcast_invariant=True)
    states = agent.init_fleet(jax.random.PRNGKey(2), F, env_params=params,
                              env=env)
    keys = jax.random.split(jax.random.PRNGKey(3), F)
    s_fix, h_fix = run_online_fleet(keys, env, agent, states, T=T,
                                    env_params=params)

    def stop_lane1(rewards_so_far, t):
        done = np.zeros(rewards_so_far.shape[0], bool)
        if t == stop_at:
            done[1] = True
        return done

    res = run_online_fleet_elastic(keys, env, agent, states, T,
                                   rule=StopRule(check_every=stop_at),
                                   env_params=params, stop_fn=stop_lane1)
    assert res.epochs_run.tolist() == [T, stop_at, T]
    assert res.executed_lane_epochs == F * stop_at + 2 * (T - stop_at)
    # surviving lanes: full-trace and final-state bit-match
    for lane in (0, 2):
        np.testing.assert_array_equal(res.history.rewards[lane],
                                      h_fix.rewards[lane])
        np.testing.assert_array_equal(res.history.moved[lane],
                                      h_fix.moved[lane])
        np.testing.assert_array_equal(res.history.final_assignment[lane],
                                      h_fix.final_assignment[lane])
    for a, b in zip(jax.tree.leaves(res.states), jax.tree.leaves(s_fix)):
        np.testing.assert_array_equal(np.asarray(a)[[0, 2]],
                                      np.asarray(b)[[0, 2]])
    # the stopped lane's prefix is the fixed-grid prefix
    np.testing.assert_array_equal(res.history.rewards[1, :stop_at],
                                  h_fix.rewards[1, :stop_at])


def test_all_lanes_stopping_ends_the_run(small_env, rr_agent):
    F, T = 2, 20
    states = rr_agent.init_fleet(jax.random.PRNGKey(4), F)
    keys = jax.random.split(jax.random.PRNGKey(5), F)

    def stop_all(rewards_so_far, t):
        return np.ones(rewards_so_far.shape[0], bool)

    res = run_online_fleet_elastic(keys, small_env, rr_agent, states, T,
                                   rule=StopRule(check_every=5),
                                   stop_fn=stop_all)
    assert res.epochs_run.tolist() == [5, 5]
    assert res.executed_lane_epochs == F * 5
    assert res.history.rewards.shape == (F, T)


def test_compact_lanes_keeps_broadcast_invariant_leaves(small_env):
    env = small_env
    ref = env.default_params()
    params = scenarios.build("one_slow_machine", env, 4,
                             broadcast_invariant=True)
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    env_states = env.reset_fleet(keys, params=params)
    states = jax.numpy.arange(4.0)
    k2, s2, e2, p2 = compact_lanes([0, 2], keys, states, env_states,
                                   params, ref)
    assert k2.shape[0] == 2 and s2.shape[0] == 2 and e2.X.shape[0] == 2
    # stacked leaf gathered, invariant leaf untouched (still unstacked)
    assert p2.speed.shape == (2,) + ref.speed.shape
    assert p2.routing.shape == ref.routing.shape
    np.testing.assert_array_equal(np.asarray(p2.speed),
                                  np.asarray(params.speed)[[0, 2]])


# --------------------------------------------------------------------------
# Successive-halving scenario search
# --------------------------------------------------------------------------
def test_search_scenarios_leaderboard(small_env, rr_agent):
    fleet, rungs = 4, (3, 3)
    lb = search_scenarios(small_env, rr_agent, fleet=fleet, rungs=rungs,
                          eval_window=2, seed=0)
    # refill keeps the fleet wide: fleet launched + fleet/2 refills
    assert len(lb.entries) == fleet + fleet // 2
    assert lb.total_lane_epochs == fleet * sum(rungs)
    # ranked best-first by eval reward
    scores = [e.score for e in lb.entries]
    assert scores == sorted(scores, reverse=True)
    assert all(np.isfinite(s) for s in scores)
    # half the first rung's candidates were pruned after rung 1
    pruned = [e for e in lb.entries if not e.survived]
    assert len(pruned) == fleet // 2
    assert all(e.rung == 1 for e in pruned)
    # every candidate's params are available for curriculum reuse
    for e in lb.entries:
        assert e.cand in lb.params
    js = lb.to_json()
    assert js["rungs"] == list(rungs)
    assert len(js["leaderboard"]) == len(lb.entries)


def test_search_rejects_tiny_fleet(small_env, rr_agent):
    with pytest.raises(ValueError):
        search_scenarios(small_env, rr_agent, fleet=1, rungs=(2,))


# --------------------------------------------------------------------------
# Elastic restore: lane_map roundtrip + the reworked resume_after_failure
# --------------------------------------------------------------------------
def test_elastic_checkpoint_lane_map_and_resume(tmp_path, small_env,
                                                rr_agent):
    from repro.checkpoint.fleet import FleetCheckpoint
    from repro.fault.elastic import resume_after_failure

    env, agent = small_env, rr_agent
    F, T = 3, 12
    states = agent.init_fleet(jax.random.PRNGKey(6), F)
    keys = jax.random.split(jax.random.PRNGKey(7), F)
    ck = FleetCheckpoint(tmp_path, every=4, keep=10)

    def stop_lane0(rewards_so_far, t):
        done = np.zeros(rewards_so_far.shape[0], bool)
        if t == 4:
            done[0] = True
        return done

    run_online_fleet_elastic(keys, env, agent, states, T,
                             rule=StopRule(check_every=4), checkpoint=ck,
                             stop_fn=stop_lane0)
    ck.wait()
    assert ck.all_epochs() == [4, 8, 12]
    # the epoch-8 snapshot is the compacted 2-lane fleet with a lane map
    two = jax.tree.map(lambda x: x[:2], states)
    from repro.core.agent import reset_fleet_states
    like_env = reset_fleet_states(keys[:2], env)
    epoch, _, _, _, lanes = ck.restore(two, like_env, keys[:2], epoch=8,
                                       with_lane_map=True)
    assert epoch == 8
    assert lanes.tolist() == [1, 2]          # lane 0 stopped and compacted

    # resume_after_failure plans a survivor mesh and restores the newest
    # (compacted) snapshot through the same path — templates describe the
    # surviving 2-lane fleet
    mesh, epoch, r_states, r_env, r_keys, r_lanes = resume_after_failure(
        ck, env, agent, keys[:2], two, env_states=like_env,
        alive_devices=1, with_lane_map=True)
    assert epoch == 12 and mesh.devices.size == 1
    assert r_lanes.tolist() == [1, 2]
    for leaf in jax.tree.leaves((r_states, r_env, r_keys)):
        assert np.ndim(leaf) == 0 or np.asarray(leaf).shape[0] == 2
    ck.close()

    with pytest.raises(TypeError):
        resume_after_failure(ck, env, object(), keys, states)


def test_kill_and_resume_elastic_bitmatches_uninterrupted(tmp_path,
                                                          small_env):
    """The kill/resume contract for COMPACTED fleets: kill an elastic
    scenario run after a compaction (lane 1 stopped at epoch 4, snapshot
    taken at epoch 8 holds 2 lanes + lane map), resume it through
    restore_elastic + run_online_fleet_elastic(lane_ids=...), and the
    surviving lanes' remaining trajectories and final agent states must
    bit-match the uninterrupted run — with all accounting still in the
    ORIGINAL lane numbering."""
    from repro.checkpoint.fleet import FleetCheckpoint
    from repro.core.agent import reset_fleet_states
    from repro.fleet.lifecycle import restore_elastic

    env = small_env
    agent = make_agent("ddpg", env, k_nn=4)
    F, T, cut = 3, 12, 8
    params = scenarios.build("one_slow_machine", env, F,
                             broadcast_invariant=True)
    ref = env.default_params()
    states = agent.init_fleet(jax.random.PRNGKey(8), F, env_params=params,
                              env=env)
    keys = jax.random.split(jax.random.PRNGKey(9), F)

    def stop_lane1(rewards_so_far, t):
        done = np.zeros(rewards_so_far.shape[0], bool)
        if t == 4:
            done[1] = True
        return done

    ck = FleetCheckpoint(tmp_path, every=4, keep=10, use_async=False)
    full = run_online_fleet_elastic(keys, env, agent, states, T,
                                    rule=StopRule(check_every=4),
                                    env_params=params, stop_fn=stop_lane1,
                                    checkpoint=ck)
    assert full.epochs_run.tolist() == [T, 4, T]
    assert full.lane_ids.tolist() == [0, 1, 2]
    assert ck.has_lane_map(epoch=cut)

    # "kill" = resume from the epoch-8 snapshot with FULL-SIZE templates
    # (they only supply tree structure; shapes come from the manifest)
    like_env = reset_fleet_states(keys, env)
    epoch, r_keys, r_states, r_env, r_params, ids = restore_elastic(
        ck, states, like_env, keys, env_params=params, ref=ref, epoch=cut)
    assert epoch == cut
    assert ids.tolist() == [0, 2]            # lane 1 compacted away
    # scenario rows followed the survivors; invariant leaves stay single
    assert np.asarray(r_params.speed).shape[0] == 2
    np.testing.assert_array_equal(np.asarray(r_params.speed),
                                  np.asarray(params.speed)[[0, 2]])
    assert r_params.routing.shape == ref.routing.shape

    ck2 = FleetCheckpoint(tmp_path / "resumed", every=4, keep=10,
                          use_async=False)
    res = run_online_fleet_elastic(r_keys, env, agent, r_states, T - cut,
                                   rule=StopRule(check_every=4),
                                   env_states=r_env, env_params=r_params,
                                   start_epoch=epoch, lane_ids=ids,
                                   stop_fn=stop_lane1, checkpoint=ck2)
    assert res.lane_ids.tolist() == [0, 2]
    assert res.epochs_run.tolist() == [T - cut, T - cut]
    # remaining trajectories bit-match the uninterrupted run's tail
    np.testing.assert_array_equal(res.history.rewards,
                                  full.history.rewards[[0, 2], cut:])
    np.testing.assert_array_equal(res.history.moved,
                                  full.history.moved[[0, 2], cut:])
    # final agent states bit-match too
    for a, b in zip(jax.tree.leaves(res.states), jax.tree.leaves(full.states)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[[0, 2]])
    # the resumed run's snapshots keep naming the ORIGINAL lanes
    two = jax.tree.map(lambda x: x[:2], states)
    ep2, _, _, _, lanes2 = ck2.restore(two, reset_fleet_states(keys[:2], env),
                                       keys[:2], with_lane_map=True)
    assert ep2 == T and lanes2.tolist() == [0, 2]
