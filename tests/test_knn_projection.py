"""The MIQP-NN replacement (core/knn_projection.py) — exactness and
feasibility (DESIGN.md §2)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.knn_projection import (distance_to, knn_actions_exact,
                                       knn_actions_jax,
                                       knn_assignments_exact,
                                       nearest_assignment)
from repro.core.spaces import is_feasible


def brute_force_knn(proto: np.ndarray, k: int) -> np.ndarray:
    """Enumerate all M^N assignments (tiny instances only)."""
    n, m = proto.shape
    dists = []
    for cols in itertools.product(range(m), repeat=n):
        a = np.eye(m)[list(cols)]
        dists.append((np.sum((a - proto) ** 2), cols))
    dists.sort(key=lambda t: t[0])
    return np.array([d for d, _ in dists[:k]])


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(2, 4),
       st.integers(1, 8))
def test_exact_knn_matches_brute_force(seed, n, m, k):
    rng = np.random.default_rng(seed)
    proto = rng.uniform(size=(n, m))
    cols = knn_assignments_exact(proto, k)
    actions = np.eye(m)[cols]
    got = np.sort(((actions - proto) ** 2).sum((1, 2)))
    want = brute_force_knn(proto, min(k, m ** n))[: len(got)]
    np.testing.assert_allclose(np.sort(got)[: len(want)], want, rtol=1e-9)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 30), st.integers(2, 10),
       st.integers(1, 12))
def test_exact_knn_ordered_and_feasible(seed, n, m, k):
    rng = np.random.default_rng(seed)
    proto = rng.uniform(size=(n, m))
    acts = knn_actions_exact(proto, k)
    d = ((acts - proto[None]) ** 2).sum((1, 2))
    assert np.all(np.diff(d) >= -1e-9), "neighbours must be distance-ordered"
    for a in acts:
        assert bool(is_feasible(jnp.asarray(a)))


def test_jax_beam_matches_exact_on_random_instances():
    mismatches = 0
    for seed in range(20):
        key = jax.random.PRNGKey(seed)
        proto = jax.random.uniform(key, (40, 10))
        k = 8
        exact = knn_actions_exact(np.asarray(proto), k)
        beam = np.asarray(knn_actions_jax(proto, k))
        d_exact = np.sort(((exact - np.asarray(proto)) ** 2).sum((1, 2)))
        d_beam = np.sort(((beam - np.asarray(proto)) ** 2).sum((1, 2)))
        if not np.allclose(d_exact, d_beam, rtol=1e-5):
            mismatches += 1
    # the beam is exact w.h.p. on continuous data; allow a rare tie case
    assert mismatches <= 1, f"{mismatches}/20 beam≠exact"


def test_jax_beam_contains_exact_1nn():
    for seed in range(10):
        key = jax.random.PRNGKey(100 + seed)
        proto = jax.random.uniform(key, (25, 6))
        beam = np.asarray(knn_actions_jax(proto, 6))
        one = np.asarray(nearest_assignment(proto))
        assert any(np.array_equal(b, one) for b in beam)


def test_pallas_beam_matches_xla_beam_exactly():
    """knn_actions_jax(use_pallas=True) routes the top-2/regret reduction
    through the kernels/knn_topk Pallas kernel (interpret mode on CPU) and
    must match the lax.top_k beam bit for bit."""
    for seed, (n, m, k) in [(0, (40, 10, 8)), (1, (25, 6, 6)),
                            (2, (7, 3, 4)), (3, (100, 10, 16))]:
        proto = jax.random.uniform(jax.random.PRNGKey(seed), (n, m))
        beam = np.asarray(knn_actions_jax(proto, k))
        pallas = np.asarray(knn_actions_jax(proto, k, use_pallas=True))
        np.testing.assert_array_equal(pallas, beam)


def test_nearest_assignment_is_row_argmax():
    proto = jnp.asarray([[0.1, 0.9], [0.7, 0.3]])
    a = nearest_assignment(proto)
    np.testing.assert_array_equal(np.asarray(a),
                                  [[0.0, 1.0], [1.0, 0.0]])


def test_distance_to():
    proto = jnp.zeros((3, 4))
    a = jax.nn.one_hot(jnp.array([0, 1, 2]), 4)
    assert float(distance_to(proto, a)) == pytest.approx(3.0)
