"""Device-sharded scenario fleets (run_online_fleet(..., mesh=...)).

The contract under test: (a) on the host mesh (jax.make_mesh over the one
CPU device) the sharded path is bit-comparable to the plain vmap runner,
(b) params partition specs mirror params_in_axes (stacked leaves shard,
broadcast-invariant leaves replicate) and stay hashable, (c) indivisible
fleets fail loudly, and (d) on a REAL 2-device mesh (subprocess with
--xla_force_host_platform_device_count=2) lane i still matches the
un-sharded run and a checkpoint written under the 2-device mesh restores
against a different device count (elastic re-placement)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import ddpg, make_agent
from repro.core.agent import run_online_fleet
from repro.core.ddpg import DDPGConfig
from repro.dsdps import SchedulingEnv, apps, scenarios
from repro.dsdps.apps import default_workload
from repro.launch.mesh import make_host_mesh
from repro.sharding.fleet import (fleet_axes, fleet_shardings, fleet_size,
                                  fleet_spec, params_partition_specs)


@pytest.fixture(scope="module")
def small_env():
    topo = apps.continuous_queries("small")
    return SchedulingEnv(topo, default_workload(topo))


@pytest.fixture(scope="module")
def ddpg_agent(small_env):
    cfg = DDPGConfig(n_executors=small_env.N, n_machines=small_env.M,
                     state_dim=small_env.state_dim, k_nn=4)
    return make_agent("ddpg", small_env, cfg=cfg)


def test_fleet_axes_and_spec():
    mesh = make_host_mesh()
    assert fleet_axes(mesh) == ("data",)
    assert fleet_size(mesh) == 1
    assert fleet_spec(mesh) == P(("data",))


def test_params_partition_specs(small_env):
    env = small_env
    p = env.default_params()
    mesh = make_host_mesh()
    bc = scenarios.build("one_slow_machine", env, 3, broadcast_invariant=True)
    specs = params_partition_specs(bc, p, mesh)
    # stacked leaves shard the fleet axis, invariant leaves replicate
    assert specs.speed == P(("data",))
    assert specs.routing == P() and specs.flow_solve == P()
    # single-scenario params replicate everywhere
    single = params_partition_specs(p, p, mesh)
    assert all(s == P() for s in single)
    # hashable: the sharded program takes the spec tree as a static arg
    assert hash(specs) == hash(params_partition_specs(
        scenarios.build("one_slow_machine", env, 3, broadcast_invariant=True),
        p, mesh))


def test_fleet_shardings_shapes(small_env):
    mesh = make_host_mesh()
    tree = {"stacked": np.zeros((4, 3)), "vector": np.zeros(4),
            "scalar": np.float32(1.0)}
    sh = fleet_shardings(mesh, tree)
    assert isinstance(sh["stacked"], NamedSharding)
    assert sh["stacked"].spec == P(("data",))
    assert sh["vector"].spec == P(("data",))
    assert sh["scalar"].spec == P()          # scalars replicate


def test_host_mesh_lane_equivalence(small_env, ddpg_agent):
    """The ISSUE-4 acceptance gate: lane i of a mesh-sharded
    run_online_fleet bit-matches lane i of the single-device vmap run on
    the host mesh (the broadcast-matmul ulp caveat does not bite here —
    both paths lower the same program on one device)."""
    env, agent = small_env, ddpg_agent
    F, T = 4, 8
    params = scenarios.build("mixed", env, F, broadcast_invariant=True)
    states = agent.init_fleet(jax.random.PRNGKey(0), F, env_params=params,
                              env=env)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    s_v, h_v = run_online_fleet(keys, env, agent, states, T=T,
                                env_params=params)
    s_m, h_m = run_online_fleet(keys, env, agent, states, T=T,
                                env_params=params, mesh=make_host_mesh())
    np.testing.assert_array_equal(h_m.rewards, h_v.rewards)
    np.testing.assert_array_equal(h_m.latencies, h_v.latencies)
    np.testing.assert_array_equal(h_m.moved, h_v.moved)
    np.testing.assert_array_equal(h_m.final_assignment, h_v.final_assignment)
    for a, b in zip(jax.tree.leaves(s_v), jax.tree.leaves(s_m)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bad_agent_still_raises_before_sharding(small_env):
    """mesh= does not loosen the Agent requirement."""
    env = small_env
    cfg = DDPGConfig(n_executors=env.N, n_machines=env.M,
                     state_dim=env.state_dim, k_nn=4)
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    states = ddpg.init_fleet(jax.random.PRNGKey(1), cfg, 2)
    with pytest.raises(TypeError, match="make_agent"):
        run_online_fleet(keys, env, cfg, states, T=2, mesh=make_host_mesh())


_TWO_DEVICE_SCRIPT = textwrap.dedent("""
    import jax, numpy as np, tempfile
    assert len(jax.devices()) == 2, jax.devices()
    from repro.core import make_agent, reset_fleet_states, run_online_fleet
    from repro.checkpoint.fleet import FleetCheckpoint
    from repro.dsdps import SchedulingEnv, apps, scenarios
    from repro.dsdps.apps import default_workload
    from repro.launch.mesh import make_fleet_mesh, make_host_mesh

    topo = apps.continuous_queries("small")
    env = SchedulingEnv(topo, default_workload(topo))
    agent = make_agent("ddpg", env, k_nn=4)
    F, T = 2, 4
    params = scenarios.build("one_slow_machine", env, F,
                             broadcast_invariant=True)
    states = agent.init_fleet(jax.random.PRNGKey(0), F, env_params=params,
                              env=env)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    _, h_v = run_online_fleet(keys, env, agent, states, T=T,
                              env_params=params)
    mesh = make_fleet_mesh()
    assert mesh.devices.size == 2
    with tempfile.TemporaryDirectory() as d:
        ck = FleetCheckpoint(d, every=2)
        _, h_m = run_online_fleet(keys, env, agent, states, T=T,
                                  env_params=params, mesh=mesh,
                                  checkpoint=ck)
        ck.wait()
        # lane equivalence under real 2-way sharding
        np.testing.assert_array_equal(h_m.moved, h_v.moved)
        np.testing.assert_array_equal(h_m.final_assignment,
                                      h_v.final_assignment)
        np.testing.assert_allclose(h_m.rewards, h_v.rewards,
                                   rtol=1e-5, atol=1e-5)
        # elastic restore: checkpoint written under the 2-device mesh
        # re-places against the 1-device host mesh
        like_env = reset_fleet_states(keys, env, params)
        ep, st, es, ks = ck.restore(states, like_env, keys,
                                    mesh=make_host_mesh())
        assert ep == T
        run_online_fleet(ks, env, agent, st, T=2, env_params=params,
                         env_states=es, mesh=make_host_mesh())
        ck.close()
    # a fleet that does not divide the data axis fails loudly
    keys3 = jax.random.split(jax.random.PRNGKey(2), 3)
    states3 = agent.init_fleet(jax.random.PRNGKey(3), 3)
    try:
        run_online_fleet(keys3, env, agent, states3, T=2, mesh=mesh)
        raise SystemExit("expected ValueError for indivisible fleet")
    except ValueError as e:
        assert "does not divide" in str(e)
    print("TWO_DEVICE_OK")
""")


def test_two_device_sharding_subprocess(small_env):
    """Real multi-device coverage on CPU: force 2 host devices in a
    subprocess, shard a fleet over them, and pin lane equivalence plus the
    cross-device-count elastic restore."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2")
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _TWO_DEVICE_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "TWO_DEVICE_OK" in out.stdout
