"""Fleet checkpoint/resume (checkpoint/fleet.py + the chunked runner).

The ISSUE-4 acceptance gate: a fleet run killed mid-training resumes from
the latest atomic checkpoint and reaches the same final epoch — with the
SAME trajectory and final agent states — as an uninterrupted run with the
same checkpoint cadence, on the host mesh."""
import jax
import numpy as np
import pytest

from repro.checkpoint.fleet import FleetCheckpoint
from repro.core import make_agent, reset_fleet_states
from repro.core.agent import run_online_fleet
from repro.dsdps import SchedulingEnv, apps, scenarios
from repro.dsdps.apps import default_workload
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def small_env():
    topo = apps.continuous_queries("small")
    return SchedulingEnv(topo, default_workload(topo))


@pytest.fixture(scope="module")
def ddpg_agent(small_env):
    return make_agent("ddpg", small_env, k_nn=4)


@pytest.fixture(scope="module")
def fleet_inputs(small_env, ddpg_agent):
    F = 3
    states = ddpg_agent.init_fleet(jax.random.PRNGKey(0), F)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    return F, states, keys


def _trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_cadence_and_epoch_tagging(tmp_path, small_env, ddpg_agent,
                                        fleet_inputs):
    env, agent = small_env, ddpg_agent
    _, states, keys = fleet_inputs
    ck = FleetCheckpoint(tmp_path, every=4, keep=10)
    run_online_fleet(keys, env, agent, states, T=10, checkpoint=ck)
    ck.wait()
    # chunk boundaries at 4, 8 and the final partial chunk at 10
    assert ck.all_epochs() == [4, 8, 10]
    assert ck.latest_epoch() == 10
    ck.close()


def test_kill_and_resume_bitmatches_uninterrupted(tmp_path, small_env,
                                                  ddpg_agent, fleet_inputs):
    env, agent = small_env, ddpg_agent
    _, states0, keys0 = fleet_inputs
    T, every = 12, 4

    # uninterrupted reference (same checkpoint cadence => same chunking)
    ck_a = FleetCheckpoint(tmp_path / "a", every=every)
    s_ref, h_ref = run_online_fleet(keys0, env, agent, states0, T=T,
                                    checkpoint=ck_a)
    ck_a.close()

    # "crash" after 8 of 12 epochs
    ck_b = FleetCheckpoint(tmp_path / "b", every=every)
    run_online_fleet(keys0, env, agent, states0, T=8, checkpoint=ck_b)
    ck_b.close()         # process dies; checkpoints are already on disk

    # new process: fresh FleetCheckpoint over the same directory
    ck_b2 = FleetCheckpoint(tmp_path / "b", every=every)
    like_env = reset_fleet_states(keys0, env)
    epoch, states, env_states, keys = ck_b2.restore(states0, like_env, keys0)
    assert epoch == 8
    s_res, h_res = run_online_fleet(keys, env, agent, states, T=T - epoch,
                                    env_states=env_states, checkpoint=ck_b2,
                                    start_epoch=epoch)
    ck_b2.wait()
    # resumed run reaches the same final epoch with the same trajectory
    assert ck_b2.latest_epoch() == T
    np.testing.assert_array_equal(h_res.rewards, h_ref.rewards[:, epoch:])
    np.testing.assert_array_equal(h_res.moved, h_ref.moved[:, epoch:])
    np.testing.assert_array_equal(h_res.final_assignment,
                                  h_ref.final_assignment)
    _trees_equal(s_res, s_ref)
    ck_b2.close()


def test_chunked_run_matches_single_scan(tmp_path, small_env, ddpg_agent,
                                         fleet_inputs):
    """Chunking the epoch scan for checkpointing must not change the
    result: the carry threads between chunks exactly as within one scan
    (identical per-epoch body; bit-equal on CPU)."""
    env, agent = small_env, ddpg_agent
    _, states, keys = fleet_inputs
    ck = FleetCheckpoint(tmp_path, every=5)
    s_c, h_c = run_online_fleet(keys, env, agent, states, T=12, checkpoint=ck)
    ck.close()
    s_u, h_u = run_online_fleet(keys, env, agent, states, T=12)
    np.testing.assert_array_equal(h_c.rewards, h_u.rewards)
    np.testing.assert_array_equal(h_c.final_assignment, h_u.final_assignment)
    _trees_equal(s_c, s_u)


def test_scenario_fleet_checkpoint_roundtrip(tmp_path, small_env, ddpg_agent):
    """Heterogeneous-scenario carries (broadcast-invariant params lanes)
    survive the save→restore roundtrip bit-for-bit, and restore re-places
    leaves against a mesh when asked (elastic path, host mesh here)."""
    env, agent = small_env, ddpg_agent
    F = 2
    params = scenarios.build("one_slow_machine", env, F,
                             broadcast_invariant=True)
    states = agent.init_fleet(jax.random.PRNGKey(2), F, env_params=params,
                              env=env)
    keys = jax.random.split(jax.random.PRNGKey(3), F)
    ck = FleetCheckpoint(tmp_path, every=3, use_async=False)
    s_out, _ = run_online_fleet(keys, env, agent, states, T=3,
                                env_params=params, checkpoint=ck)
    like_env = reset_fleet_states(keys, env, params)
    epoch, r_states, r_env, r_keys = ck.restore(states, like_env, keys,
                                                mesh=make_host_mesh())
    assert epoch == 3
    _trees_equal(r_states, s_out)
    for leaf in jax.tree.leaves(r_states):
        assert isinstance(leaf, jax.Array)    # re-placed on the mesh


def test_graph_policy_structural_fleet_checkpoint_roundtrip(tmp_path):
    """graph_policy's nested graph-param pytree ({"gnn": {enc, mp*,
    head}} dicts + eligibility traces + the Welford normalizer) survives
    save → restore bit-for-bit on a STRUCTURAL fleet — heterogeneous DAG
    lanes checkpoint exactly like flat-vector agents, and the restored
    run continues bit-identically to an uninterrupted one."""
    from repro.dsdps.structural import StructuralSchedulingEnv
    env = StructuralSchedulingEnv(apps.structural_topologies())
    F, T, every = 2, 6, 3
    params = scenarios.build_for(env, "dag_shapes", F)
    agent = make_agent("graph_policy", env)
    states = agent.init_fleet(jax.random.PRNGKey(2), F, env_params=params,
                              env=env)
    keys = jax.random.split(jax.random.PRNGKey(3), F)
    s_ref, h_ref = run_online_fleet(keys, env, agent, states, T=T,
                                    env_params=params)
    ck = FleetCheckpoint(tmp_path, every=every, use_async=False)
    s_out, _ = run_online_fleet(keys, env, agent, states, T=every,
                                env_params=params, checkpoint=ck)
    like_env = reset_fleet_states(keys, env, params)
    epoch, r_states, r_env, r_keys = ck.restore(states, like_env, keys,
                                                mesh=make_host_mesh())
    assert epoch == every
    _trees_equal(r_states, s_out)
    s_res, h_res = run_online_fleet(r_keys, env, agent, r_states, T=T - epoch,
                                    env_params=params, env_states=r_env,
                                    start_epoch=epoch)
    np.testing.assert_array_equal(np.asarray(h_res.rewards),
                                  np.asarray(h_ref.rewards)[:, epoch:])
    _trees_equal(s_res, s_ref)


def test_overlapped_save_survives_buffer_deletion(tmp_path):
    """The overlapped transfer path must snapshot on-device BEFORE the
    caller's next donating dispatch can invalidate the carries: deleting
    the original buffer right after save_async (what donation does on
    accelerator meshes) must not corrupt or fail the background write."""
    import time

    import jax.numpy as jnp

    from repro.checkpoint.checkpointer import AsyncCheckpointer

    ck = AsyncCheckpointer(tmp_path)
    assert ck.overlap_transfer
    x = jnp.arange(8.0)
    orig_write = ck._write

    def slow_write(*a, **k):           # deletion wins the race every time
        time.sleep(0.2)
        return orig_write(*a, **k)

    ck._write = slow_write
    ck.save_async(1, {"x": x})
    x.delete()                         # donation's effect on the original
    ck.wait()                          # raises if the worker saw a dead buf
    out = ck.restore({"x": jnp.zeros(8)}, step=1)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(8.0))
    ck.close()


def test_restore_empty_dir_raises(tmp_path, small_env, ddpg_agent,
                                  fleet_inputs):
    _, states, keys = fleet_inputs
    ck = FleetCheckpoint(tmp_path, every=2, use_async=False)
    like_env = reset_fleet_states(keys, small_env)
    with pytest.raises(FileNotFoundError):
        ck.restore(states, like_env, keys)
    with pytest.raises(ValueError):
        FleetCheckpoint(tmp_path, every=0)
