"""Sharding policy: every param/cache spec must be divisibility-valid for
every architecture on the production mesh shapes (no 512 host devices
needed — PartitionSpec construction is pure)."""
import numpy as np
import pytest

import jax
from repro.configs import ARCH_IDS, get_config
from repro.treepath import keystr_path
from repro.models import lm
from repro.models.config import ModelConfig


class FakeMesh:
    """Duck-typed mesh: .axis_names / .shape only (policy never touches
    devices when building PartitionSpecs)."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.shape = dict(zip(names, shape))


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
@pytest.mark.parametrize("mesh_shape,names", [
    ((16, 16), ("data", "model")),
    ((2, 16, 16), ("pod", "data", "model")),
])
def test_param_specs_divisible(arch_id, mesh_shape, names):
    from repro.sharding.policy import ShardingPolicy
    cfg = get_config(arch_id)
    mesh = FakeMesh(mesh_shape, names)
    policy = ShardingPolicy.__new__(ShardingPolicy)
    policy.mesh = mesh
    policy.cfg = cfg
    policy.fsdp = True
    from repro.sharding.policy import MeshAxes
    policy.axes = MeshAxes(dp=tuple(n for n in names if n != "model"))
    policy.dp_size = _axis_size(mesh, policy.axes.dp)
    policy.tp_size = _axis_size(mesh, "model")

    abstract = lm.abstract_params(cfg)
    specs = policy.params_tree(abstract)

    flat_p = jax.tree_util.tree_flatten_with_path(abstract)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "_normalized_spec")
                             or x.__class__.__name__ == "PartitionSpec")
    assert len(flat_p) == len(flat_s)
    n_sharded = 0
    for (kp, leaf), spec in zip(flat_p, flat_s):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            n_sharded += 1
            size = _axis_size(mesh, ax)
            path = jax.tree_util.keystr(kp)
            assert dim % size == 0, \
                f"{arch_id} {path}: dim {dim} not divisible by {ax}={size}"
    # the policy must actually shard the bulk of the model
    assert n_sharded > 10, f"{arch_id}: almost nothing sharded"


@pytest.mark.parametrize("arch_id", ["llama3-8b", "jamba-1.5-large-398b",
                                     "rwkv6-7b", "seamless-m4t-medium"])
def test_cache_specs_divisible(arch_id):
    from repro.sharding.policy import MeshAxes, ShardingPolicy
    cfg = get_config(arch_id)
    mesh = FakeMesh((16, 16), ("data", "model"))
    policy = ShardingPolicy.__new__(ShardingPolicy)
    policy.mesh, policy.cfg = mesh, cfg
    policy.axes = MeshAxes(dp=("data",))
    policy.dp_size, policy.tp_size = 16, 16

    cache = jax.eval_shape(lambda: lm.init_cache(
        cfg, batch=128, max_seq=4096,
        enc_len=1024 if cfg.family == "encdec" else 0))
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    for kp, leaf in flat:
        path = keystr_path(kp, separator="/")
        spec = policy.cache_spec(path, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            assert dim % _axis_size(mesh, ax) == 0, (arch_id, path, dim, ax)


def test_fallbacks_kick_in():
    """granite: 40 experts unsplittable by 16 -> expert d_ff TP'd instead
    of EP; jamba: 16 experts -> true EP; yi: 56 kv/q heads unsplittable ->
    the *cache* falls back to sequence sharding and the activation
    constraint leaves the head axis unsharded (params still shard the
    flattened head dim, which is 16-divisible)."""
    from repro.sharding.policy import MeshAxes, ShardingPolicy
    mesh = FakeMesh((16, 16), ("data", "model"))

    def mk(cfg):
        p = ShardingPolicy.__new__(ShardingPolicy)
        p.mesh, p.cfg = mesh, cfg
        p.fsdp = True
        p.axes = MeshAxes(dp=("data",))
        p.dp_size, p.tp_size = 16, 16
        return p

    gr = mk(get_config("granite-moe-3b-a800m"))
    spec = gr.param_spec("layers/pos0/ffn/gate", (32, 40, 1536, 512))
    assert tuple(spec)[1] is None                      # experts NOT sharded
    assert "model" in tuple(spec)                      # ...but d_ff TP'd

    ja = mk(get_config("jamba-1.5-large-398b"))
    spec = ja.param_spec("layers/pos1/ffn/gate", (9, 16, 8192, 24576))
    assert tuple(spec)[1] == "model"                   # true EP: 16 experts

    yi = mk(get_config("yi-34b"))
    spec = yi.param_spec("layers/pos0/mixer/wq/w", (60, 7168, 7168))
    assert "model" in tuple(spec)                      # params still TP'd
    # kv heads (8) unsplittable by 16 -> cache sequence-sharded instead
    cspec = yi.cache_spec("pos0/k", (60, 128, 32768, 8, 128))
    assert tuple(cspec)[2] == "model" and tuple(cspec)[3] is None
