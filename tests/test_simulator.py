"""DSDPS queueing-simulator invariants (property-based where sensible)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.dsdps import SchedulingEnv, apps
from repro.dsdps.apps import default_workload
from repro.dsdps.simulator import average_tuple_time_ms


@pytest.fixture(scope="module")
def env():
    topo = apps.continuous_queries("small")
    return SchedulingEnv(topo, default_workload(topo))


def test_latency_positive_and_finite(env):
    w = env.workload.init()
    lat = env.evaluate(env.round_robin_assignment(), w)
    assert 0.1 < float(lat) < 1e3


@settings(max_examples=15, deadline=None)
@given(st.floats(0.5, 1.8))
def test_latency_monotone_in_workload(factor):
    topo = apps.continuous_queries("small")
    env = SchedulingEnv(topo, default_workload(topo))
    w = env.workload.init()
    X = env.round_robin_assignment()
    base = float(env.evaluate(X, w))
    scaled = float(env.evaluate(X, w * factor))
    if factor >= 1.0:
        assert scaled >= base - 1e-6
    else:
        assert scaled <= base + 1e-6


def test_straggler_increases_latency(env):
    w = env.workload.init()
    X = env.round_robin_assignment()
    speed = jnp.asarray(env.cluster.speed_factors())
    base = float(env.evaluate(X, w, speed=speed))
    slow = float(env.evaluate(X, w, speed=speed.at[0].set(0.3)))
    assert slow > base


def test_default_multi_process_overhead(env):
    """Storm's default (many worker processes/machine) must be slower than
    the same machine assignment with one process per machine — the paper's
    inter-process-traffic effect [52]."""
    w = env.workload.init()
    Xd, same_proc, n_procs = env.storm_default_assignment()
    default = float(env.evaluate(Xd, w, same_proc=same_proc, n_procs=n_procs))
    one_proc = float(env.evaluate(Xd, w))
    assert default > one_proc


def test_flow_conservation(env):
    """Executor arrival rates solve λ = w + Rᵀλ."""
    p = env.params
    w = env.workload.init()
    n = env.N
    w_full = np.zeros(n)
    w_full[p.spout_ids] = np.asarray(w)
    lam = p.flow_solve @ w_full
    np.testing.assert_allclose(lam, w_full + p.routing.T @ lam,
                               rtol=1e-8, atol=1e-8)


def test_reward_is_negative_latency(env):
    key = jax.random.PRNGKey(0)
    s = env.reset(key)
    out = env.step(key, s, env.round_robin_assignment())
    assert float(out.reward) == pytest.approx(-float(out.latency_ms))


def test_step_counts_moved_executors(env):
    key = jax.random.PRNGKey(0)
    s = env.reset(key)
    out = env.step(key, s, s.X)
    assert int(out.moved) == 0
    X2 = s.X.at[0].set(jnp.roll(s.X[0], 1))
    out2 = env.step(key, s, X2)
    assert int(out2.moved) == 1


def test_noise_measurement_averages(env):
    key = jax.random.PRNGKey(1)
    w = env.workload.init()
    X = env.round_robin_assignment()
    exact = float(env.evaluate(X, w))
    from repro.dsdps.simulator import measured_latency_ms
    speed = jnp.asarray(env.cluster.speed_factors())
    samples = [float(measured_latency_ms(jax.random.fold_in(key, i), X, w,
                                         env.params, env.cluster, speed))
               for i in range(30)]
    assert abs(np.mean(samples) - exact) / exact < 0.05


def test_all_paper_topologies_build():
    for name, fn in apps.ALL_APPS.items():
        topo = fn()
        env = SchedulingEnv(topo, default_workload(topo))
        w = env.workload.init()
        lat = float(env.evaluate(env.round_robin_assignment(), w))
        assert np.isfinite(lat) and lat > 0
    # paper executor counts
    assert apps.continuous_queries("small").num_executors == 20
    assert apps.continuous_queries("medium").num_executors == 50
    assert apps.continuous_queries("large").num_executors == 100
    assert apps.log_stream_processing().num_executors == 100
    assert apps.word_count().num_executors == 100
