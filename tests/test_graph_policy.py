"""graph_policy + StructuralSchedulingEnv: the padding-exactness and
structure-as-data contracts.

What makes the structural fleet trustworthy is that padding is INERT —
not approximately, bit-for-bit:

  * the same topology padded into a larger envelope yields identical
    network params (shapes depend only on per-node widths), identical
    greedy moves, and identical evaluated latency;
  * the envelope-padded latency model agrees with the plain
    ``SchedulingEnv`` model on the same topology;
  * a structural fleet lane bit-matches the equivalent single run
    (the lane-bitmatch pattern from tests/test_fleet_runner.py);
  * one XLA program serves every DAG shape: two fleet runs over three
    heterogeneous topologies compile the fleet program exactly once;
  * a too-small envelope raises a ValueError naming the topology —
    never a silently truncated observation (the ``build_for``
    envelope-aware dispatch regression).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import make_agent, run_online_agent, run_online_fleet
from repro.core.graph_policy import graph_param_specs
from repro.dsdps import SchedulingEnv, apps, scenarios
from repro.dsdps.apps import default_workload
from repro.dsdps.simulator import lane_params
from repro.dsdps.structural import Envelope, StructuralSchedulingEnv
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def topo():
    return apps.continuous_queries("small")


@pytest.fixture(scope="module")
def tight_env(topo):
    return StructuralSchedulingEnv([topo])     # auto (exact) envelope


@pytest.fixture(scope="module")
def padded_env(topo):
    return StructuralSchedulingEnv(
        [topo], envelope=Envelope(max_execs=29, max_edges=151, max_spouts=5,
                                  max_components=8))


@pytest.fixture(scope="module")
def structural_env():
    return StructuralSchedulingEnv(apps.structural_topologies())


# -- padding invariance ------------------------------------------------------
def test_init_params_identical_across_envelopes(tight_env, padded_env):
    """Param shapes depend only on per-node feature widths, so the same
    key draws the same network at ANY envelope."""
    a_t = make_agent("graph_policy", tight_env)
    a_p = make_agent("graph_policy", padded_env)
    st_t = a_t.init(jax.random.PRNGKey(0))
    st_p = a_p.init(jax.random.PRNGKey(0))
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y),
                 st_t.qnet, st_p.qnet)


def test_greedy_select_bit_invariant_under_padding(tight_env, padded_env,
                                                   topo):
    a_t = make_agent("graph_policy", tight_env)
    a_p = make_agent("graph_policy", padded_env)
    st_t = a_t.init(jax.random.PRNGKey(0))
    st_p = a_p.init(jax.random.PRNGKey(0))
    p_t, p_p = tight_env.default_params(), padded_env.default_params()
    e_t = tight_env.reset(jax.random.PRNGKey(1), p_t)
    e_p = padded_env.reset(jax.random.PRNGKey(1), p_p)
    key = jax.random.PRNGKey(2)
    act_t, aux_t = a_t.select(key, st_t, tight_env.state_vector(e_t, p_t),
                              e_t, p_t, explore=False)
    act_p, aux_p = a_p.select(key, st_p, padded_env.state_vector(e_p, p_p),
                              e_p, p_p, explore=False)
    n = topo.num_executors
    # the flat move index i*M + j is envelope-independent (row-major over
    # real executors), so greedy moves agree bit-for-bit
    assert int(aux_t[0]) == int(aux_p[0])
    np.testing.assert_array_equal(np.asarray(act_t[:n]),
                                  np.asarray(act_p[:n]))
    assert (np.asarray(act_p[n:]) == 0.0).all()


def test_padded_latency_matches_plain_env(topo, tight_env, padded_env):
    plain = SchedulingEnv(topo, default_workload(topo))
    X = plain.round_robin_assignment()
    w = jnp.asarray(plain.workload.init())
    ref = float(plain.evaluate(X, w))
    for env in (tight_env, padded_env):
        n, s = topo.num_executors, len(topo.spout_executors)
        X_pad = jnp.zeros((env.N, env.M)).at[:n].set(X)
        w_pad = jnp.zeros((env.envelope.max_spouts,)).at[:s].set(w)
        np.testing.assert_allclose(float(env.evaluate(X_pad, w_pad)), ref,
                                   rtol=1e-6)


def test_structural_default_params_reject_too_small_envelope(topo):
    small = StructuralSchedulingEnv(
        [topo], envelope=Envelope(max_execs=topo.num_executors - 1,
                                  max_edges=500, max_spouts=4,
                                  max_components=6))
    with pytest.raises(ValueError, match=topo.name):
        small.params_for(topo)


def test_dag_shapes_scenario_requires_structural_env(topo):
    plain = SchedulingEnv(topo, default_workload(topo))
    with pytest.raises(TypeError, match="StructuralSchedulingEnv"):
        scenarios.build_for(plain, "dag_shapes", 3)


# -- the structural fleet ----------------------------------------------------
def test_structural_fleet_lane_bitmatch(structural_env):
    """Fleet lane i over DAG i bit-matches the single run with the same
    key, state, and params lane — padding and heterogeneous structure
    change nothing about the trajectory."""
    env = structural_env
    F, T = 3, 5
    params = scenarios.build_for(env, "dag_shapes", F)
    agent = make_agent("graph_policy", env)
    states = agent.init_fleet(jax.random.PRNGKey(0), F, env_params=params,
                              env=env)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    _, hist = run_online_fleet(keys, env, agent, states, T=T,
                               env_params=params)
    assert np.asarray(hist.rewards).shape == (F, T)
    for i in range(F):
        st_i = jax.tree.map(lambda x, i=i: x[i], states)
        lane_p = lane_params(params, env.default_params(), i)
        _, h1 = run_online_agent(keys[i], env, agent, st_i, T=T,
                                 env_params=lane_p)
        np.testing.assert_array_equal(np.asarray(hist.rewards[i]),
                                      np.asarray(h1.rewards))
        np.testing.assert_array_equal(np.asarray(hist.latencies[i]),
                                      np.asarray(h1.latencies))
        np.testing.assert_array_equal(np.asarray(hist.moved[i]),
                                      np.asarray(h1.moved))
        np.testing.assert_array_equal(np.asarray(hist.final_assignment[i]),
                                      np.asarray(h1.final_assignment))


def test_structural_fleet_compiles_once(structural_env):
    """Two runs over three heterogeneous DAG shapes: ONE fleet-program
    compile — topology structure rides as traced GraphEnvParams leaves,
    not static shapes."""
    from repro.core import agent as agent_mod
    from repro.diagnostics import guards
    env = structural_env
    F, T = 3, 4
    params = scenarios.build_for(env, "dag_shapes", F)
    agent = make_agent("graph_policy", env)
    states = agent.init_fleet(jax.random.PRNGKey(0), F, env_params=params,
                              env=env)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    with guards(track=(agent_mod._fleet_program,),
                label="test_graph_compile_once") as g:
        run_online_fleet(keys, env, agent, states, T=T, env_params=params)
        run_online_fleet(keys, env, agent, states, T=T, env_params=params)
    assert g.counter.compiles == 1


def test_structural_fleet_on_host_mesh_bitmatches_vmap(structural_env):
    env = structural_env
    F, T = 3, 4
    params = scenarios.build_for(env, "dag_shapes", F)
    agent = make_agent("graph_policy", env)
    states = agent.init_fleet(jax.random.PRNGKey(0), F, env_params=params,
                              env=env)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    _, h_vmap = run_online_fleet(keys, env, agent, states, T=T,
                                 env_params=params)
    _, h_mesh = run_online_fleet(keys, env, agent, states, T=T,
                                 env_params=params, mesh=make_host_mesh())
    np.testing.assert_array_equal(np.asarray(h_vmap.rewards),
                                  np.asarray(h_mesh.rewards))


def test_structural_env_moved_ignores_padded_rows(structural_env):
    """`moved` counts real executors only: flipping a padded row of the
    action must not register as a move (and must not change latency)."""
    env = structural_env
    topo = env.topologies[1]                       # diamond: n < envelope
    p = env.params_for(topo)
    n = topo.num_executors
    assert n < env.N
    state = env.reset(jax.random.PRNGKey(0), p)
    action = state.X.at[n, 0].set(1.0)             # "move" a padded exec
    key = jax.random.PRNGKey(1)
    out_pad = env.step(key, state, action, p)
    out_same = env.step(key, state, state.X, p)
    assert float(out_pad.moved) == 0.0
    np.testing.assert_array_equal(np.asarray(out_pad.latency_ms),
                                  np.asarray(out_same.latency_ms))


# -- sharding: the first non-degenerate "model"-axis agent -------------------
def test_graph_param_specs_partition_gnn_over_model_axis():
    topo = apps.continuous_queries("small")
    env = StructuralSchedulingEnv([topo])
    agent = make_agent("graph_policy", env)
    state = agent.init(jax.random.PRNGKey(0))
    specs = graph_param_specs(state.qnet, make_host_mesh())
    gnn = specs["gnn"]
    # matrices tensor-parallelize over "model"; bias vectors too (the
    # head's out dim is n_machines); nothing shards over the data axes
    assert gnn["enc"]["w"] == P(None, "model")
    assert gnn["head"]["w"] == P(None, "model")
    assert gnn["head"]["b"] == P("model")
    for t in (0, 1):
        for k in ("self", "fwd", "bwd"):
            assert gnn[f"mp{t}"][k]["w"] == P(None, "model")
    for spec in jax.tree.leaves(specs,
                                is_leaf=lambda x: isinstance(x, P)):
        assert "data" not in jax.tree.leaves(spec)
