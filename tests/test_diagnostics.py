"""Runtime tracing-discipline guards (repro.diagnostics).

Covers the jit-cache-miss sentinel (CompileCounter), the guards() bundle
(transfer guard + counter + NaN sweeps), and the acceptance contract: the
host-mesh ``run_online_fleet`` epoch step compiles EXACTLY ONCE across a
4-lane heterogeneous (per-lane scenario params) fleet, and repeat runs
with the same statics compile zero times."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agent as agent_mod
from repro.core import ddpg, make_agent
from repro.core.agent import run_online_fleet
from repro.core.ddpg import DDPGConfig
from repro.diagnostics import (CompileCounter, NonFiniteError, active,
                               guards, maybe_check_finite)
from repro.dsdps import SchedulingEnv, apps, scenarios
from repro.dsdps.apps import default_workload
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def small_env():
    topo = apps.continuous_queries("small")
    return SchedulingEnv(topo, default_workload(topo))


@pytest.fixture(scope="module")
def ddpg_agent(small_env):
    cfg = DDPGConfig(n_executors=small_env.N, n_machines=small_env.M,
                     state_dim=small_env.state_dim, k_nn=4)
    return make_agent("ddpg", small_env, cfg=cfg)


def _fleet(small_env, ddpg_agent, F):
    states = ddpg.init_fleet(jax.random.PRNGKey(0), ddpg_agent.cfg, F)
    keys = jax.random.split(jax.random.PRNGKey(1), F)
    return keys, states


# --------------------------------------------------------------------------
# CompileCounter
# --------------------------------------------------------------------------
def test_compile_counter_counts_cache_misses():
    @jax.jit
    def f(x):
        return x * 2

    f(jnp.arange(3))                      # warm an unrelated shape
    with CompileCounter(f) as cc:
        f(jnp.arange(3))                  # cached: no miss
        assert cc.compiles == 0
        f(jnp.arange(5))                  # new shape: one miss
        assert cc.compiles == 1
        f(jnp.arange(5))
    assert cc.compiles == 1               # readable after exit
    assert cc.per_target() == {"f": 1}


def test_compile_counter_assertions():
    @jax.jit
    def g(x):
        return x + 1

    cc = CompileCounter(g, label="unit").start()
    g(jnp.arange(4))
    cc.assert_compiles(1)
    cc.assert_compiles(3, at_most=True)
    with pytest.raises(AssertionError, match="jit-cache-miss sentinel"):
        cc.assert_compiles(0)
    with pytest.raises(RuntimeError, match="not started"):
        CompileCounter(g).compiles


def test_compile_counter_tolerates_plain_callables():
    cc = CompileCounter(lambda x: x).start()
    assert cc.compiles == 0               # no _cache_size: tracked as zero


# --------------------------------------------------------------------------
# guards() bundle
# --------------------------------------------------------------------------
def test_guards_blocks_implicit_transfers_allows_explicit_pulls():
    dev = jnp.arange(4.0)
    with guards(nan_check=False):
        assert np.asarray(dev).sum() == 6.0      # explicit d2h: legal
        with pytest.raises(Exception, match="[Dd]isallowed"):
            jnp.ones(3)                          # implicit fill h2d: blocked
    jnp.ones(3)                                  # guard lifted on exit


def test_guards_state_is_scoped():
    assert active() is None
    with guards(nan_check=True) as g:
        assert active() is g
    assert active() is None


def test_maybe_check_finite_noop_outside_guards():
    maybe_check_finite({"x": jnp.array([np.nan])}, "nowhere")  # no raise


def test_maybe_check_finite_raises_and_names_leaf():
    tree = {"ok": jnp.ones(3), "boom": jnp.array([1.0, np.inf, np.nan])}
    with guards(nan_check=True) as g:
        with pytest.raises(NonFiniteError, match="boom"):
            maybe_check_finite(tree, "epoch 7")
    assert any("epoch 7" in rec for rec in g.nonfinite)
    # int leaves never trip the sweep
    with guards(nan_check=True):
        maybe_check_finite({"i": jnp.arange(3)}, "ints")


# --------------------------------------------------------------------------
# Acceptance: one compilation per fleet program, heterogeneous 4-lane fleet
# --------------------------------------------------------------------------
def test_host_mesh_epoch_step_compiles_exactly_once(small_env, ddpg_agent):
    """4-lane heterogeneous fleet on the host mesh: the sharded fleet
    program compiles exactly once for the whole run, and a second run
    with the same statics compiles zero times."""
    env = small_env
    F = 4
    env_params = scenarios.build_for(env, "mixed", F)
    mesh = make_host_mesh()
    keys, states = _fleet(env, ddpg_agent, F)
    with guards(track=(agent_mod._fleet_program_sharded,)) as g:
        _, hist = run_online_fleet(keys, env, ddpg_agent, states, T=3,
                                   env_params=env_params, mesh=mesh)
    assert hist.rewards.shape == (F, 3)
    g.counter.assert_compiles(1)
    # warm cache: an identical run must not compile at all
    with guards(track=(agent_mod._fleet_program_sharded,)) as g2:
        run_online_fleet(keys, env, ddpg_agent, states, T=3,
                         env_params=env_params, mesh=mesh)
    g2.counter.assert_compiles(0)


def test_unsharded_chunked_run_compile_ceiling(small_env, ddpg_agent):
    """Plain vmap path, chunked by a checkpoint cadence: at most one
    compilation per distinct chunk length (T=5, every=3 -> chunks 3+2)."""
    env = small_env
    keys, states = _fleet(env, ddpg_agent, 4)

    class Cadence:                        # checkpoint stub: cadence only
        every = 3

        def save(self, *a, **k):
            pass

    with guards(track=(agent_mod._fleet_program,)) as g:
        run_online_fleet(keys, env, ddpg_agent, states, T=5,
                         checkpoint=Cadence())
    g.counter.assert_compiles(2, at_most=True)
