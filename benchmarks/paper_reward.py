"""Paper Figs 7/9/11: normalized + smoothed reward over online learning
for actor-critic vs DQN (large-scale topologies), seed-averaged over the
fleet (mean curve ± std band across budget.n_seeds independent runs).

  python -m benchmarks.paper_reward --app cq_large [--epochs 400]
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from benchmarks.paper_common import Budget, make_env, run_actor_critic, run_dqn

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "paper"


def run(app: str, budget: Budget, seed: int = 0) -> dict:
    env = make_env(app)
    _, dqn_hist = run_dqn(env, budget, seed, deploy=False)
    _, ac_hist, _ = run_actor_critic(env, budget, seed, deploy=False)
    dqn_mean, dqn_std = dqn_hist.seed_band()
    ac_mean, ac_std = ac_hist.seed_band()
    out = {
        "app": app,
        "epochs": budget.online_epochs,
        "n_seeds": budget.n_seeds,
        "dqn_smoothed_mean": dqn_mean.tolist(),
        "dqn_smoothed_std": dqn_std.tolist(),
        "ac_smoothed_mean": ac_mean.tolist(),
        "ac_smoothed_std": ac_std.tolist(),
    }
    last = max(len(ac_mean) // 5, 1)
    out["ac_final_avg"] = float(np.mean(ac_mean[-last:]))
    out["dqn_final_avg"] = float(np.mean(dqn_mean[-last:]))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="cq_large")
    ap.add_argument("--epochs", type=int, default=0)
    ap.add_argument("--paper-budget", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    budget = Budget.paper() if args.paper_budget else Budget.quick()
    if args.epochs:
        import dataclasses
        budget = dataclasses.replace(budget, online_epochs=args.epochs)
    out = run(args.app, budget, args.seed)
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"reward_{args.app}.json").write_text(json.dumps(out))
    print(f"[{args.app}] final smoothed reward: "
          f"actor-critic {out['ac_final_avg']:.3f} vs "
          f"DQN {out['dqn_final_avg']:.3f} "
          f"(paper Fig 7: AC climbs above DQN's ~0.44)")


if __name__ == "__main__":
    main()
