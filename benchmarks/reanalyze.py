"""Re-run the corrected HLO analysis over stored .hlo.zst dumps and patch
the dry-run JSON artifacts in place (used after analyzer improvements)."""
import json
import pathlib
import sys

import zstandard as zstd

from benchmarks.hlo_analysis import analyze

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def main() -> None:
    hlo_dir = ART / "hlo"
    n = 0
    for jf in sorted(ART.glob("*.json")):
        rec = json.loads(jf.read_text())
        if rec.get("status") != "ok":
            continue
        name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        if rec.get("overrides"):
            name += "__" + "-".join(f"{k}={v}" for k, v in
                                    sorted(rec["overrides"].items()))
        hf = hlo_dir / f"{name}.hlo.zst"
        if not hf.exists():
            print(f"missing HLO for {jf.name}", file=sys.stderr)
            continue
        text = zstd.ZstdDecompressor().decompress(
            hf.read_bytes(), max_output_size=1 << 31).decode()
        rec["corrected"] = analyze(text)
        jf.write_text(json.dumps(rec, indent=2))
        n += 1
    print(f"re-analyzed {n} artifacts")


if __name__ == "__main__":
    main()
