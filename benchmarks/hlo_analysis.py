"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts each ``while`` body
ONCE, so scanned programs (microbatch scan × layer scan × chunked
attention/CE scans) under-report FLOPs/bytes/collectives by the product
of trip counts.  This module re-derives corrected per-device numbers from
the optimized HLO text:

  1. parse every computation and every op's result type;
  2. recover each while loop's trip count from its condition computation
     (jax scans lower to  ``compare(iter, constant(N)), direction=LT``);
  3. propagate multipliers through the call graph
     (while bodies × trip count; fusions/calls × 1);
  4. count dot FLOPs (2·|result|·K), collective wire bytes, and
     fusion-level HBM bytes, each scaled by its computation's multiplier.

Validated in tests/test_hlo_analysis.py against analytically-known
programs (scanned matmuls)."""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP = re.compile(r'known_trip_count"?:\s*\{"?n"?:"?(\d+)')
# op result type is either a tuple "(f32[..], s32[])" (may contain spaces)
# or a single token "f32[64,64]{1,0}"
_OP_LINE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALLS = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}
_COLLECTIVES = tuple(_WIRE_FACTOR)
# ops whose results are real HBM writes even on TPU (fusion roots, data
# movement, matmuls, reductions); bare elementwise ops fuse away
_HBM_OPS = frozenset({
    "dot", "fusion", "copy", "dynamic-slice", "dynamic-update-slice",
    "gather", "scatter", "reduce", "reduce-window", "sort", "concatenate",
    "pad", "slice", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute", "convolution", "cholesky",
    "triangular-solve", "rng", "custom-call",
})


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    m = _SHAPE.search(type_str)
    if not m:
        return 1
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return max(n, 1)


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    kind: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    is_fusion: bool = False


def parse_hlo(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry: str | None = None
    for line in text.splitlines():
        if not line.startswith((" ", "\t")) and "{" in line and "->" in line:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(1), [])
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if m:
            cur.ops.append(Op(m.group(1), m.group(2), m.group(3), line))
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """jax scan condition: compare(iter, constant(N)), direction=LT."""
    consts = {}
    for op in cond.ops:
        if op.kind == "constant":
            cm = _CONST.search(op.line)
            if cm:
                consts[op.name] = int(cm.group(1))
    for op in cond.ops:
        if op.kind == "compare" and "direction=LT" in op.line:
            # operands: %iter, %const  — find any known constant reference
            for cname, cval in consts.items():
                if f"%{cname}" in op.line or f"({cname}" in op.line \
                        or f" {cname})" in op.line or f"{cname}," in op.line:
                    return cval
    # fall back: any constant in the condition
    if consts:
        return max(consts.values())
    return 1


def _multipliers(comps: dict[str, Computation], entry: str) -> dict[str, float]:
    mult: dict[str, float] = defaultdict(float)

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] += m
        for op in comps[name].ops:
            refs = _CALLS.findall(op.line)
            if op.kind == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                tm = _TRIP.search(op.line)
                if tm:
                    trips = int(tm.group(1))
                else:
                    trips = _trip_count(comps[cond]) if cond and cond in comps else 1
                if body:
                    visit(body, m * trips)
                if cond:
                    visit(cond, m * (trips + 1))
            else:
                for r in refs:
                    if r != name:
                        visit(r, m)

    visit(entry, 1.0)
    return mult


_DOT_LHS_TYPE = re.compile(r"dot\(\s*([a-z0-9]+\[[0-9,]*\])")
_DOT_LHS_NAME = re.compile(r"dot\(\s*%?([\w.\-]+)")


def _dot_flops(op: Op, op_types: dict[str, str]) -> float:
    """dot: flops = 2 * |result| * prod(lhs contracting dims).

    Depending on the HLO dumper version, operands print with inline types —
    ``dot(f32[128,128]{1,0} %lhs, ...)`` — or bare, with or without the
    ``%`` sigil; prefer the inline type and fall back to a name lookup."""
    tm = _DOT_LHS_TYPE.search(op.line)
    if tm:
        lhs = tm.group(1)
    else:
        nm = _DOT_LHS_NAME.search(op.line)
        if not nm:
            return 0.0
        lhs = op_types.get(nm.group(1), "")
    lm = _SHAPE.search(lhs)
    if not lm:
        return 0.0
    lhs_dims = [int(d) for d in lm.group(2).split(",") if d]
    cm = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.line)
    k = 1
    if cm:
        for ci in cm.group(1).split(","):
            if ci and int(ci) < len(lhs_dims):
                k *= lhs_dims[int(ci)]
    return 2.0 * _type_elems(op.type_str) * k


def analyze(text: str) -> dict:
    comps, entry = parse_hlo(text)
    if entry is None:
        entry = next(iter(comps))

    # op result types across all computations (operand shape lookup)
    op_types: dict[str, str] = {}
    for c in comps.values():
        for op in c.ops:
            op_types[op.name] = op.type_str
        c.is_fusion = c.name.startswith("fused_") or "fused_computation" in c.name

    mult = _multipliers(comps, entry)

    flops = 0.0
    coll: dict[str, dict] = {}
    hbm_bytes = 0.0
    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if m == 0.0:
            continue
        for op in c.ops:
            if op.kind == "dot":
                flops += m * _dot_flops(op, op_types)
            elif op.kind in ("convolution",):
                # not used by these models; count result elems as flops proxy
                flops += m * 2.0 * _type_elems(op.type_str)
            if op.kind.removesuffix("-start") in _COLLECTIVES:
                kind = op.kind.removesuffix("-start")
                b = _type_bytes(op.type_str)
                d = coll.setdefault(kind, {"count": 0.0, "result_bytes": 0.0,
                                           "wire_bytes": 0.0})
                d["count"] += m
                d["result_bytes"] += m * b
                d["wire_bytes"] += m * b * _WIRE_FACTOR[kind]
            # HBM traffic: results materialized outside fusions.  Bare
            # elementwise/shape ops are excluded — the CPU backend leaves
            # them unfused but the TPU backend fuses elementwise chains,
            # so counting them would overstate TPU HBM traffic (validated:
            # scan-heavy models were 4-5× inflated before this filter).
            if not c.is_fusion and op.kind in _HBM_OPS:
                hbm_bytes += m * _type_bytes(op.type_str)

    return {
        "flops": flops,
        "hbm_bytes_est": hbm_bytes,
        "collectives": coll,
        "collective_wire_bytes": sum(d["wire_bytes"] for d in coll.values()),
        "num_computations": len(comps),
    }


def breakdown(text: str, top: int = 20) -> list[tuple]:
    """Per-computation flop contributions (flops, mult, name) sorted desc."""
    comps, entry = parse_hlo(text)
    if entry is None:
        entry = next(iter(comps))
    op_types = {}
    for c in comps.values():
        for op in c.ops:
            op_types[op.name] = op.type_str
    mult = _multipliers(comps, entry)
    rows = []
    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if not m:
            continue
        f = sum(_dot_flops(op, op_types) for op in c.ops if op.kind == "dot")
        if f:
            rows.append((m * f, m, f, c.name))
    rows.sort(reverse=True)
    return rows[:top]


def _dot_lines(text: str, comp_name: str) -> list[str]:
    comps, _ = parse_hlo(text)
    return [op.line.strip()[:200] for op in comps[comp_name].ops
            if op.kind == "dot"]


if __name__ == "__main__":
    import argparse as _ap
    import pathlib as _pl

    _p = _ap.ArgumentParser()
    _p.add_argument("path")
    _p.add_argument("--top", type=int, default=15)
    _p.add_argument("--dots", default="", help="print dot lines of one comp")
    _a = _p.parse_args()
    raw = _pl.Path(_a.path).read_bytes()
    if _a.path.endswith(".zst"):
        import zstandard as _z
        raw = _z.ZstdDecompressor().decompress(raw, max_output_size=1 << 31)
    text = raw.decode()
    if _a.dots:
        for ln in _dot_lines(text, _a.dots):
            print(ln)
    else:
        res = analyze(text)
        print(f"total flops {res['flops']:.4e}  "
              f"hbm {res['hbm_bytes_est']:.4e}  "
              f"wire {res['collective_wire_bytes']:.4e}")
        for tot, m, f, name in breakdown(text, _a.top):
            print(f"  {tot:12.4e} = {m:8.0f} x {f:10.3e}  {name}")
