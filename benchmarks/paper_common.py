"""Shared harness for the paper's evaluation: run all four schedulers on a
topology and report stabilized average tuple processing time (the
quantity plotted in Figs 6/8/10).

DRL methods (DQN, actor-critic) run as a seed FLEET — ``budget.n_seeds``
independent online-learning runs executed in one jitted, vmapped scan
(core/agent.run_online_fleet) — and report mean ± std across seeds, the
averaging discipline DRL-for-scheduling results need (Decima et al.)."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ModelBasedScheduler, make_agent, run_online_fleet
from repro.core import ddpg as ddpg_lib
from repro.core import dqn as dqn_lib
from repro.core.exploration import EpsilonSchedule
from repro.dsdps import SchedulingEnv, apps, lane_params
from repro.dsdps.apps import default_workload


def _lane_params(env, env_params, lane: int):
    """The EnvParams lane ``lane`` deploys under: lane ``lane`` of a stacked
    scenario fleet (broadcast-invariant stacks included), the shared params
    otherwise (default when None)."""
    p = env.default_params() if env_params is None else env_params
    return lane_params(p, env.default_params(), lane)


@dataclasses.dataclass
class Budget:
    """Training budgets.  `paper()` matches the paper's setup (10k offline
    samples, T=1500–2000 online epochs); `quick()` is CPU-benchmark scale."""
    offline_samples: int
    offline_updates: int
    online_epochs: int
    updates_per_epoch: int
    mb_samples: int
    k_nn: int = 12
    n_seeds: int = 4          # fleet width of the DRL seed sweep

    @classmethod
    def quick(cls) -> "Budget":
        return cls(offline_samples=1500, offline_updates=400,
                   online_epochs=250, updates_per_epoch=2, mb_samples=300)

    @classmethod
    def paper(cls) -> "Budget":
        return cls(offline_samples=10_000, offline_updates=3000,
                   online_epochs=2000, updates_per_epoch=1, mb_samples=400,
                   k_nn=16, n_seeds=8)

    @classmethod
    def validated(cls) -> "Budget":
        """Best stable operating point found in the tuning log (probe2/3):
        long online runs at paper scale drift (DDPG instability); 600
        epochs × 2 updates with 4k offline samples is the sweet spot on
        this simulator."""
        return cls(offline_samples=4000, offline_updates=1500,
                   online_epochs=600, updates_per_epoch=2, mb_samples=400,
                   k_nn=16, n_seeds=8)


def make_env(app: str) -> SchedulingEnv:
    topo = apps.ALL_APPS[app]()
    return SchedulingEnv(topo, default_workload(topo))


def run_default(env: SchedulingEnv) -> float:
    X, same_proc, n_procs = env.storm_default_assignment()
    w = env.workload.init()
    return float(env.evaluate(X, w, same_proc=same_proc, n_procs=n_procs))


def run_model_based(env: SchedulingEnv, budget: Budget, seed: int = 0):
    sched = ModelBasedScheduler(env).fit(jax.random.PRNGKey(seed),
                                         n_samples=budget.mb_samples)
    w = env.workload.init()
    X = sched.schedule(w, sweeps=3)
    return float(env.evaluate(X, w)), X


def run_dqn(env: SchedulingEnv, budget: Budget, seed: int = 0,
            deploy: bool = True, env_params=None):
    """Fleet of budget.n_seeds independent DQN runs in one XLA program.

    Returns (per-seed deployed latencies, stacked History); ``deploy=False``
    skips the per-seed greedy rollouts (callers that only need the reward
    histories, e.g. paper_reward) and returns an empty latency list."""
    agent = make_agent("dqn", env,
                       eps=EpsilonSchedule(
                           decay_epochs=max(budget.online_epochs * 2 // 3, 1)))
    cfg = agent.cfg
    F = budget.n_seeds
    states = agent.init_fleet(jax.random.PRNGKey(seed), F)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), F)
    states, hist = run_online_fleet(
        keys, env, agent, states, T=budget.online_epochs,
        updates_per_epoch=budget.updates_per_epoch, env_params=env_params)
    if not deploy:
        return [], hist
    # each trained agent's deployed solution: greedy move rollout, scored
    # under the scenario params that lane actually trained on
    lats = []
    for f in range(F):
        p_f = _lane_params(env, env_params, f)
        state_f = jax.tree.map(lambda x, f=f: x[f], states)
        s = env.reset(jax.random.PRNGKey(seed + 5), p_f)
        for t in range(2 * env.N):
            move = dqn_lib.select_move(jax.random.PRNGKey(t), state_f, cfg,
                                       env.state_vector(s, p_f),
                                       explore=False)
            s = s._replace(X=dqn_lib.apply_move(s.X, move, env.M))
        lats.append(float(env.evaluate(s.X, p_f.base_rates, params=p_f)))
    return lats, hist


def run_actor_critic(env: SchedulingEnv, budget: Budget, seed: int = 0,
                     deploy: bool = True, env_params=None):
    """Fleet of budget.n_seeds independent actor-critic runs (offline
    pretrain + online learning, both fleet-batched).

    Returns (per-seed deployed latencies, stacked History, (states, cfg));
    ``deploy=False`` skips the per-seed wide-K-NN deployment search."""
    agent = make_agent("ddpg", env, k_nn=budget.k_nn,
                       eps=EpsilonSchedule(
                           decay_epochs=max(budget.online_epochs * 2 // 3, 1)))
    cfg = agent.cfg
    F = budget.n_seeds
    states = agent.init_fleet(jax.random.PRNGKey(seed), F)
    states = ddpg_lib.offline_pretrain_fleet(
        jax.random.split(jax.random.PRNGKey(seed + 1), F), states, cfg, env,
        n_samples=budget.offline_samples, n_updates=budget.offline_updates,
        env_params=env_params)
    states, hist = run_online_fleet(
        jax.random.split(jax.random.PRNGKey(seed + 2), F), env, agent, states,
        T=budget.online_epochs, updates_per_epoch=budget.updates_per_epoch,
        env_params=env_params)
    if not deploy:
        return [], hist, (states, cfg)
    # each trained agent's deployed solution (paper: "scheduling solutions
    # given by well-trained DRL agents"): greedy action with a wide exact
    # K-NN (K=256 is free with the closed-form enumeration), iterated a
    # few epochs as the system re-stabilizes — under the lane's scenario
    lats = []
    for f in range(F):
        p_f = _lane_params(env, env_params, f)
        w = p_f.base_rates
        state_f = jax.tree.map(lambda x, f=f: x[f], states)
        s = env.reset(jax.random.PRNGKey(seed + 5), p_f)
        best = None
        for t in range(4):
            a = ddpg_lib.select_action(jax.random.PRNGKey(seed + 6 + t),
                                       state_f, cfg,
                                       env.state_vector(s, p_f),
                                       explore=False, exact_host_knn=True,
                                       k_override=256)
            lat_a = float(env.evaluate(a, w, params=p_f))
            if best is None or lat_a < best:
                best = lat_a
            s = s._replace(X=a)
        lats.append(best)
    return lats, hist, (states, cfg)


def compare_all(app: str, budget: Budget, seed: int = 0, verbose=True):
    env = make_env(app)
    t0 = time.time()
    out: dict = {"app": app, "n_seeds": budget.n_seeds}
    out["default"] = run_default(env)
    out["model_based"], _ = run_model_based(env, budget, seed)
    dqn_lats, dqn_hist = run_dqn(env, budget, seed)
    ac_lats, ac_hist, _ = run_actor_critic(env, budget, seed)
    out["dqn"] = float(np.mean(dqn_lats))
    out["dqn_std"] = float(np.std(dqn_lats))
    out["dqn_seeds"] = dqn_lats
    out["actor_critic"] = float(np.mean(ac_lats))
    out["actor_critic_std"] = float(np.std(ac_lats))
    out["actor_critic_seeds"] = ac_lats
    # seed-averaged online reward curves with variance bands (Figs 7/9/11)
    for name, hist in (("dqn", dqn_hist), ("ac", ac_hist)):
        mean, std = hist.seed_band()
        out[f"{name}_curve_mean"] = np.round(mean, 5).tolist()
        out[f"{name}_curve_std"] = np.round(std, 5).tolist()
    out["imp_vs_default"] = 1 - out["actor_critic"] / out["default"]
    out["imp_vs_model_based"] = 1 - out["actor_critic"] / out["model_based"]
    out["seconds"] = round(time.time() - t0, 1)
    out["_dqn_hist"] = dqn_hist
    out["_ac_hist"] = ac_hist
    if verbose:
        print(f"[{app}] default={out['default']:.2f}ms "
              f"model={out['model_based']:.2f}ms "
              f"dqn={out['dqn']:.2f}±{out['dqn_std']:.2f}ms "
              f"actor-critic={out['actor_critic']:.2f}"
              f"±{out['actor_critic_std']:.2f}ms "
              f"over {budget.n_seeds} seeds "
              f"(+{out['imp_vs_default']:.1%} vs default, "
              f"+{out['imp_vs_model_based']:.1%} vs model-based) "
              f"[{out['seconds']}s]", flush=True)
    return out
