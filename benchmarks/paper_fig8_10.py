"""Paper Figs 8 & 10: log-stream-processing and word-count (large-scale),
× the four schedulers.  DRL entries are mean ± std over a seed fleet (one
batched run); fig8_10.json carries the seed-averaged reward curves.

  python -m benchmarks.paper_fig8_10 [--paper-budget]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.paper_common import Budget, compare_all

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "paper"


def run(budget: Budget, seed: int = 0) -> list[dict]:
    results = []
    for app in ("log_stream", "word_count"):
        out = compare_all(app, budget, seed)
        out.pop("_dqn_hist"), out.pop("_ac_hist")
        results.append(out)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-budget", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    budget = Budget.paper() if args.paper_budget else Budget.quick()
    results = run(budget, args.seed)
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "fig8_10.json").write_text(json.dumps(results, indent=2))
    print("\npaper reference (default / model / dqn / AC, ms):")
    print("  log stream 9.61 / 7.91 / 8.19 / 7.20   (paper Fig 8)")
    print("  word count 3.10 / 2.16 / 2.29 / 1.70   (paper Fig 10)")


if __name__ == "__main__":
    main()
