"""§Roofline: three-term roofline per (arch × shape × mesh) from the
dry-run artifacts (trip-count-corrected HLO analysis).

  compute    = flops_per_device / peak_flops
  memory     = hbm_bytes_per_device / hbm_bw
  collective = wire_bytes_per_device / (links × link_bw)

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(2D torus: ~4 usable links/chip; collective term uses 2 links since ring
reductions stress one dimension at a time).

Usage:
  python -m benchmarks.roofline                # markdown table, all cells
  python -m benchmarks.roofline --csv
  python -m benchmarks.roofline --cell llama3-8b train_4k multi
"""
from __future__ import annotations

import argparse
import json
import pathlib

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link
LINKS = 2.0                  # effective links driving a ring collective

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def model_flops(rec: dict) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) per device; decode: D = tokens
    generated per step (= batch) and forward-only (2·N·D)."""
    from repro.configs import SHAPES, get_config
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n = rec.get("param_count_active") or cfg.param_count(active_only=True)
    n_embed = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n_eff = n - n_embed + cfg.vocab_size * cfg.d_model  # lm head matmul flops
    devices = 512 if rec["mesh"] == "multi" else 256
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_eff * tokens / devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_eff * tokens / devices
    # decode: one token per sequence per step
    return 2.0 * n_eff * shape.global_batch / devices


def roofline_terms(rec: dict) -> dict:
    c = rec.get("corrected") or {}
    flops = c.get("flops") or rec.get("flops_per_device", 0.0)
    hbm = c.get("hbm_bytes_est") or rec.get("bytes_accessed_per_device", 0.0)
    wire = c.get("collective_wire_bytes",
                 rec.get("collective_wire_bytes_per_device", 0.0))
    t_comp = flops / PEAK_FLOPS
    t_mem = hbm / HBM_BW
    t_coll = wire / (LINKS * LINK_BW)
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])
    mf = model_flops(rec)
    total = max(t_comp, t_mem, t_coll)
    return {
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "bottleneck": dom[0],
        "model_flops_per_device": mf,
        "useful_flops_ratio": (mf / flops) if flops else 0.0,
        # roofline fraction: useful model flops per bound-step-time vs peak
        "roofline_fraction": (mf / total / PEAK_FLOPS) if total else 0.0,
        "hlo_flops_per_device": flops,
        "hbm_bytes_per_device": hbm,
        "wire_bytes_per_device": wire,
        "peak_gib": rec["memory"]["peak_bytes_est"] / 2 ** 30,
    }


def load_all(tag: str = "") -> list[dict]:
    out = []
    for f in sorted(ART.glob("*.json")):
        rec = json.loads(f.read_text())
        is_tagged = rec.get("overrides") or "__o" in f.stem
        if tag:
            if tag not in f.stem:
                continue
        elif len(f.stem.split("__")) != 3:
            continue
        if rec.get("status") != "ok":
            out.append(rec)
            continue
        rec["_roofline"] = roofline_terms(rec)
        out.append(rec)
    return out


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}µs"


def markdown_table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute | memory | collective | "
           "bottleneck | 6ND/HLO | roofline frac | peak GiB |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for r in recs:
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"{r.get('status')}: {r.get('reason', r.get('error', ''))[:40]} "
                        "| | | | | | |")
            continue
        t = r["_roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_s(t['t_compute_s'])} | {fmt_s(t['t_memory_s'])} "
            f"| {fmt_s(t['t_collective_s'])} | {t['bottleneck']} "
            f"| {t['useful_flops_ratio']:.2f} | {t['roofline_fraction']:.1%} "
            f"| {t['peak_gib']:.1f} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--tag", default="", help="perf-experiment artifacts")
    ap.add_argument("--cell", nargs=3, metavar=("ARCH", "SHAPE", "MESH"))
    args = ap.parse_args()

    recs = load_all(args.tag)
    if args.cell:
        recs = [r for r in recs if (r["arch"], r["shape"], r["mesh"])
                == tuple(args.cell)]
    if args.csv:
        print("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
              "bottleneck,useful_ratio,roofline_fraction,peak_gib")
        for r in recs:
            if r.get("status") != "ok":
                continue
            t = r["_roofline"]
            print(f"{r['arch']},{r['shape']},{r['mesh']},"
                  f"{t['t_compute_s']:.6g},{t['t_memory_s']:.6g},"
                  f"{t['t_collective_s']:.6g},{t['bottleneck']},"
                  f"{t['useful_flops_ratio']:.4f},"
                  f"{t['roofline_fraction']:.4f},{t['peak_gib']:.2f}")
    else:
        print(markdown_table(recs))


if __name__ == "__main__":
    main()
