# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark entry point:  PYTHONPATH=src python -m benchmarks.run

Sections:
  kernels / simulator  — microbenchmarks (name, us_per_call, derived)
  paper figures        — quick-budget scheduler comparison per topology
                         (fig6 small/medium/large, fig8 log, fig10 wc)
  roofline             — summary from dry-run artifacts when present

Full-budget paper validation lives in the individual
``benchmarks.paper_*`` modules (--paper-budget)."""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-paper", action="store_true",
                    help="only micro-benchmarks (fast)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print("name,us_per_call,derived")

    from benchmarks.kernel_bench import run_all
    for name, us, derived in run_all():
        print(f"{name},{us:.1f},{derived}", flush=True)

    if not args.skip_paper:
        # minutes, not micro: trains a 32-lane fleet — skip on the fast path
        from benchmarks.fleet_bench import run_all as fleet_run_all
        for name, us, derived in fleet_run_all(fleet=32, epochs=300):
            print(f"{name},{us:.1f},{derived}", flush=True)

    if not args.skip_paper:
        from benchmarks.paper_common import Budget, compare_all
        budget = Budget.quick()
        for app, fig in [("cq_small", "fig6a"), ("cq_medium", "fig6b"),
                         ("cq_large", "fig6c"), ("log_stream", "fig8"),
                         ("word_count", "fig10")]:
            out = compare_all(app, budget, args.seed, verbose=False)
            print(f"paper_{fig}_{app}_default_ms,{out['default'] * 1e3:.0f},"
                  f"avg_tuple_time={out['default']:.3f}ms", flush=True)
            print(f"paper_{fig}_{app}_model_based_ms,"
                  f"{out['model_based'] * 1e3:.0f},"
                  f"avg_tuple_time={out['model_based']:.3f}ms")
            print(f"paper_{fig}_{app}_dqn_ms,{out['dqn'] * 1e3:.0f},"
                  f"avg_tuple_time={out['dqn']:.3f}ms")
            print(f"paper_{fig}_{app}_actor_critic_ms,"
                  f"{out['actor_critic'] * 1e3:.0f},"
                  f"avg_tuple_time={out['actor_critic']:.3f}ms;"
                  f"imp_vs_default={out['imp_vs_default']:.1%};"
                  f"imp_vs_model={out['imp_vs_model_based']:.1%}", flush=True)

    # roofline summary (if the dry-run artifacts exist)
    try:
        from benchmarks.roofline import load_all
        recs = [r for r in load_all() if r.get("status") == "ok"]
        for r in recs:
            t = r["_roofline"]
            tot = max(t["t_compute_s"], t["t_memory_s"], t["t_collective_s"])
            print(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},"
                  f"{tot * 1e6:.0f},"
                  f"bottleneck={t['bottleneck']};"
                  f"frac={t['roofline_fraction']:.3f}")
    except Exception as e:  # artifacts may not exist yet
        print(f"roofline_skipped,0,{type(e).__name__}")


if __name__ == "__main__":
    main()
