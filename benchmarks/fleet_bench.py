"""Fleet-runner microbenchmark: online-learning epochs/sec, sequential
legacy Python loop vs the fully-jitted fleet-batched scan.

The paper's credibility hinges on seed-swept online-learning curves; this
bench shows why that is now affordable — one vmapped scan executes the
whole seed fleet as a single XLA program (target: ≥ 10× lane-epochs/sec
over the per-epoch Python loop).

  PYTHONPATH=src python -m benchmarks.fleet_bench [--fleet 32] [--epochs 300]

Rows are ``name,us_per_call,derived`` — the benchmarks.run CSV schema
(us_per_call = microseconds per lane-epoch)."""
from __future__ import annotations

import argparse
import time

import jax

from repro.core import ddpg as ddpg_lib
from repro.core.agent import run_online_ddpg_python, run_online_fleet
from repro.core.ddpg import DDPGConfig
from repro.dsdps import SchedulingEnv, apps
from repro.dsdps.apps import default_workload


def run_all(fleet: int = 32, epochs: int = 300, app: str = "cq_small",
            baseline_epochs: int = 40) -> list[tuple]:
    topo = apps.ALL_APPS[app]()
    env = SchedulingEnv(topo, default_workload(topo))
    cfg = DDPGConfig(n_executors=env.N, n_machines=env.M,
                     state_dim=env.state_dim)
    state = ddpg_lib.init_state(jax.random.PRNGKey(0), cfg)
    rows = []

    # sequential baseline: the legacy per-epoch Python loop (short run —
    # per-epoch cost is flat after the first few jit dispatches)
    run_online_ddpg_python(jax.random.PRNGKey(1), env, cfg, state, T=3)
    t0 = time.perf_counter()
    run_online_ddpg_python(jax.random.PRNGKey(1), env, cfg, state,
                           T=baseline_epochs)
    dt = time.perf_counter() - t0
    eps_python = baseline_epochs / dt
    rows.append((f"fleet_bench_{app}_python_loop", dt / baseline_epochs * 1e6,
                 f"epochs_per_sec={eps_python:.1f}"))

    # fleet runner: fleet × epochs lane-epochs in ONE jitted vmapped scan
    states = ddpg_lib.init_fleet(jax.random.PRNGKey(2), cfg, fleet)
    keys = jax.random.split(jax.random.PRNGKey(3), fleet)
    t0 = time.perf_counter()
    run_online_fleet(keys, env, cfg, states, T=epochs)
    dt_cold = time.perf_counter() - t0              # includes compile
    t0 = time.perf_counter()
    run_online_fleet(keys, env, cfg, states, T=epochs)
    dt_warm = time.perf_counter() - t0
    eps_warm = fleet * epochs / dt_warm
    eps_cold = fleet * epochs / dt_cold
    rows.append((f"fleet_bench_{app}_scan_f{fleet}_T{epochs}",
                 dt_warm / (fleet * epochs) * 1e6,
                 f"lane_epochs_per_sec={eps_warm:.1f};"
                 f"speedup_vs_python={eps_warm / eps_python:.1f}x;"
                 f"speedup_incl_compile={eps_cold / eps_python:.1f}x"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet", type=int, default=32)
    ap.add_argument("--epochs", type=int, default=300)
    ap.add_argument("--app", default="cq_small")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run_all(args.fleet, args.epochs, args.app):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
