"""Fleet-runner microbenchmark: online-learning epochs/sec, sequential
legacy Python loop vs the fully-jitted fleet-batched scan — and, with
``--scenario-batched``, the scenario-batched fleet where every lane carries
its own EnvParams (heterogeneous workload rates × service jitter × noise ×
stragglers) vmapped through the same one-XLA-program runner.
``--sharded`` additionally times the mesh-sharded fleet
(``run_online_fleet(..., mesh=launch.mesh.make_fleet_mesh())``): the fleet
axis partitioned over every visible device via shard_map, recorded as
lane-epochs/sec next to the single-device vmap row.  ``--lifecycle`` times
the elastic lane lifecycle (repro/fleet/lifecycle.py) against the fixed
grid on a plateauing fleet: total lane-epochs executed, the savings
fraction, elastic-vs-fixed lane-epochs/sec, and the final-reward gap.
``--graph`` runs the structural (DAG-shape) fleet: graph_policy vs ddpg
on the same ``dag_shapes`` scenario lanes — different topologies padded
into one envelope and trained as ONE program (compile-once asserted
under the diagnostics guards; per-topology tail-latency parity >= 0.95
asserted in full runs).

The paper's credibility hinges on seed-swept online-learning curves; this
bench shows why that is now affordable — one vmapped scan executes the
whole fleet as a single XLA program (target: ≥ 10× lane-epochs/sec over
the per-epoch Python loop), and scenario heterogeneity rides as traced
parameters: the stacked-params program compiles once, then any scenario
edit (new rates, stragglers, noise levels) reuses the executable.

  PYTHONPATH=src python -m benchmarks.fleet_bench [--fleet 32] [--epochs 300]
      [--scenario-batched] [--sharded] [--json artifacts/fleet_bench.json]

Rows are ``name,us_per_call,derived`` — the benchmarks.run CSV schema
(us_per_call = microseconds per lane-epoch); the same rows are written to
the JSON artifact."""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

if "--multihost-worker" in sys.argv:
    # workers of a `--multihost` run must join jax.distributed before ANY
    # jax computation — and some agent modules build jnp defaults at
    # import time — so the handshake happens ahead of the imports below
    from repro.launch.mesh import init_distributed
    init_distributed()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddpg as ddpg_lib
from repro.core import make_agent
from repro.core.agent import run_online_ddpg_python, run_online_fleet
from repro.core.ddpg import DDPGConfig
from repro.dsdps import SchedulingEnv, apps, scenarios
from repro.dsdps.apps import default_workload
from repro.launch.mesh import make_fleet_mesh

DEFAULT_JSON = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / \
    "fleet_bench.json"


def _params_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(params))


def provenance(mesh_shape=None, agent=None) -> dict:
    """Where this row was measured: pinned on every JSON row so numbers
    from different machines / backends / process topologies never get
    compared as like-for-like by accident.  ``agent`` additionally pins
    WHICH agent kind produced the row (the --streaming rows compare agent
    kinds, so the name must survive into the artifact)."""
    out = {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "process_count": jax.process_count(),
        "device_count": jax.device_count(),
        "local_device_count": jax.local_device_count(),
    }
    if mesh_shape is not None:
        out["mesh_shape"] = [int(s) for s in mesh_shape]
    if agent is not None:
        out["agent"] = agent
    return out


def run_all(fleet: int = 32, epochs: int = 300, app: str = "cq_small",
            baseline_epochs: int = 40,
            scenario_batched: bool = False,
            broadcast_invariant: bool = False,
            sharded: bool = False,
            lifecycle: bool = False,
            guards_overhead: bool = False) -> list[tuple]:
    # the broadcast comparison is a variant OF the scenario-batched fleet
    scenario_batched = scenario_batched or broadcast_invariant
    topo = apps.ALL_APPS[app]()
    env = SchedulingEnv(topo, default_workload(topo))
    cfg = DDPGConfig(n_executors=env.N, n_machines=env.M,
                     state_dim=env.state_dim)
    agent = make_agent("ddpg", env, cfg=cfg)
    state = ddpg_lib.init_state(jax.random.PRNGKey(0), cfg)
    rows = []

    # sequential baseline: the legacy per-epoch Python loop (short run —
    # per-epoch cost is flat after the first few jit dispatches)
    run_online_ddpg_python(jax.random.PRNGKey(1), env, cfg, state, T=3)
    t0 = time.perf_counter()
    run_online_ddpg_python(jax.random.PRNGKey(1), env, cfg, state,
                           T=baseline_epochs)
    dt = time.perf_counter() - t0
    eps_python = baseline_epochs / dt
    rows.append((f"fleet_bench_{app}_python_loop", dt / baseline_epochs * 1e6,
                 f"epochs_per_sec={eps_python:.1f}"))

    # seed-only fleet: fleet × epochs lane-epochs in ONE jitted vmapped scan
    states = ddpg_lib.init_fleet(jax.random.PRNGKey(2), cfg, fleet)
    keys = jax.random.split(jax.random.PRNGKey(3), fleet)
    t0 = time.perf_counter()
    run_online_fleet(keys, env, agent, states, T=epochs)
    dt_cold = time.perf_counter() - t0              # includes compile
    t0 = time.perf_counter()
    run_online_fleet(keys, env, agent, states, T=epochs)
    dt_warm = time.perf_counter() - t0
    eps_warm = fleet * epochs / dt_warm
    eps_cold = fleet * epochs / dt_cold
    rows.append((f"fleet_bench_{app}_scan_f{fleet}_T{epochs}",
                 dt_warm / (fleet * epochs) * 1e6,
                 f"lane_epochs_per_sec={eps_warm:.1f};"
                 f"speedup_vs_python={eps_warm / eps_python:.1f}x;"
                 f"speedup_incl_compile={eps_cold / eps_python:.1f}x"))

    # per-lane memory: what one more lane costs — the replay buffer
    # dominates the carry, and this is the number that sizes 1000+-lane
    # sweeps against a device's HBM (ROADMAP: multi-host mega-fleets)
    carry_bytes = _params_bytes(states)
    replay_bytes = (_params_bytes(states.replay)
                    if hasattr(states, "replay") else 0)
    rows.append((f"fleet_bench_{app}_lane_memory_f{fleet}", 0.0,
                 f"carry_bytes_per_lane={carry_bytes // fleet};"
                 f"replay_bytes_per_lane={replay_bytes // fleet};"
                 f"net_bytes_per_lane={(carry_bytes - replay_bytes) // fleet};"
                 f"replay_fraction={replay_bytes / max(carry_bytes, 1):.3f};"
                 f"fleet_carry_bytes={carry_bytes}"))

    if guards_overhead:
        # the SAME seed-only fleet run, re-timed inside the runtime
        # tracing-discipline guards (repro.diagnostics.guards): implicit-
        # transfer guard + jit-cache-miss sentinel + non-finite sweeps at
        # chunk boundaries.  The program is already compiled from the row
        # above, so this isolates steady-state guard overhead against
        # dt_warm — the acceptance contract pins it under 5% on cq_small.
        from repro.core import agent as agent_mod
        from repro.diagnostics import guards
        with guards(track=(agent_mod._fleet_program,),
                    label="fleet_bench") as g:
            run_online_fleet(keys, env, agent, states, T=epochs)  # settle
            t0 = time.perf_counter()
            run_online_fleet(keys, env, agent, states, T=epochs)
            dt_g = time.perf_counter() - t0
            compiles = g.counter.compiles
        eps_g = fleet * epochs / dt_g
        overhead = dt_g / dt_warm - 1.0
        rows.append((f"fleet_bench_{app}_guards_f{fleet}_T{epochs}",
                     dt_g / (fleet * epochs) * 1e6,
                     f"guarded_lane_epochs_per_sec={eps_g:.1f};"
                     f"unguarded_lane_epochs_per_sec={eps_warm:.1f};"
                     f"guard_overhead_pct={overhead * 100:.2f};"
                     f"fleet_program_compiles_under_guard={compiles}"))

    if scenario_batched:
        # scenario-batched fleet: per-lane EnvParams (mixed stragglers /
        # diurnal rates / noise / service jitter) vmapped as traced inputs.
        # The stacked-params program compiles once (cold_s below); EDITING
        # the scenario values afterwards reuses the executable — that warm
        # path is what the second timing measures.
        env_params = scenarios.build("mixed", env, fleet)
        t0 = time.perf_counter()
        run_online_fleet(keys, env, agent, states, T=epochs,
                         env_params=env_params)
        dt_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        run_online_fleet(keys, env, agent, states, T=epochs,
                         env_params=env_params)
        dt_warm = time.perf_counter() - t0
        eps_scen = fleet * epochs / dt_warm
        rows.append((f"fleet_bench_{app}_scenario_f{fleet}_T{epochs}",
                     dt_warm / (fleet * epochs) * 1e6,
                     f"lane_epochs_per_sec={eps_scen:.1f};"
                     f"vs_seed_only_fleet={eps_scen / eps_warm:.2f}x;"
                     f"speedup_vs_python={eps_scen / eps_python:.1f}x;"
                     f"cold_s={dt_cold:.2f}"))

        if broadcast_invariant:
            # same scenario fleet, but scenario-invariant leaves (routing /
            # flow_solve / tuple_bytes) kept single-copy and broadcast with
            # per-leaf in_axes=None — numerically identical to the stacked
            # run, minus the F×-duplicated params memory
            bc_params = scenarios.build("mixed", env, fleet,
                                        broadcast_invariant=True)
            run_online_fleet(keys, env, agent, states, T=epochs,
                             env_params=bc_params)   # compile
            t0 = time.perf_counter()
            run_online_fleet(keys, env, agent, states, T=epochs,
                             env_params=bc_params)
            dt_bc = time.perf_counter() - t0
            eps_bc = fleet * epochs / dt_bc
            rows.append((f"fleet_bench_{app}_broadcast_f{fleet}_T{epochs}",
                         dt_bc / (fleet * epochs) * 1e6,
                         f"lane_epochs_per_sec={eps_bc:.1f};"
                         f"vs_stacked_scenario={eps_bc / eps_scen:.2f}x;"
                         f"params_bytes_stacked={_params_bytes(env_params)};"
                         f"params_bytes_broadcast={_params_bytes(bc_params)}"))

    if sharded:
        # mesh-sharded fleet: the SAME runner with the fleet axis
        # partitioned over every visible device (shard_map over the data
        # axis of launch.mesh.make_fleet_mesh()).  On a 1-device host this
        # measures the sharding machinery's overhead against the plain
        # vmap row; on a real mesh it is the fleet-capacity scaling row.
        # Carries are donated on accelerator meshes, so hand the program
        # fresh copies each call.
        mesh = make_fleet_mesh()
        n_dev = mesh.devices.size

        def fresh():
            return jax.tree.map(jnp.array, states)

        run_online_fleet(keys, env, agent, fresh(), T=epochs,
                         mesh=mesh)                  # compile
        t0 = time.perf_counter()
        run_online_fleet(keys, env, agent, fresh(), T=epochs, mesh=mesh)
        dt_sh = time.perf_counter() - t0
        eps_sh = fleet * epochs / dt_sh
        rows.append((f"fleet_bench_{app}_sharded_f{fleet}_T{epochs}_d{n_dev}",
                     dt_sh / (fleet * epochs) * 1e6,
                     f"lane_epochs_per_sec={eps_sh:.1f};"
                     f"vmap_lane_epochs_per_sec={eps_warm:.1f};"
                     f"vs_vmap={eps_sh / eps_warm:.2f}x;"
                     f"devices={n_dev}"))

    if lifecycle:
        # elastic lane lifecycle vs fixed grid on a PLATEAUING fleet: the
        # round-robin baseline's reward plateaus by construction, so what
        # this row measures is the stopping rule's detection latency and
        # the lane-epochs the compacting runner then refuses to pay —
        # executed_lane_epochs strictly below the fixed grid with final
        # eval rewards matching within tolerance (the ISSUE-5 acceptance
        # contract; the bit-exactness side is pinned in
        # tests/test_lifecycle.py).
        from repro.fleet.lifecycle import StopRule, run_online_fleet_elastic
        rr = make_agent("round_robin", env)
        rr_states = rr.init_fleet(jax.random.PRNGKey(5), fleet)
        rule = StopRule(window=max(2, epochs // 16), rel_tol=0.02,
                        min_epochs=max(4, epochs // 8),
                        check_every=max(4, epochs // 8))
        run_online_fleet(keys, env, rr, rr_states, T=epochs)      # compile
        t0 = time.perf_counter()
        _, h_fix = run_online_fleet(keys, env, rr, rr_states, T=epochs)
        dt_fix = time.perf_counter() - t0
        run_online_fleet_elastic(keys, env, rr, rr_states, epochs,
                                 rule=rule)                       # compile
        t0 = time.perf_counter()
        res = run_online_fleet_elastic(keys, env, rr, rr_states, epochs,
                                       rule=rule)
        dt_el = time.perf_counter() - t0
        eps_fix = fleet * epochs / dt_fix
        eps_el = res.executed_lane_epochs / dt_el
        k = max(1, min(rule.window, epochs))
        gap = float(np.abs(res.history.rewards[:, -k:].mean(axis=1)
                           - np.asarray(h_fix.rewards)[:, -k:].mean(axis=1)
                           ).max())
        rows.append((f"fleet_bench_{app}_lifecycle_f{fleet}_T{epochs}",
                     dt_el / max(res.executed_lane_epochs, 1) * 1e6,
                     f"executed_lane_epochs={res.executed_lane_epochs};"
                     f"fixed_grid_lane_epochs={res.fixed_grid_lane_epochs};"
                     f"savings={res.savings:.2f};"
                     f"elastic_lane_epochs_per_sec={eps_el:.1f};"
                     f"fixed_lane_epochs_per_sec={eps_fix:.1f};"
                     f"elastic_wall_s={dt_el:.3f};fixed_wall_s={dt_fix:.3f};"
                     f"final_reward_gap={gap:.5f}"))

        # successive-halving scenario search: how many lane-epochs the
        # rung/prune/refill discipline spends vs a fixed grid over every
        # candidate it ever launched
        from repro.fleet.lifecycle import search_scenarios
        s_fleet = min(fleet, 8)
        rung = max(2, epochs // 8)
        t0 = time.perf_counter()
        lb = search_scenarios(env, rr, fleet=s_fleet,
                              rungs=(rung, rung, 2 * rung),
                              eval_window=max(2, rung // 2), seed=0)
        dt_s = time.perf_counter() - t0
        fixed_grid = len(lb.entries) * sum(lb.rungs)
        rows.append((f"fleet_bench_{app}_search_f{s_fleet}_r{rung}",
                     dt_s / max(lb.total_lane_epochs, 1) * 1e6,
                     f"candidates={len(lb.entries)};"
                     f"total_lane_epochs={lb.total_lane_epochs};"
                     f"fixed_grid_lane_epochs={fixed_grid};"
                     f"best_eval_reward={lb.entries[0].score:.4f};"
                     f"wall_s={dt_s:.3f}"))
    return rows


# --------------------------------------------------------------------------
# streaming lanes: replay-free Stream Q(λ)/AC(λ) vs the replay agents
# --------------------------------------------------------------------------
HBM_BUDGET_GIB = 16.0    # reference accelerator memory for the width ceiling


def _replay_bytes(states) -> int:
    return _params_bytes(states.replay) if hasattr(states, "replay") else 0


def _trace_bytes(states) -> int:
    total = 0
    for leaf in ("z", "z_actor", "z_critic"):
        if hasattr(states, leaf):
            total += _params_bytes(getattr(states, leaf))
    return total


def run_streaming(fleet: int = 4, epochs: int = 300,
                  app: str = "cq_small") -> list[tuple]:
    """The replay-free streaming story in three rows per agent pair:

    * parity — final smoothed (per-lane min-max-normalized, filtfilt)
      reward of the streaming fleet over the replay fleet, same seeds,
      plus warm lane-epochs/sec for both;
    * memory — per-lane carry bytes side by side (streaming lanes report
      ZERO replay bytes; the carry is nets + traces + the Welford
      normalizer) and the shrink factor;
    * width ceiling — how many lanes of each kind fit a reference
      HBM_BUDGET_GIB accelerator, i.e. the fleet-width cap moving.

    Every row's provenance block carries the streaming agent kind."""
    topo = apps.ALL_APPS[app]()
    env = SchedulingEnv(topo, default_workload(topo))
    rows = []
    budget = int(HBM_BUDGET_GIB * 2**30)
    for replay_name, stream_name in (("dqn", "stream_q"),
                                     ("ddpg", "stream_ac")):
        results = {}
        for name in (replay_name, stream_name):
            agent = make_agent(name, env)
            states = agent.init_fleet(jax.random.PRNGKey(0), fleet)
            keys = jax.random.split(jax.random.PRNGKey(1), fleet)
            run_online_fleet(keys, env, agent, states, T=epochs)  # compile
            t0 = time.perf_counter()
            _, hist = run_online_fleet(keys, env, agent, states, T=epochs)
            dt = time.perf_counter() - t0
            k = max(1, min(20, epochs // 4))
            results[name] = {
                "final": float(hist.smoothed_rewards()[:, -k:].mean()),
                "eps": fleet * epochs / dt,
                "carry": _params_bytes(states) // fleet,
                "replay": _replay_bytes(states) // fleet,
                "traces": _trace_bytes(states) // fleet,
            }
        rep, st = results[replay_name], results[stream_name]
        parity = st["final"] / max(rep["final"], 1e-9)
        rows.append((
            f"fleet_bench_{app}_streaming_{stream_name}_vs_{replay_name}"
            f"_f{fleet}_T{epochs}",
            1e6 / st["eps"],
            f"parity_final_smoothed={parity:.3f};"
            f"{stream_name}_final={st['final']:.4f};"
            f"{replay_name}_final={rep['final']:.4f};"
            f"{stream_name}_lane_epochs_per_sec={st['eps']:.1f};"
            f"{replay_name}_lane_epochs_per_sec={rep['eps']:.1f}",
            provenance(agent=stream_name)))
        shrink = rep["carry"] / max(st["carry"], 1)
        rows.append((
            f"fleet_bench_{app}_streaming_memory_{stream_name}_f{fleet}",
            0.0,
            f"carry_bytes_per_lane={st['carry']};"
            f"replay_bytes_per_lane={st['replay']};"
            f"trace_bytes_per_lane={st['traces']};"
            f"{replay_name}_carry_bytes_per_lane={rep['carry']};"
            f"{replay_name}_replay_bytes_per_lane={rep['replay']};"
            f"carry_shrink_vs_{replay_name}={shrink:.1f}x",
            provenance(agent=stream_name)))
        width_replay = budget // max(rep["carry"], 1)
        width_stream = budget // max(st["carry"], 1)
        rows.append((
            f"fleet_bench_{app}_fleet_width_ceiling_{stream_name}",
            0.0,
            f"hbm_budget_gib={HBM_BUDGET_GIB:.0f};"
            f"max_fleet_width_{replay_name}={width_replay};"
            f"max_fleet_width_{stream_name}={width_stream};"
            f"widening={width_stream / max(width_replay, 1):.1f}x",
            provenance(agent=stream_name)))
    return rows


# --------------------------------------------------------------------------
# structural (DAG-shape) fleets: graph_policy vs ddpg across topologies
# --------------------------------------------------------------------------
def run_graph(fleet: int = 6, epochs: int = 300,
              smoke: bool = False) -> list[tuple]:
    """The Decima-style structural story: ONE fleet trains across
    *different DAGs* (chain / diamond / wide fan-out padded into a common
    envelope, ``scenarios.dag_shapes``) in a single XLA program, and the
    graph policy's message passing is compared against the flat-vector
    ddpg baseline on the SAME lanes.

    Two contracts are asserted here (they are what the CI graph smoke
    lane pins):

    * compile-once — despite three heterogeneous graph structures, the
      fleet program compiles exactly once (structure rides as traced
      GraphEnvParams leaves, checked under repro.diagnostics.guards);
    * parity (full runs only) — per-topology BEST-lane tail latency of
      the graph fleet within 0.95x of ddpg's on the same scenarios (the
      fleet is a parallel seed sweep; the deployed policy is the best
      lane, drl_control's reporting convention)."""
    from repro.core import agent as agent_mod
    from repro.diagnostics import guards
    from repro.dsdps.structural import StructuralSchedulingEnv

    env = StructuralSchedulingEnv(apps.structural_topologies())
    n_topos = len(env.topologies)
    env_params = scenarios.build_for(env, "dag_shapes", fleet)
    keys = jax.random.split(jax.random.PRNGKey(1), fleet)
    k = max(1, min(20, epochs // 4))
    results = {}
    compiles = None
    for name in ("graph_policy", "ddpg", "round_robin"):
        agent = make_agent(name, env)
        states = agent.init_fleet(jax.random.PRNGKey(0), fleet,
                                  env_params=env_params, env=env)
        if name == "graph_policy":
            # cold + warm run under the tracing-discipline guards: the
            # heterogeneous-DAG fleet must compile exactly once
            with guards(track=(agent_mod._fleet_program,),
                        label="fleet_bench_graph") as g:
                t0 = time.perf_counter()
                run_online_fleet(keys, env, agent, states, T=epochs,
                                 env_params=env_params)
                dt_cold = time.perf_counter() - t0
                t0 = time.perf_counter()
                _, hist = run_online_fleet(keys, env, agent, states, T=epochs,
                                           env_params=env_params)
                dt = time.perf_counter() - t0
                compiles = g.counter.compiles
            if compiles != 1:
                raise SystemExit(
                    f"--graph: structural fleet compiled {compiles}x across "
                    f"two runs over {n_topos} DAG shapes (want exactly 1 — "
                    f"topology structure must ride as traced params, not "
                    f"static shapes)")
        else:
            run_online_fleet(keys, env, agent, states, T=epochs,
                             env_params=env_params)           # compile
            t0 = time.perf_counter()
            _, hist = run_online_fleet(keys, env, agent, states, T=epochs,
                                       env_params=env_params)
            dt = time.perf_counter() - t0
        results[name] = {
            "eps": fleet * epochs / dt,
            "tails": np.asarray(hist.latencies)[:, -k:].mean(axis=1),
        }
    g_res, d_res = results["graph_policy"], results["ddpg"]
    env_d = env.envelope
    rows = [(f"fleet_bench_graph_dag_shapes_f{fleet}_T{epochs}",
             1e6 / g_res["eps"],
             f"lane_epochs_per_sec={g_res['eps']:.1f};"
             f"ddpg_lane_epochs_per_sec={d_res['eps']:.1f};"
             f"fleet_program_compiles={compiles};"
             f"n_topologies={n_topos};"
             f"envelope=execs{env_d.max_execs}_edges{env_d.max_edges}"
             f"_spouts{env_d.max_spouts}_comps{env_d.max_components}"
             + (f";cold_s={dt_cold:.2f}" if not smoke else ""),
             provenance(agent="graph_policy"))]
    # per-topology parity: lane i runs topology i % n_topos, so grouping
    # lanes by residue compares the two agents on identical scenario sets.
    # The asserted number is BEST-lane parity — the fleet is a parallel
    # seed sweep and the deployed policy is the best lane (drl_control's
    # reporting convention); lane means ride along for transparency.
    per_topo, lanes = [], np.arange(fleet)
    for t, topo in enumerate(env.topologies):
        sel = lanes % n_topos == t
        g_best = float(g_res["tails"][sel].min())
        parity = float(d_res["tails"][sel].min()) / max(g_best, 1e-9)
        parity_mean = (float(d_res["tails"][sel].mean())
                       / max(float(g_res["tails"][sel].mean()), 1e-9))
        rr_lat = float(results["round_robin"]["tails"][sel].mean())
        per_topo.append((topo.name, parity, parity_mean, g_best, rr_lat))
    parity_min = min(p for _, p, _, _, _ in per_topo)
    rows.append((f"fleet_bench_graph_parity_f{fleet}_T{epochs}",
                 0.0,
                 f"parity_min_vs_ddpg={parity_min:.3f};" +
                 ";".join(f"{n}_best_parity={p:.3f};"
                          f"{n}_mean_parity={pm:.3f};"
                          f"{n}_best_tail_ms={gl:.3f};"
                          f"{n}_round_robin_ms={rl:.3f}"
                          for n, p, pm, gl, rl in per_topo),
                 provenance(agent="graph_policy")))
    if not smoke and parity_min < 0.95:
        raise SystemExit(
            f"--graph: per-topology best-lane tail-latency parity vs ddpg "
            f"fell to {parity_min:.3f} (< 0.95): "
            f"{[(n, round(p, 3)) for n, p, _, _, _ in per_topo]}")
    return rows


# --------------------------------------------------------------------------
# multi-host scaling: N localhost processes, one process-spanning mesh
# --------------------------------------------------------------------------
def run_multihost_worker(fleet: int, epochs: int, app: str,
                         worker_out: str | None) -> None:
    """One rank of a ``--multihost`` measurement: every process builds the
    SAME fleet from shared seeds, joins the process-spanning mesh, and
    times the spanning ``run_online_fleet`` between cross-process
    barriers; process 0 writes the result JSON for the driver."""
    from jax.experimental import multihost_utils

    from repro.launch.mesh import make_fleet_mesh
    topo = apps.ALL_APPS[app]()
    env = SchedulingEnv(topo, default_workload(topo))
    cfg = DDPGConfig(n_executors=env.N, n_machines=env.M,
                     state_dim=env.state_dim)
    agent = make_agent("ddpg", env, cfg=cfg)
    states = ddpg_lib.init_fleet(jax.random.PRNGKey(2), cfg, fleet)
    keys = jax.random.split(jax.random.PRNGKey(3), fleet)
    mesh = make_fleet_mesh(spanning=True)
    run_online_fleet(keys, env, agent, states, T=epochs, mesh=mesh)  # compile
    multihost_utils.sync_global_devices("fleet_bench_mh_warm")
    t0 = time.perf_counter()
    run_online_fleet(keys, env, agent, states, T=epochs, mesh=mesh)
    multihost_utils.sync_global_devices("fleet_bench_mh_done")
    dt = time.perf_counter() - t0
    if jax.process_index() == 0 and worker_out:
        pathlib.Path(worker_out).write_text(json.dumps({
            "lane_epochs_per_sec": fleet * epochs / dt,
            "wall_s": dt,
            "fleet": fleet, "epochs": epochs,
            "provenance": provenance(mesh.devices.shape),
        }))


def run_multihost(fleet: int = 32, epochs: int = 300, app: str = "cq_small",
                  smoke: bool = False, devices_per_proc: int = 2,
                  json_path: str = "") -> list[tuple]:
    """Drive the multi-host scaling sweep: for each process count spawn
    that many localhost workers (``repro.launch.multihost`` env wiring:
    REPRO_* vars + ``--xla_force_host_platform_device_count``), each
    running :func:`run_multihost_worker`, and record lane-epochs/sec
    plus the scaling factor against the single-process run.  On one
    machine the processes share the same cores, so the interesting
    number is the multi-process machinery's overhead staying small —
    on real multi-host fleets the same rows become capacity scaling."""
    from repro.launch.multihost import free_port, worker_env
    procs_list = (1, 2) if smoke else (1, 2, 4)
    max_dev = procs_list[-1] * devices_per_proc
    if fleet % max_dev != 0:
        raise SystemExit(
            f"--multihost needs --fleet divisible by "
            f"{max_dev} (= {procs_list[-1]} procs x {devices_per_proc} "
            f"devices); got {fleet}")
    rows, base_eps = [], None
    out_dir = pathlib.Path(json_path).parent if json_path \
        else pathlib.Path(".")
    for n in procs_list:
        coordinator = f"127.0.0.1:{free_port()}"
        out = out_dir / f".fleet_bench_mh_{n}.json"
        if out.exists():
            out.unlink()
        workers = []
        for pid in range(n):
            cmd = [sys.executable, "-m", "benchmarks.fleet_bench",
                   "--multihost-worker", "--fleet", str(fleet),
                   "--epochs", str(epochs), "--app", app, "--json", ""]
            if pid == 0:
                cmd += ["--worker-out", str(out)]
            workers.append(subprocess.Popen(
                cmd, env=worker_env(os.environ, coordinator, n, pid,
                                    devices_per_proc),
                stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                text=True))
        fail = []
        for pid, p in enumerate(workers):
            out_text, _ = p.communicate(timeout=1800)
            if p.returncode != 0:
                fail.append((pid, out_text))
        if fail:
            for pid, text in fail:
                print(f"----- multihost worker {pid}/{n} failed -----")
                print("\n".join(text.splitlines()[-30:]))
            raise SystemExit(f"--multihost: {len(fail)} worker(s) of the "
                             f"{n}-process run failed")
        payload = json.loads(out.read_text())
        out.unlink()
        eps = payload["lane_epochs_per_sec"]
        if base_eps is None:
            base_eps = eps
        rows.append((
            f"fleet_bench_{app}_multihost_p{n}_d{devices_per_proc}"
            f"_f{fleet}_T{epochs}",
            payload["wall_s"] / (fleet * epochs) * 1e6,
            f"lane_epochs_per_sec={eps:.1f};"
            f"scaling_vs_1proc={eps / base_eps:.2f}x;"
            f"processes={n};devices={n * devices_per_proc};"
            f"wall_s={payload['wall_s']:.3f}",
            payload["provenance"]))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet", type=int, default=32)
    ap.add_argument("--epochs", type=int, default=300)
    ap.add_argument("--app", default="cq_small")
    ap.add_argument("--baseline-epochs", type=int, default=40)
    ap.add_argument("--scenario-batched", action="store_true",
                    help="also time the params-vmapped heterogeneous-"
                         "scenario fleet (dsdps.scenarios 'mixed')")
    ap.add_argument("--broadcast-invariant", action="store_true",
                    help="also time the per-leaf broadcast variant of the "
                         "scenario-batched fleet (invariant leaves "
                         "single-copy, in_axes=None) and report stacked-vs-"
                         "broadcast lane-epochs/sec + params memory "
                         "(implies --scenario-batched)")
    ap.add_argument("--sharded", action="store_true",
                    help="also time the mesh-sharded fleet (fleet axis "
                         "over every visible device via shard_map, "
                         "launch.mesh.make_fleet_mesh) and record "
                         "lane-epochs/sec for vmap vs sharded")
    ap.add_argument("--lifecycle", action="store_true",
                    help="also time per-lane early stopping + compaction "
                         "vs the fixed grid on a plateauing fleet and "
                         "record executed lane-epochs, savings, and the "
                         "final-reward gap")
    ap.add_argument("--guards", action="store_true",
                    help="also re-time the seed-only fleet run inside the "
                         "runtime tracing-discipline guards "
                         "(repro.diagnostics.guards) and record the "
                         "steady-state overhead vs the unguarded warm run")
    ap.add_argument("--streaming", action="store_true",
                    help="also run the replay-free streaming lanes "
                         "(stream_q/stream_ac) against their replay "
                         "counterparts (dqn/ddpg) and record reward "
                         "parity, per-lane carry bytes (zero replay "
                         "bytes), and the fleet-width ceiling moving")
    ap.add_argument("--streaming-fleet", type=int, default=4,
                    help="fleet width of the --streaming comparison runs "
                         "(memory rows are per-lane, so small is fine)")
    ap.add_argument("--graph", action="store_true",
                    help="also run the structural (DAG-shape) fleet: "
                         "graph_policy vs ddpg on the same dag_shapes "
                         "scenario lanes (chain/diamond/wide-fanout padded "
                         "into one envelope), asserting the heterogeneous-"
                         "DAG fleet compiles exactly once and — in full "
                         "runs — per-topology tail-latency parity >= 0.95; "
                         "with --smoke this runs ONLY the small graph lane "
                         "(the CI graph smoke job)")
    ap.add_argument("--graph-fleet", type=int, default=6,
                    help="fleet width of the --graph comparison runs "
                         "(lanes round-robin over the structural "
                         "topologies, so a multiple of 3 covers them "
                         "evenly)")
    ap.add_argument("--multihost", action="store_true",
                    help="also run the multi-host scaling sweep: launch "
                         "1/2/4 localhost worker processes joined into one "
                         "jax.distributed job over a process-spanning "
                         "fleet mesh (CPU device emulation) and record "
                         "lane-epochs/sec + scaling per process count")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink the --multihost sweep to 1/2 processes "
                         "(the CI multihost-smoke job); with --graph, run "
                         "only a small structural lane (the CI graph "
                         "smoke job)")
    ap.add_argument("--multihost-devices", type=int, default=2,
                    help="emulated CPU devices per worker process in the "
                         "--multihost sweep")
    ap.add_argument("--multihost-worker", action="store_true",
                    help=argparse.SUPPRESS)       # internal: one mh rank
    ap.add_argument("--worker-out", default=None,
                    help=argparse.SUPPRESS)       # internal: rank-0 result
    ap.add_argument("--json", default=str(DEFAULT_JSON),
                    help="benchmark JSON artifact path ('' disables)")
    args = ap.parse_args()
    if args.multihost_worker:
        run_multihost_worker(args.fleet, args.epochs, args.app,
                             args.worker_out)
        return
    graph_only = args.graph and args.smoke
    rows = [] if graph_only else run_all(
        args.fleet, args.epochs, args.app, args.baseline_epochs,
        args.scenario_batched, args.broadcast_invariant,
        args.sharded, args.lifecycle, args.guards)
    if args.streaming and not graph_only:
        rows += run_streaming(args.streaming_fleet, args.epochs, args.app)
    if args.graph:
        rows += run_graph(3 if args.smoke else args.graph_fleet,
                          8 if args.smoke else args.epochs, smoke=args.smoke)
    if args.multihost:
        rows += run_multihost(args.fleet, args.epochs, args.app,
                              smoke=args.smoke,
                              devices_per_proc=args.multihost_devices,
                              json_path=args.json)
    print("name,us_per_call,derived")
    for row in rows:
        name, us, derived = row[:3]
        print(f"{name},{us:.1f},{derived}", flush=True)
    if args.json:
        out = pathlib.Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        prov = provenance()
        out.write_text(json.dumps(
            [{"name": r[0], "us_per_call": round(r[1], 2), "derived": r[2],
              "provenance": (r[3] if len(r) > 3 else prov)}
             for r in rows], indent=2))
        print(f"wrote {out}")


if __name__ == "__main__":
    main()
