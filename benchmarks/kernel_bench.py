"""Kernel microbenchmarks: XLA reference paths timed on CPU (wall time is
NOT a TPU prediction — the derived column reports the structural metric
that matters per kernel: exact-causal FLOPs, VMEM working set, etc.).
Pallas kernels themselves are validated in interpret mode (tests/) and
only meaningfully timed on real TPU hardware."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def timeit(fn, *args, iters: int = 3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_flash_attention_ref() -> list[tuple]:
    from repro.models.attention import flash_attention
    rows = []
    for (S, H, Hkv, hd) in [(1024, 8, 2, 64), (2048, 8, 2, 64)]:
        B = 1
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
        f = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True))
        us = timeit(f, q, k, v)
        useful_flops = 2 * 2 * B * H * hd * S * (S + 1) / 2
        rows.append((f"flash_attn_ref_S{S}", us,
                     f"causal_flops={useful_flops:.3e}"))
    return rows


def bench_wkv6_ref() -> list[tuple]:
    from repro.kernels.rwkv6_scan.ref import wkv6_ref
    rows = []
    for (T, H, hd) in [(512, 8, 64), (1024, 8, 64)]:
        B = 1
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        w = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, H, hd))) * .5 + .45
        r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) for i in (1, 2, 3))
        u = jax.random.normal(ks[4], (H, hd)) * 0.1
        f = jax.jit(lambda *a: wkv6_ref(*a)[0])
        us = timeit(f, w, r, k, v, u)
        state_bytes = H * hd * hd * 4
        rows.append((f"wkv6_ref_T{T}", us,
                     f"vmem_state_bytes={state_bytes}"))
    return rows


def bench_knn_projection() -> list[tuple]:
    from repro.core.knn_projection import knn_actions_exact, knn_actions_jax
    rows = []
    for (n, m, k) in [(100, 10, 16), (100, 10, 32)]:
        proto = np.random.default_rng(0).uniform(size=(n, m))
        t0 = time.perf_counter()
        for _ in range(20):
            knn_actions_exact(proto, k)
        us = (time.perf_counter() - t0) / 20 * 1e6
        rows.append((f"knn_exact_N{n}M{m}K{k}", us,
                     "replaces_gurobi_miqp~10000us"))
        pj = jnp.asarray(proto)
        f = jax.jit(lambda p, k=k: knn_actions_jax(p, k))
        us = timeit(f, pj)
        rows.append((f"knn_beam_N{n}M{m}K{k}", us, "jit_in-graph"))
        # Pallas-backed top-2/regret reduction (kernels/knn_topk); interpret
        # mode off-TPU, so CPU wall time here is a correctness smoke, not a
        # TPU prediction
        fp = jax.jit(lambda p, k=k: knn_actions_jax(p, k, use_pallas=True))
        us = timeit(fp, pj)
        rows.append((f"knn_beam_pallas_N{n}M{m}K{k}", us,
                     "row_top2_regret_kernel"))
    return rows


def bench_simulator() -> list[tuple]:
    from repro.dsdps import SchedulingEnv, apps
    from repro.dsdps.apps import default_workload
    topo = apps.continuous_queries("large")
    env = SchedulingEnv(topo, default_workload(topo))
    w = env.workload.init()
    X = env.round_robin_assignment()
    f = jax.jit(lambda X, w: env.evaluate(X, w))
    us = timeit(f, X, w)
    return [("dsdps_sim_eval_100x10", us, "env_reward_latency")]


def run_all() -> list[tuple]:
    rows = []
    rows += bench_simulator()
    rows += bench_knn_projection()
    rows += bench_flash_attention_ref()
    rows += bench_wkv6_ref()
    return rows
