"""Control-plane serving microbenchmark: decision latency + throughput.

Times three ways of answering the same request load — N concurrent
per-cluster decision asks against a fixed registry of heterogeneous live
clusters (perturbed EnvParams) — with the trained-policy serving path of
``repro/serve/control.py``:

* ``sequential`` — one jitted ``Agent.select`` dispatch per request
  (:func:`~repro.serve.control.single_select_program`), the per-cluster
  baseline a naive service would run;
* ``batched`` — the :class:`~repro.serve.control.ControlPlane` slot
  scheduler: FIFO admission into a fixed slot pool, every active slot
  served in ONE vmapped dispatch that gathers each slot's cluster row
  from the broadcast-invariant params stack;
* ``batched_donated`` — the same plane with the per-step key/state-vector
  buffers donated (accelerator backends only; donation is a no-op on CPU
  and the row is marked ``donated=inactive_on_cpu``).

Every request in every path is "submitted" at t0, so queueing delay —
not just compute — lands in the reported p50/p99, exactly as a live
service would bill it.  The bench ASSERTS the acceptance contract: the
batched plane's decisions bit-match the per-cluster single selects
(explore=False) request-for-request, and batched is strictly faster per
decision than sequential.

  PYTHONPATH=src python -m benchmarks.serve_bench [--clusters 6]
      [--requests 96] [--slots 8] [--smoke]
      [--json artifacts/serve_bench.json]

Rows are ``name,us_per_call,derived`` — the benchmarks.run CSV schema
(us_per_call = microseconds per decision); the same rows are written to
the JSON artifact."""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from repro.core import make_agent
from repro.dsdps import SchedulingEnv, apps, scenarios
from repro.dsdps.apps import default_workload
from repro.serve.control import (ControlPlane, DecisionRequest,
                                 latency_stats, single_select_program)

DEFAULT_JSON = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / \
    "serve_bench.json"


def _request_load(env, cluster_names, n_requests: int, seed: int = 0):
    """(rid, cluster, s_vec) triples — random feasible assignments +
    lognormal-jittered spout loads, round-robined over the clusters."""
    rng = np.random.default_rng(seed)
    load = []
    for rid in range(n_requests):
        X = np.eye(env.M, dtype=np.float32)[rng.integers(0, env.M, env.N)]
        w = np.exp(rng.normal(0.0, 0.25, env.workload.num_spouts))
        s_vec = np.concatenate([X.reshape(-1), w.astype(np.float32)])
        load.append((rid, cluster_names[rid % len(cluster_names)], s_vec))
    return load


def _run_sequential(agent, state, params_by_name, load, key):
    """One jitted select per request; every request submitted at t0."""
    prog = single_select_program(agent, False)
    rid0, c0, s0 = load[0]
    key, kw = jax.random.split(key)
    np.asarray(prog(kw, state, s0, params_by_name[c0]))       # warm/compile
    actions, lats = {}, []
    t0 = time.perf_counter()
    for rid, c, s in load:
        key, k = jax.random.split(key)
        actions[rid] = np.asarray(prog(k, state, s, params_by_name[c]))
        lats.append((time.perf_counter() - t0) * 1e3)
    wall = time.perf_counter() - t0
    return actions, lats, wall


def _run_batched(env, agent, state, params_by_name, load, key,
                 n_slots: int, donate: bool):
    """The ControlPlane slot scheduler over the same load, warmed first."""
    plane = ControlPlane(env, agent, state, kind="placement",
                         n_slots=n_slots, donate=donate)
    for name, p in params_by_name.items():
        plane.register_cluster(name, p)
    key, kw = jax.random.split(key)
    for rid, c, s in load[:n_slots]:                          # warm/compile
        plane.submit(DecisionRequest(rid=-1 - rid, cluster=c, s_vec=s))
    plane.run(kw)
    plane.reset_stats()
    reqs = [DecisionRequest(rid=rid, cluster=c, s_vec=s)
            for rid, c, s in load]
    t0 = time.perf_counter()
    for r in reqs:
        plane.submit(r)
    done = plane.run(key)
    wall = time.perf_counter() - t0
    actions = {r.rid: np.asarray(r.action) for r in done}
    return actions, list(plane._latencies_ms), wall


def run_all(app: str = "cq_small", clusters: int = 6, requests: int = 96,
            slots: int = 8, seed: int = 0) -> list[tuple]:
    topo = apps.ALL_APPS[app]()
    env = SchedulingEnv(topo, default_workload(topo))
    agent = make_agent("ddpg", env, k_nn=8)
    state = agent.init(jax.random.PRNGKey(seed))
    key = jax.random.PRNGKey(seed + 1)
    params_by_name = {}
    for c in range(clusters):
        key, k = jax.random.split(key)
        params_by_name[f"cluster-{c}"] = scenarios.sample_perturbed(env, k)
    load = _request_load(env, list(params_by_name), requests, seed)
    rows = []
    key, k_seq, k_bat, k_don = jax.random.split(key, 4)

    seq_actions, seq_lats, seq_wall = _run_sequential(
        agent, state, params_by_name, load, k_seq)
    seq = latency_stats(seq_lats)
    rows.append((f"serve_bench_{app}_sequential_c{clusters}_r{requests}",
                 seq_wall / requests * 1e6,
                 f"decisions_per_sec={requests / seq_wall:.0f};"
                 f"p50_ms={seq['p50_ms']:.3f};p99_ms={seq['p99_ms']:.3f}"))

    bat_actions, bat_lats, bat_wall = _run_batched(
        env, agent, state, params_by_name, load, k_bat, slots, donate=False)
    bat = latency_stats(bat_lats)
    bitmatch = len(bat_actions) == requests and all(
        np.array_equal(bat_actions[rid], seq_actions[rid])
        for rid, _, _ in load)
    rows.append((f"serve_bench_{app}_batched_s{slots}_c{clusters}"
                 f"_r{requests}",
                 bat_wall / requests * 1e6,
                 f"decisions_per_sec={requests / bat_wall:.0f};"
                 f"p50_ms={bat['p50_ms']:.3f};p99_ms={bat['p99_ms']:.3f};"
                 f"speedup_vs_sequential={seq_wall / bat_wall:.1f}x;"
                 f"bitmatch_vs_sequential={'ok' if bitmatch else 'FAIL'}"))

    donate = jax.default_backend() != "cpu"
    don_actions, don_lats, don_wall = _run_batched(
        env, agent, state, params_by_name, load, k_don, slots, donate=donate)
    don = latency_stats(don_lats)
    don_bitmatch = len(don_actions) == requests and all(
        np.array_equal(don_actions[rid], seq_actions[rid])
        for rid, _, _ in load)
    rows.append((f"serve_bench_{app}_batched_donated_s{slots}_c{clusters}"
                 f"_r{requests}",
                 don_wall / requests * 1e6,
                 f"decisions_per_sec={requests / don_wall:.0f};"
                 f"p50_ms={don['p50_ms']:.3f};p99_ms={don['p99_ms']:.3f};"
                 f"speedup_vs_sequential={seq_wall / don_wall:.1f}x;"
                 f"donated={'active' if donate else 'inactive_on_cpu'};"
                 f"bitmatch_vs_sequential="
                 f"{'ok' if don_bitmatch else 'FAIL'}"))

    # the acceptance contract, enforced where it is measured
    if not (bitmatch and don_bitmatch):
        raise AssertionError(
            "batched decisions do not bit-match the per-cluster single "
            "selects (explore=False) — see the FAIL row above")
    if bat_wall >= seq_wall:
        raise AssertionError(
            f"batched serving is not strictly faster per decision: "
            f"batched {bat_wall / requests * 1e6:.1f} us vs sequential "
            f"{seq_wall / requests * 1e6:.1f} us")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="cq_small", choices=list(apps.ALL_APPS))
    ap.add_argument("--clusters", type=int, default=6)
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (<= 3 clusters, 24 requests, "
                         "4 slots)")
    ap.add_argument("--json", default=str(DEFAULT_JSON),
                    help="benchmark JSON artifact path ('' disables)")
    args = ap.parse_args()
    if args.smoke:
        args.clusters = min(args.clusters, 3)
        args.requests = min(args.requests, 24)
        args.slots = min(args.slots, 4)
    rows = run_all(args.app, args.clusters, args.requests, args.slots,
                   args.seed)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    if args.json:
        out = pathlib.Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(
            [{"name": n, "us_per_call": round(us, 2), "derived": d}
             for n, us, d in rows], indent=2))
        print(f"wrote {out}")


if __name__ == "__main__":
    main()
