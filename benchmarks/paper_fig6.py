"""Paper Fig 6: average tuple processing time on the continuous-queries
topology, small/medium/large, × {default, model-based, DQN, actor-critic}.

DRL entries are mean ± std over a fleet of budget.n_seeds independent
seeds (one batched run), and fig6.json includes the seed-averaged online
reward curves with variance bands (``{dqn,ac}_curve_mean/std``).

  python -m benchmarks.paper_fig6 [--paper-budget] [--seed N]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.paper_common import Budget, compare_all

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "paper"


def run(budget: Budget, seed: int = 0) -> list[dict]:
    results = []
    for app in ("cq_small", "cq_medium", "cq_large"):
        out = compare_all(app, budget, seed)
        out.pop("_dqn_hist"), out.pop("_ac_hist")
        results.append(out)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-budget", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    budget = Budget.paper() if args.paper_budget else Budget.quick()
    results = run(budget, args.seed)
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "fig6.json").write_text(json.dumps(results, indent=2))
    print("\npaper Fig6 reference (default / model / dqn / AC, ms):")
    print("  small  1.96 / 1.46 / 1.54 / 1.33   (paper)")
    print("  medium 2.08 / 1.61 / 1.59 / 1.43   (paper)")
    print("  large  2.64 / 2.12 / 2.45 / 1.72   (paper)")


if __name__ == "__main__":
    main()
