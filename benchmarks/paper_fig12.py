"""Paper Fig 12: robustness to a +50% workload change at mid-run —
actor-critic vs model-based on the three large-scale topologies.

The trained AC agent re-schedules online after the shift; the model-based
scheduler re-runs its search with the new workload (as [25] would).  The
shift itself is just an EnvParams edit (``scale_rates``) against the same
env spec — no env rebuild, and further shifts at the same horizon reuse
the compiled program — the functional-core payoff."""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.paper_common import (Budget, make_env, run_actor_critic,
                                     run_model_based)
from repro.core import make_agent, run_online_fleet
from repro.dsdps import SchedulingEnv, scenarios

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "paper"


def run(app: str, budget: Budget, seed: int = 0,
        shift_factor: float = 1.5) -> dict:
    env = make_env(app)
    # pre-train the agent fleet on the unshifted workload
    ac_lats0, _, (states, cfg) = run_actor_critic(env, budget, seed)
    mb_lat0, Xmb = run_model_based(env, budget, seed)

    # shifted scenario: both methods adapt.  For the DRL fleet the shift is
    # a traced-parameter change against the same env spec (no env rebuild);
    # constructed through the named-scenario module like every other fleet.
    shifted = scenarios.workload_shift(env, shift_factor)
    keys = jax.random.split(jax.random.PRNGKey(seed + 7), budget.n_seeds)
    states, hist = run_online_fleet(
        keys, env, make_agent("ddpg", env, cfg=cfg), states,
        T=max(budget.online_epochs // 3, 40),
        updates_per_epoch=budget.updates_per_epoch,
        env_params=shifted)
    w_new = shifted.base_rates
    ac_after = [float(env.evaluate(
        jnp.asarray(hist.final_assignment[f]), w_new, params=shifted))
        for f in range(budget.n_seeds)]
    # model-based: refit search under new workload using its old model —
    # [25] profiles the (shifted) system, so it sees the shifted env spec
    wl = dataclasses.replace(env.workload,
                             base_rates=tuple(r * shift_factor
                                              for r in env.workload.base_rates))
    env_shift = SchedulingEnv(env.topo, wl, cluster=env.cluster,
                              noise_sigma=env.noise_sigma, seed=env.seed)
    from repro.core.model_based import ModelBasedScheduler
    mb = ModelBasedScheduler(env_shift).fit(jax.random.PRNGKey(seed),
                                            n_samples=budget.mb_samples)
    mb_after = float(env_shift.evaluate(mb.schedule(w_new, sweeps=3), w_new))
    return {"app": app, "n_seeds": budget.n_seeds,
            "ac_before": float(np.mean(ac_lats0)),
            "ac_before_std": float(np.std(ac_lats0)),
            "mb_before": mb_lat0,
            "ac_after_shift": float(np.mean(ac_after)),
            "ac_after_shift_std": float(np.std(ac_after)),
            "ac_after_seeds": ac_after,
            "mb_after_shift": mb_after,
            "shift_factor": shift_factor}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-budget", action="store_true")
    ap.add_argument("--apps", nargs="+",
                    default=["cq_large", "log_stream", "word_count"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    budget = Budget.paper() if args.paper_budget else Budget.quick()
    results = []
    for app in args.apps:
        out = run(app, budget, args.seed)
        results.append(out)
        print(f"[{app}] AC {out['ac_before']:.2f}±{out['ac_before_std']:.2f} "
              f"-> {out['ac_after_shift']:.2f}±{out['ac_after_shift_std']:.2f}ms "
              f"({out['n_seeds']} seeds), "
              f"model-based {out['mb_before']:.2f} -> {out['mb_after_shift']:.2f}ms "
              f"after +{(out['shift_factor'] - 1):.0%} workload "
              f"(paper Fig12 cq_large: AC 1.76 vs MB 2.17)", flush=True)
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "fig12.json").write_text(json.dumps(results, indent=2))


if __name__ == "__main__":
    main()
