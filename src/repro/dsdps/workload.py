"""Workload (spout arrival-rate) processes.

The state in the paper is (X, w) where w is the tuple arrival rate of each
data source; adaptivity to w is a headline feature (Fig 12: +50% shift).

Two surfaces:

  * ``WorkloadProcess`` — the declarative spec (hashable frozen dataclass,
    part of the SchedulingEnv static spec);
  * ``step_rates`` — the pure transition function the functional env API
    drives with rate parameters taken from an ``EnvParams`` pytree, so a
    fleet of lanes can carry *different* base rates / jitter / shift
    schedules through one vmapped program."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Sentinel "never shifts" epoch for the traced shift schedule: the paper's
# Fig 12 step change is expressed as `epoch >= shift_epoch`, so an epoch no
# run ever reaches disables it without a Python-level branch.
NEVER_SHIFT: int = 2 ** 30


def step_rates(
    key: jax.Array,
    w: jnp.ndarray,
    epoch: jnp.ndarray,
    base_rates: jnp.ndarray,
    jitter: jnp.ndarray,
    revert: jnp.ndarray,
    shift_epoch: jnp.ndarray = NEVER_SHIFT,
    shift_factor: jnp.ndarray = 1.5,
) -> jnp.ndarray:
    """One epoch of the mean-reverting multiplicative random walk, with all
    rate parameters as (traceable, vmappable) arguments."""
    base = jnp.where(epoch >= shift_epoch, base_rates * shift_factor,
                     base_rates)
    z = jax.random.normal(key, w.shape) * jitter
    target = base * jnp.exp(z)
    return w + revert * (target - w)


@dataclasses.dataclass(frozen=True)
class WorkloadProcess:
    """Mean-reverting multiplicative random walk around a base rate, with an
    optional step change (Fig 12's +50% shift at a given epoch)."""

    base_rates: tuple[float, ...]       # tuples/sec per spout executor
    jitter: float = 0.05                # per-epoch lognormal sigma
    revert: float = 0.2                 # pull toward base
    shift_epoch: int | None = None      # epoch at which rates jump
    shift_factor: float = 1.5

    @property
    def num_spouts(self) -> int:
        return len(self.base_rates)

    def init(self) -> jnp.ndarray:
        return jnp.asarray(self.base_rates)

    def step(self, key: jax.Array, w: jnp.ndarray, epoch: jnp.ndarray) -> jnp.ndarray:
        shift = self.shift_epoch if self.shift_epoch is not None else NEVER_SHIFT
        return step_rates(key, w, epoch, jnp.asarray(self.base_rates),
                          self.jitter, self.revert, shift, self.shift_factor)


def constant(rates: tuple[float, ...]) -> WorkloadProcess:
    return WorkloadProcess(base_rates=rates, jitter=0.0, revert=1.0)
