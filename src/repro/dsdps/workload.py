"""Workload (spout arrival-rate) processes.

The state in the paper is (X, w) where w is the tuple arrival rate of each
data source; adaptivity to w is a headline feature (Fig 12: +50% shift)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class WorkloadProcess:
    """Mean-reverting multiplicative random walk around a base rate, with an
    optional step change (Fig 12's +50% shift at a given epoch)."""

    base_rates: tuple[float, ...]       # tuples/sec per spout executor
    jitter: float = 0.05                # per-epoch lognormal sigma
    revert: float = 0.2                 # pull toward base
    shift_epoch: int | None = None      # epoch at which rates jump
    shift_factor: float = 1.5

    @property
    def num_spouts(self) -> int:
        return len(self.base_rates)

    def init(self) -> jnp.ndarray:
        return jnp.asarray(self.base_rates)

    def step(self, key: jax.Array, w: jnp.ndarray, epoch: jnp.ndarray) -> jnp.ndarray:
        base = jnp.asarray(self.base_rates)
        if self.shift_epoch is not None:
            base = jnp.where(epoch >= self.shift_epoch, base * self.shift_factor, base)
        z = jax.random.normal(key, w.shape) * self.jitter
        target = base * jnp.exp(z)
        return w + self.revert * (target - w)


def constant(rates: tuple[float, ...]) -> WorkloadProcess:
    return WorkloadProcess(base_rates=rates, jitter=0.0, revert=1.0)
