"""The paper's three evaluation applications (§4.1), at the paper's exact
executor counts, plus the cluster spec of the testbed.

Service demands / tuple sizes / arrival rates are calibration constants
chosen so the *default round-robin scheduler on the large-scale setup*
reproduces the paper's measured stabilized latencies (Fig 6c/8/10):
continuous queries ≈ 2.6 ms, log stream ≈ 9.6 ms, word count ≈ 3.1 ms.
See benchmarks/calibration.py for the fit."""
from __future__ import annotations

from repro.dsdps.topology import ALL, FIELDS, GLOBAL, SHUFFLE, Component, Edge, Topology
from repro.dsdps.workload import WorkloadProcess


def continuous_queries(scale: str = "large") -> Topology:
    """spout -> Query -> File  (select-query over an in-memory table)."""
    counts = {
        "small": (2, 9, 9),
        "medium": (5, 25, 20),
        "large": (10, 45, 45),
    }[scale]
    sp, q, f = counts
    return Topology(
        name=f"continuous_queries_{scale}",
        components=[
            Component("spout", sp, cpu_ms_per_tuple=0.03, selectivity=1.0,
                      tuple_bytes=180, is_spout=True),
            Component("query", q, cpu_ms_per_tuple=0.55, selectivity=0.30,
                      tuple_bytes=320),
            Component("file", f, cpu_ms_per_tuple=0.35, selectivity=0.0,
                      tuple_bytes=64),
        ],
        edges=[
            Edge("spout", "query", SHUFFLE),
            Edge("query", "file", SHUFFLE),
        ],
    )


def log_stream_processing() -> Topology:
    """spout -> LogRules -> {Indexer -> DB_i, Counter -> DB_c} (ack joins)."""
    return Topology(
        name="log_stream_processing",
        components=[
            Component("spout", 10, cpu_ms_per_tuple=0.05, selectivity=1.0,
                      tuple_bytes=900, is_spout=True),
            Component("logrules", 20, cpu_ms_per_tuple=1.10, selectivity=1.0,
                      tuple_bytes=700),
            Component("indexer", 20, cpu_ms_per_tuple=0.90, selectivity=1.0,
                      tuple_bytes=500),
            Component("counter", 20, cpu_ms_per_tuple=0.60, selectivity=1.0,
                      tuple_bytes=96),
            Component("db_index", 15, cpu_ms_per_tuple=1.30, selectivity=0.0,
                      tuple_bytes=64),
            Component("db_count", 15, cpu_ms_per_tuple=0.80, selectivity=0.0,
                      tuple_bytes=64),
        ],
        edges=[
            Edge("spout", "logrules", SHUFFLE),
            Edge("logrules", "indexer", SHUFFLE),
            Edge("logrules", "counter", SHUFFLE),
            Edge("indexer", "db_index", SHUFFLE),
            Edge("counter", "db_count", FIELDS, skew=0.6),
        ],
    )


def word_count() -> Topology:
    """spout -> SplitSentence -> WordCount (fields) -> Database."""
    return Topology(
        name="word_count",
        components=[
            Component("spout", 10, cpu_ms_per_tuple=0.04, selectivity=1.0,
                      tuple_bytes=600, is_spout=True),
            Component("split", 30, cpu_ms_per_tuple=0.28, selectivity=8.0,
                      tuple_bytes=48),
            Component("count", 30, cpu_ms_per_tuple=0.06, selectivity=0.12,
                      tuple_bytes=40),
            Component("db", 30, cpu_ms_per_tuple=0.45, selectivity=0.0,
                      tuple_bytes=40),
        ],
        edges=[
            Edge("spout", "split", SHUFFLE),
            Edge("split", "count", FIELDS, skew=0.8),
            Edge("count", "db", SHUFFLE),
        ],
    )


def diamond(parallelism: int = 4) -> Topology:
    """spout -> fork -> {left, right} -> merge — the canonical ack-join
    diamond for the structural (DAG-shape) scenario fleets: two parallel
    branches whose completion times max-join at the merge bolt."""
    return Topology(
        name="diamond",
        components=[
            Component("spout", 2, cpu_ms_per_tuple=0.03, selectivity=1.0,
                      tuple_bytes=200, is_spout=True),
            Component("fork", parallelism, cpu_ms_per_tuple=0.30,
                      selectivity=2.0, tuple_bytes=260),
            Component("left", parallelism, cpu_ms_per_tuple=0.55,
                      selectivity=0.5, tuple_bytes=180),
            Component("right", parallelism, cpu_ms_per_tuple=0.40,
                      selectivity=0.5, tuple_bytes=220),
            Component("merge", parallelism, cpu_ms_per_tuple=0.35,
                      selectivity=0.0, tuple_bytes=64),
        ],
        edges=[
            Edge("spout", "fork", SHUFFLE),
            Edge("fork", "left", SHUFFLE),
            Edge("fork", "right", FIELDS, skew=0.5),
            Edge("left", "merge", SHUFFLE),
            Edge("right", "merge", SHUFFLE),
        ],
    )


def wide_fanout(branches: int = 4) -> Topology:
    """spout -> router -> {b0..b(k-1)} -> collector — one router replicated
    to ``branches`` parallel bolts (the wide-fan-out structural stress:
    completion is the max over many sibling branches)."""
    comps = [
        Component("spout", 2, cpu_ms_per_tuple=0.03, selectivity=1.0,
                  tuple_bytes=240, is_spout=True),
        Component("router", 3, cpu_ms_per_tuple=0.20, selectivity=1.0,
                  tuple_bytes=240),
    ]
    edges = [Edge("spout", "router", SHUFFLE)]
    for b in range(branches):
        comps.append(Component(f"b{b}", 2, cpu_ms_per_tuple=0.35 + 0.05 * b,
                               selectivity=1.0 / branches, tuple_bytes=160))
        edges.append(Edge("router", f"b{b}", SHUFFLE))
        edges.append(Edge(f"b{b}", "collector", SHUFFLE))
    comps.append(Component("collector", 3, cpu_ms_per_tuple=0.25,
                           selectivity=0.0, tuple_bytes=64))
    return Topology(name="wide_fanout", components=comps, edges=edges)


# Spout arrival rates (tuples/sec per spout executor) for each app — chosen
# so the cluster runs at moderate utilization under round-robin (the paper's
# cluster was loaded but "not overloaded", §4.2).
def default_workload(topo: Topology) -> WorkloadProcess:
    per_spout = {
        "continuous_queries_small": 1500.0,
        "continuous_queries_medium": 1300.0,
        "continuous_queries_large": 1100.0,
        "log_stream_processing": 130.0,
        "word_count": 550.0,
        "diamond": 900.0,
        "wide_fanout": 800.0,
    }[topo.name]
    n_spout = int(len(topo.spout_executors))
    return WorkloadProcess(base_rates=(per_spout,) * n_spout)


ALL_APPS = {
    "cq_small": lambda: continuous_queries("small"),
    "cq_medium": lambda: continuous_queries("medium"),
    "cq_large": lambda: continuous_queries("large"),
    "log_stream": log_stream_processing,
    "word_count": word_count,
    "diamond": diamond,
    "wide_fanout": wide_fanout,
}


# the default structural-fleet topology set: chain (cq_small), diamond,
# wide fan-out — three DAG shapes padded into one envelope (see
# repro.dsdps.structural and the `dag_shapes` scenario)
STRUCTURAL_APPS = ("cq_small", "diamond", "wide_fanout")


def structural_topologies() -> list[Topology]:
    return [ALL_APPS[name]() for name in STRUCTURAL_APPS]
