"""Structural scheduling fleets: many DAG *shapes* in one XLA program.

``SchedulingEnv`` bakes its topology into jit-static structure
(``SimParams``: reverse-topological schedules, component membership,
spout index arrays), so every fleet lane must share one graph.  This
module moves the structure into the traced params pytree instead:

  * :class:`Envelope` — the common padded size (max executors / edges /
    spouts / components) a set of topologies is embedded into;
  * :class:`GraphEnvParams` — ``EnvParams`` plus masked structure leaves
    (node mask, spout/component one-hots, edge index/weight arrays), so a
    *stacked* fleet carries a different DAG per lane;
  * :class:`StructuralSchedulingEnv` — the same functional env API as
    ``SchedulingEnv`` (reset/step/state_vector/evaluate/reset_fleet) with
    a padding-exact latency model: padded executors have zero service,
    zero flow, zero mask, and are provably inert in every term.

The completion-time recursion (reverse topo order in ``_latency_core``)
is replaced by a fixed-depth dense relaxation over ``R @ comp_onehot`` —
mathematically identical for DAGs (executors of one component share a
downstream set; nodes of downstream-height ``h`` are exact after ``h``
iterations, and height < max_components), but with no Python-level
dependence on any single topology, so three different DAGs compile into
ONE program."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.dsdps import apps as _apps
from repro.dsdps.cluster import ClusterSpec, PAPER_CLUSTER
from repro.dsdps.env import EnvState, StepOut
from repro.dsdps.simulator import (_congestion, build_sim_params,
                                   params_in_axes)
from repro.dsdps.topology import Topology
from repro.dsdps.workload import NEVER_SHIFT, WorkloadProcess, step_rates


@dataclasses.dataclass(frozen=True)
class Envelope:
    """Common padded sizes a set of topologies is embedded into."""

    max_execs: int
    max_edges: int
    max_spouts: int
    max_components: int

    @classmethod
    def for_topologies(cls, topos: Sequence[Topology], seed: int = 0,
                       headroom: int = 0) -> "Envelope":
        """Tight envelope over ``topos`` (optionally ``headroom`` extra
        executor slots, for padding-invariance experiments)."""
        execs = max(t.num_executors for t in topos)
        edges = max(int(np.count_nonzero(t.routing_matrix(seed))) for t in topos)
        spouts = max(len(t.spout_executors) for t in topos)
        comps = max(len(t.components) for t in topos)
        return cls(max_execs=execs + headroom, max_edges=edges + headroom,
                   max_spouts=spouts, max_components=comps)


class GraphEnvParams(NamedTuple):
    """``EnvParams`` plus traced (per-lane) topology structure.

    Field names/prefix match :class:`~repro.dsdps.simulator.EnvParams`, so
    every ``_replace``-based scenario helper (``with_straggler``,
    ``scale_rates``, ``perturb_service``, …) and the stack/axes/lane
    machinery work unchanged.  Padded entries are zeros (index arrays use
    the sacrificial index ``N``), which every consumer masks exactly."""

    routing: jnp.ndarray             # [N, N] padded executor routing matrix
    flow_solve: jnp.ndarray          # [N, N] (I - R^T)^-1, identity on padding
    service_ms: jnp.ndarray          # [N] true CPU ms / tuple (0 on padding)
    nominal_service_ms: jnp.ndarray  # [N]
    tuple_bytes: jnp.ndarray         # [N]
    acker_ms: jnp.ndarray            # scalar
    speed: jnp.ndarray               # [M]
    noise_sigma: jnp.ndarray         # scalar
    base_rates: jnp.ndarray          # [S] padded with zeros
    rate_jitter: jnp.ndarray         # scalar
    rate_revert: jnp.ndarray         # scalar
    shift_epoch: jnp.ndarray         # scalar int32
    shift_factor: jnp.ndarray        # scalar
    node_mask: jnp.ndarray           # [N] 1.0 on real executors
    spout_onehot: jnp.ndarray        # [S, N] one-hot spout rows (0 on padding)
    comp_onehot: jnp.ndarray         # [N, C] executor->component (0 on padding)
    edge_src: jnp.ndarray            # [E] int32 (padding = N, sacrificial)
    edge_dst: jnp.ndarray            # [E] int32 (padding = N, sacrificial)
    edge_w: jnp.ndarray              # [E] R[src, dst] (0 on padding)
    edge_mask: jnp.ndarray           # [E]


def graph_latency_ms(X: jnp.ndarray, w: jnp.ndarray, gp: GraphEnvParams,
                     cluster: ClusterSpec,
                     speed: jnp.ndarray | None = None) -> jnp.ndarray:
    """The ``_latency_core`` queueing model with all structure traced.

    Identical math on the real sub-graph (padding contributes exactly
    nothing: zero mask, zero service, zero flow), with the reverse-topo
    completion recursion replaced by ``max_components`` dense relaxation
    steps — see the module docstring."""
    mask = gp.node_mask
    X = X * mask[:, None]
    speed = gp.speed if speed is None else speed
    R = gp.routing
    n = X.shape[0]

    # 1. steady-state executor tuple rates (tuples/sec)
    w_full = gp.spout_onehot.T @ w                                    # [N]
    lam = gp.flow_solve @ w_full

    same_mach = X @ X.T
    same_proc = same_mach
    edge_rate = lam[:, None] * R
    cross_proc = edge_rate * (1.0 - same_proc)
    cross_mach = edge_rate * (1.0 - same_mach)

    # 2. machine CPU contention (padded executors: X row zero => no demand)
    c_ms = gp.service_ms
    ser_ms = cluster.ser_base_ms + gp.tuple_bytes * cluster.ser_ms_per_kb / 1024.0
    base_demand = (X * (lam * c_ms / 1e3)[:, None]).sum(0)
    ser_out = (X * (cross_proc.sum(1) * ser_ms / 1e3)[:, None]).sum(0)
    ser_in = (X * ((cross_proc * ser_ms[:, None]).sum(0) / 1e3)[:, None]).sum(0)
    n_procs = (X.sum(0) > 0).astype(jnp.float32)
    proc_burn = n_procs * cluster.proc_overhead_cores
    presence = jnp.clip(gp.comp_onehot.T @ X, 0.0, 1.0)               # [C, M]
    n_comp = presence.sum(0)
    mix = 1.0 + cluster.mix_penalty * jnp.maximum(n_comp - 1.0, 0.0)
    demand = (base_demand + ser_out + ser_in) * mix / speed + proc_burn
    g_m = _congestion(demand / cluster.cores_per_machine)             # [M]

    # 3. per-executor sojourn (0 on padding: c_ms = 0)
    inflate = X @ (g_m / speed)
    s_eff = c_ms * inflate
    sojourn = s_eff * _congestion(lam * s_eff / 1e3)                  # [N]

    # 4. transfer delays with NIC contention
    bytes_per_s = cross_mach * gp.tuple_bytes[:, None]
    out_load = (X * bytes_per_s.sum(1)[:, None]).sum(0)
    in_load = (X * bytes_per_s.sum(0)[:, None]).sum(0)
    nic_cap = cluster.nic_bytes_per_ms * 1e3
    nic_g = _congestion(jnp.maximum(out_load, in_load) / nic_cap)
    nic_factor = 0.5 * (X @ nic_g)[:, None] + 0.5 * (X @ nic_g)[None, :]
    wire_ms = gp.tuple_bytes[:, None] / cluster.nic_bytes_per_ms
    ser_path = 2.0 * ser_ms[:, None]
    d_edge = jnp.where(
        same_proc > 0.5,
        cluster.local_base_ms,
        jnp.where(
            same_mach > 0.5,
            cluster.ipc_base_ms + ser_path,
            cluster.net_base_ms + ser_path + wire_ms * nic_factor,
        ),
    )                                                                 # [N, N]

    # 5. completion times: fixed-depth relaxation of the reverse-topo
    # recursion.  mass[i, c] = outgoing routing mass of executor i into
    # component c; a branch's expected hop is the mass-weighted mean, the
    # downstream cost the max over branched-to components (ack joins).
    mass = R @ gp.comp_onehot                                         # [N, C]
    has = mass > 1e-9
    any_down = has.any(axis=1)
    mass_safe = jnp.maximum(mass, 1e-12)
    completion = sojourn
    depth = gp.comp_onehot.shape[1]
    for _ in range(depth):
        hop = d_edge + completion[None, :]                            # [N, N]
        branch = ((R * hop) @ gp.comp_onehot) / mass_safe             # [N, C]
        downstream = jnp.where(has, branch, -jnp.inf).max(axis=1)
        downstream = jnp.where(any_down, downstream, 0.0)
        completion = sojourn + downstream

    w_safe = jnp.maximum(w, 0.0)
    comp_sp = gp.spout_onehot @ completion                            # [S]
    avg = (w_safe * comp_sp).sum() / jnp.maximum(w_safe.sum(), 1e-9)
    return avg + gp.acker_ms


def measured_graph_latency_ms(key: jax.Array, X: jnp.ndarray, w: jnp.ndarray,
                              gp: GraphEnvParams, cluster: ClusterSpec,
                              speed: jnp.ndarray | None = None,
                              n_measurements: int = 5) -> jnp.ndarray:
    """Mean of ``n_measurements`` lognormal-noised readings (same protocol
    as ``measured_latency_from_params``)."""
    base = graph_latency_ms(X, w, gp, cluster, speed=speed)
    z = jax.random.normal(key, (n_measurements,)) * gp.noise_sigma
    return (base * jnp.exp(z)).mean()


@dataclasses.dataclass(eq=False)
class StructuralSchedulingEnv:
    """One padded envelope over several topologies; same functional env API
    as ``SchedulingEnv`` (identity hash — valid jit static), but every
    lane of a stacked :class:`GraphEnvParams` fleet may run a *different*
    DAG shape through one XLA program."""

    topologies: Sequence[Topology]
    workloads: Sequence[WorkloadProcess] | None = None
    envelope: Envelope | None = None
    cluster: ClusterSpec = PAPER_CLUSTER
    noise_sigma: float = 0.03
    seed: int = 0

    def __post_init__(self) -> None:
        self.topologies = tuple(self.topologies)
        if not self.topologies:
            raise ValueError("StructuralSchedulingEnv needs >= 1 topology")
        if self.workloads is None:
            self.workloads = tuple(_apps.default_workload(t)
                                   for t in self.topologies)
        self.workloads = tuple(self.workloads)
        if len(self.workloads) != len(self.topologies):
            raise ValueError("workloads must align 1:1 with topologies")
        if self.envelope is None:
            self.envelope = Envelope.for_topologies(self.topologies,
                                                    seed=self.seed)
        self.N = self.envelope.max_execs
        self.M = self.cluster.num_machines
        # reference topology/workload: build_for dispatch + default params
        self.topo = self.topologies[0]
        base = self.workloads[0]
        pad = self.envelope.max_spouts - len(base.base_rates)
        self.workload = dataclasses.replace(
            base, base_rates=tuple(base.base_rates) + (0.0,) * pad)
        self._default_params: GraphEnvParams | None = None

    # -- params ------------------------------------------------------------
    def params_for(self, topo: Topology,
                   workload: WorkloadProcess | None = None) -> GraphEnvParams:
        """Pad one topology into this env's envelope as a GraphEnvParams
        pytree.  Raises ``ValueError`` naming the topology and the
        offending envelope dimension when it does not fit — structure must
        never be silently truncated."""
        env_ = self.envelope
        gobs = topo.to_graph_obs(env_.max_execs, env_.max_edges,
                                 seed=self.seed)  # raises on exec/edge overflow
        n_spouts = len(topo.spout_executors)
        n_comps = len(topo.components)
        if n_spouts > env_.max_spouts or n_comps > env_.max_components:
            raise ValueError(
                f"topology {topo.name} exceeds graph envelope: "
                f"{n_spouts} spouts / {n_comps} components vs "
                f"max_spouts={env_.max_spouts} / "
                f"max_components={env_.max_components}"
            )
        if workload is None:
            for t, wl in zip(self.topologies, self.workloads):
                if t is topo or t.name == topo.name:
                    workload = wl
                    break
            else:
                workload = _apps.default_workload(topo)
        if len(workload.base_rates) != n_spouts:
            raise ValueError(
                f"workload has {len(workload.base_rates)} spout rates, "
                f"topology {topo.name} has {n_spouts} spout executors")

        sim = build_sim_params(topo, seed=self.seed)
        n, nmax = topo.num_executors, env_.max_execs
        routing = np.zeros((nmax, nmax))
        routing[:n, :n] = sim.routing
        flow = np.eye(nmax)
        flow[:n, :n] = sim.flow_solve

        def pad_vec(x, size):
            out = np.zeros(size)
            out[: len(x)] = x
            return out

        spout_onehot = np.zeros((env_.max_spouts, nmax))
        spout_onehot[np.arange(n_spouts), sim.spout_ids] = 1.0
        comp_onehot = np.zeros((nmax, env_.max_components))
        comp_onehot[np.arange(n), sim.exec_component] = 1.0
        shift = workload.shift_epoch if workload.shift_epoch is not None \
            else NEVER_SHIFT
        f32 = lambda x: jnp.asarray(x, jnp.float32)  # noqa: E731
        return GraphEnvParams(
            routing=f32(routing),
            flow_solve=f32(flow),
            service_ms=f32(pad_vec(sim.service_ms, nmax)),
            nominal_service_ms=f32(pad_vec(sim.nominal_service_ms, nmax)),
            tuple_bytes=f32(pad_vec(sim.tuple_bytes, nmax)),
            acker_ms=f32(sim.acker_ms),
            speed=f32(self.cluster.speed_factors()),
            noise_sigma=f32(self.noise_sigma),
            base_rates=f32(pad_vec(workload.base_rates, env_.max_spouts)),
            rate_jitter=f32(workload.jitter),
            rate_revert=f32(workload.revert),
            shift_epoch=jnp.asarray(shift, jnp.int32),
            shift_factor=f32(workload.shift_factor),
            node_mask=f32(gobs.node_mask),
            spout_onehot=f32(spout_onehot),
            comp_onehot=f32(comp_onehot),
            edge_src=jnp.asarray(gobs.edge_src, jnp.int32),
            edge_dst=jnp.asarray(gobs.edge_dst, jnp.int32),
            edge_w=f32(gobs.edge_w),
            edge_mask=f32(gobs.edge_mask),
        )

    def default_params(self) -> GraphEnvParams:
        if self._default_params is None:
            self._default_params = self.params_for(self.topologies[0],
                                                   self.workloads[0])
        return self._default_params

    # -- helpers -----------------------------------------------------------
    def round_robin_assignment(self) -> jnp.ndarray:
        idx = np.arange(self.N) % self.M
        return jnp.asarray(np.eye(self.M)[idx], dtype=jnp.float32)

    def state_vector(self, s: EnvState,
                     params: GraphEnvParams | None = None) -> jnp.ndarray:
        p = self.default_params() if params is None else params
        w_norm = s.w / (p.base_rates + 1e-9)   # exactly 0 on padded spouts
        return jnp.concatenate([s.X.reshape(-1), w_norm])

    @property
    def state_dim(self) -> int:
        return self.N * self.M + self.envelope.max_spouts

    @property
    def action_dim(self) -> int:
        return self.N * self.M

    # -- core API ----------------------------------------------------------
    def reset(self, key: jax.Array, params: GraphEnvParams | None = None,
              X0: jnp.ndarray | None = None) -> EnvState:
        p = self.default_params() if params is None else params
        X = self.round_robin_assignment() if X0 is None else X0
        return EnvState(
            X=X * p.node_mask[:, None],   # padded executors: zero rows
            w=p.base_rates,
            epoch=jnp.zeros((), jnp.int32),
            speed=p.speed,
        )

    def evaluate(self, X: jnp.ndarray, w: jnp.ndarray,
                 speed: jnp.ndarray | None = None,
                 params: GraphEnvParams | None = None) -> jnp.ndarray:
        """Noise-free steady-state latency (ms); X is masked internally, so
        an unmasked round-robin assignment scores correctly per lane."""
        p = self.default_params() if params is None else params
        return graph_latency_ms(X, w, p, self.cluster, speed=speed)

    def step(self, key: jax.Array, s: EnvState, action: jnp.ndarray,
             params: GraphEnvParams | None = None) -> StepOut:
        p = self.default_params() if params is None else params
        k_noise, k_w = jax.random.split(key)
        action = action * p.node_mask[:, None]
        moved = ((jnp.abs(action - s.X).sum(-1) > 0) * p.node_mask).sum()
        lat = measured_graph_latency_ms(k_noise, action, s.w, p, self.cluster,
                                        speed=s.speed)
        w_next = step_rates(k_w, s.w, s.epoch, p.base_rates, p.rate_jitter,
                            p.rate_revert, p.shift_epoch, p.shift_factor)
        nxt = EnvState(X=action, w=w_next, epoch=s.epoch + 1, speed=s.speed)
        return StepOut(state=nxt, reward=-lat, latency_ms=lat, moved=moved)

    def reset_fleet(self, keys: jax.Array, X0: jnp.ndarray | None = None,
                    speed_factors: jnp.ndarray | None = None,
                    params: GraphEnvParams | None = None) -> EnvState:
        """Stacked initial states ([F] leading axis); ``params`` may be a
        single GraphEnvParams or a stacked structural fleet."""
        p = self.default_params() if params is None else params
        axes = params_in_axes(p, self.default_params())
        if axes is not None:
            states = jax.vmap(lambda k, pp: self.reset(k, pp, X0=X0),
                              in_axes=(0, axes))(keys, p)
        else:
            states = jax.vmap(lambda k: self.reset(k, p, X0=X0))(keys)
        if speed_factors is not None:
            states = states._replace(
                speed=jnp.asarray(speed_factors, jnp.float32))
        return states
