"""Named scenario fleets — stacked EnvParams for heterogeneous lanes.

Each builder returns an ``EnvParams`` pytree with a leading ``[fleet]``
axis; ``core.agent.run_online_fleet(..., env_params=...)`` vmaps the fused
epoch scan over it, so "one slow machine per lane" × "diurnal load" ×
"noisy telemetry" all execute as ONE XLA program.  This is the Decima-style
train-over-a-distribution-of-workloads discipline the paper's pluggable
framework implies.

    from repro.dsdps import scenarios
    params = scenarios.build("one_slow_machine", env, fleet=8)
    states, hist = run_online_fleet(keys, env, agent, agent_states, T=300,
                                    env_params=params)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dsdps.simulator import (EnvParams, perturb_rates, perturb_service,
                                   scale_rates, stack_env_params,
                                   with_noise_sigma, with_straggler)


def uniform(env, fleet: int) -> EnvParams:
    """Every lane runs the env's declared parameters (pure seed sweep)."""
    p = env.default_params()
    return stack_env_params([p] * fleet)


def one_slow_machine(env, fleet: int, factor: float = 0.35) -> EnvParams:
    """Lane i slows machine ``i % M`` to ``factor`` of nominal speed — the
    straggler-mitigation stress, one straggler location per lane."""
    p = env.default_params()
    return stack_env_params(
        [with_straggler(p, i % env.M, factor) for i in range(fleet)])


def diurnal_rate(env, fleet: int, amplitude: float = 0.4) -> EnvParams:
    """Lane i's base rates scaled to a point on a daily load curve:
    1 + amplitude*sin(2π i/fleet) — samples the operating regimes a
    day/night traffic cycle sweeps through."""
    p = env.default_params()
    lanes = []
    for i in range(fleet):
        phase = 2.0 * jnp.pi * i / max(fleet, 1)
        lanes.append(scale_rates(p, 1.0 + amplitude * jnp.sin(phase)))
    return stack_env_params(lanes)


def high_noise(env, fleet: int, sigma: float = 0.12) -> EnvParams:
    """Every lane measures rewards through ``sigma`` lognormal noise —
    4× the paper's telemetry noise; stresses learning robustness."""
    p = env.default_params()
    return stack_env_params([with_noise_sigma(p, sigma)] * fleet)


def mixed(env, fleet: int, seed: int = 0) -> EnvParams:
    """Round-robin over the named regimes plus per-lane service-time and
    rate jitter — the 'as many scenarios as you can imagine' fleet."""
    p = env.default_params()
    key = jax.random.PRNGKey(seed)
    lanes = []
    for i in range(fleet):
        k_svc, k_rate = jax.random.split(jax.random.fold_in(key, i))
        lane = perturb_rates(perturb_service(p, k_svc, 0.10), k_rate, 0.10)
        kind = i % 4
        if kind == 1:
            lane = with_straggler(lane, i % env.M, 0.4)
        elif kind == 2:
            lane = scale_rates(lane, 1.0 + 0.4 * jnp.sin(
                2.0 * jnp.pi * i / max(fleet, 1)))
        elif kind == 3:
            lane = with_noise_sigma(lane, 0.12)
        lanes.append(lane)
    return stack_env_params(lanes)


SCENARIOS = {
    "uniform": uniform,
    "one_slow_machine": one_slow_machine,
    "diurnal_rate": diurnal_rate,
    "high_noise": high_noise,
    "mixed": mixed,
}


def build(name: str, env, fleet: int, **kwargs) -> EnvParams:
    """Stacked EnvParams for a named scenario fleet."""
    try:
        builder = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"known: {sorted(SCENARIOS)}") from None
    return builder(env, fleet, **kwargs)
