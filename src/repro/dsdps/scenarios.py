"""Named scenario fleets — stacked EnvParams for heterogeneous lanes.

Each builder returns an ``EnvParams`` pytree with a leading ``[fleet]``
axis; ``core.agent.run_online_fleet(..., env_params=...)`` vmaps the fused
epoch scan over it, so "one slow machine per lane" × "diurnal load" ×
"noisy telemetry" all execute as ONE XLA program.  This is the Decima-style
train-over-a-distribution-of-workloads discipline the paper's pluggable
framework implies.

    from repro.dsdps import scenarios
    params = scenarios.build("one_slow_machine", env, fleet=8)
    states, hist = run_online_fleet(keys, env, agent, agent_states, T=300,
                                    env_params=params)

``broadcast_invariant=True`` keeps leaves no lane perturbs (routing,
flow_solve, tuple_bytes, ...) as a single unstacked copy; the fleet runner
broadcasts them with per-leaf ``in_axes=None`` — numerically identical to
the fully-stacked fleet without the F× duplicated memory.

This module is the ONE place scenario fleets are constructed: launchers,
examples, and the paper benchmarks all route through :func:`build` (or
:func:`build_for`, which also dispatches the TPU expert-placement env's
scenarios from ``core.placement``) instead of ad-hoc ``perturb_*`` chains.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dsdps.simulator import (EnvParams, perturb_rates, perturb_service,
                                   scale_rates, stack_env_params,
                                   with_noise_sigma, with_straggler)


def uniform(env, fleet: int) -> list[EnvParams]:
    """Every lane runs the env's declared parameters (pure seed sweep)."""
    p = env.default_params()
    return [p] * fleet


def one_slow_machine(env, fleet: int, factor: float = 0.35) -> list[EnvParams]:
    """Lane i slows machine ``i % M`` to ``factor`` of nominal speed — the
    straggler-mitigation stress, one straggler location per lane."""
    p = env.default_params()
    return [with_straggler(p, i % env.M, factor) for i in range(fleet)]


def diurnal_rate(env, fleet: int, amplitude: float = 0.4) -> list[EnvParams]:
    """Lane i's base rates scaled to a point on a daily load curve:
    1 + amplitude*sin(2π i/fleet) — samples the operating regimes a
    day/night traffic cycle sweeps through."""
    p = env.default_params()
    lanes = []
    for i in range(fleet):
        phase = 2.0 * jnp.pi * i / max(fleet, 1)
        lanes.append(scale_rates(p, 1.0 + amplitude * jnp.sin(phase)))
    return lanes


def high_noise(env, fleet: int, sigma: float = 0.12) -> list[EnvParams]:
    """Every lane measures rewards through ``sigma`` lognormal noise —
    4× the paper's telemetry noise; stresses learning robustness."""
    p = env.default_params()
    return [with_noise_sigma(p, sigma)] * fleet


def mixed(env, fleet: int, seed: int = 0) -> list[EnvParams]:
    """Round-robin over the named regimes plus per-lane service-time and
    rate jitter — the 'as many scenarios as you can imagine' fleet."""
    p = env.default_params()
    key = jax.random.PRNGKey(seed)
    lanes = []
    for i in range(fleet):
        k_svc, k_rate = jax.random.split(jax.random.fold_in(key, i))
        lane = perturb_rates(perturb_service(p, k_svc, 0.10), k_rate, 0.10)
        kind = i % 4
        if kind == 1:
            lane = with_straggler(lane, i % env.M, 0.4)
        elif kind == 2:
            lane = scale_rates(lane, 1.0 + 0.4 * jnp.sin(
                2.0 * jnp.pi * i / max(fleet, 1)))
        elif kind == 3:
            lane = with_noise_sigma(lane, 0.12)
        lanes.append(lane)
    return lanes


def dag_shapes(env, fleet: int):
    """STRUCTURAL fleet: lane i runs topology ``i % len(env.topologies)``
    padded into the env's common envelope — chain vs diamond vs wide
    fan-out vs varying operator counts, different *graphs* in one XLA
    program.  Requires a :class:`~repro.dsdps.structural.
    StructuralSchedulingEnv`; a plain SchedulingEnv bakes its single
    topology into jit-static structure and cannot vary it per lane."""
    if not hasattr(env, "params_for"):
        raise TypeError(
            "scenario 'dag_shapes' varies topology structure per lane and "
            "needs a StructuralSchedulingEnv (repro.dsdps.structural); "
            f"{type(env).__name__} fixes one topology per program")
    topos = env.topologies
    return [env.params_for(topos[i % len(topos)]) for i in range(fleet)]


SCENARIOS = {
    "uniform": uniform,
    "one_slow_machine": one_slow_machine,
    "diurnal_rate": diurnal_rate,
    "high_noise": high_noise,
    "mixed": mixed,
}

# structure-varying scenarios: only valid on envelope-padded structural
# envs (scenario_names() lists them per env; build() checks)
STRUCTURAL_SCENARIOS = {
    "dag_shapes": dag_shapes,
}


def build(name: str, env, fleet: int, broadcast_invariant: bool = False,
          **kwargs) -> EnvParams:
    """Stacked EnvParams for a named scenario fleet.

    ``broadcast_invariant=True`` leaves lane-identical leaves unstacked
    (single copy) for per-leaf in_axes=None broadcasting."""
    if name in STRUCTURAL_SCENARIOS:
        builder = STRUCTURAL_SCENARIOS[name]
    else:
        try:
            builder = SCENARIOS[name]
        except KeyError:
            raise KeyError(
                f"unknown scenario {name!r}; known: "
                f"{sorted(SCENARIOS) + sorted(STRUCTURAL_SCENARIOS)}"
            ) from None
    return stack_env_params(builder(env, fleet, **kwargs),
                            broadcast_invariant=broadcast_invariant)


def workload_shift(env, factor: float = 1.5) -> EnvParams:
    """The Fig-12 step change as a single-scenario EnvParams edit: every
    spout's base rate scaled by ``factor`` against the same env spec (no
    env rebuild, no recompile)."""
    return scale_rates(env.default_params(), factor)


def build_for(env, name: str, fleet: int, broadcast_invariant: bool = False,
              **kwargs):
    """Scenario fleet for ANY functional env: dispatches DSDPS envs to the
    EnvParams builders above and ``ExpertPlacementEnv`` to the
    PlacementParams builders in ``repro.core.placement`` (lazy import —
    no dsdps↔core import cycle).

    The dispatch is ENVELOPE-aware, not width-aware: a DSDPS env's
    ``state_vector`` width is whatever its (possibly padded) envelope
    says, and the numeric builders operate leaf-wise on
    ``default_params()`` — EnvParams and the padded GraphEnvParams alike.
    Structure-varying scenarios (``dag_shapes``) additionally require the
    env to *have* a padding envelope (``StructuralSchedulingEnv``); a
    topology that does not fit its env's envelope raises a ``ValueError``
    from ``params_for`` naming the offending dimension — never a
    silently-truncated observation."""
    if hasattr(env, "topo"):        # DSDPS scheduling env (plain or padded)
        return build(name, env, fleet,
                     broadcast_invariant=broadcast_invariant, **kwargs)
    from repro.core import placement
    return placement.build_scenario(name, env, fleet,
                                    broadcast_invariant=broadcast_invariant,
                                    **kwargs)


def sample_perturbed(env, key: jax.Array, base=None,
                     service_sigma: float = 0.12, rate_sigma: float = 0.12,
                     straggler_prob: float = 0.25,
                     straggler_factor: float = 0.4):
    """ONE perturbed scenario around ``base`` (default: the env's declared
    parameters) — the candidate sampler of the successive-halving scenario
    search (``repro.fleet.lifecycle.search_scenarios``): lognormal jitter
    on the true service costs and arrival rates, plus a random straggler
    with probability ``straggler_prob``.  Dispatches both env families
    like :func:`build_for` (placement envs jitter routing skew and total
    load instead)."""
    if hasattr(env, "topo"):        # DSDPS scheduling env
        p = env.default_params() if base is None else base
        k_svc, k_rate, k_slow, k_m = jax.random.split(key, 4)
        lane = perturb_rates(perturb_service(p, k_svc, service_sigma),
                             k_rate, rate_sigma)
        if bool(jax.random.bernoulli(k_slow, straggler_prob)):
            lane = with_straggler(lane,
                                  int(jax.random.randint(k_m, (), 0, env.M)),
                                  straggler_factor)
        return lane
    from repro.core import placement
    p = env.default_params() if base is None else base
    k_skew, k_load, k_slow, k_d = jax.random.split(key, 4)
    lane = placement.perturb_skew(p, k_skew, service_sigma)
    load = jnp.exp(jax.random.normal(k_load) * rate_sigma
                   - 0.5 * rate_sigma ** 2)
    lane = placement.scale_load(lane, load)
    if bool(jax.random.bernoulli(k_slow, straggler_prob)):
        lane = placement.with_device_straggler(
            lane, int(jax.random.randint(k_d, (), 0, env.M)),
            straggler_factor)
    return lane


def perturb_sampler(env, base=None, **kwargs):
    """Curry :func:`sample_perturbed` into the ``perturb(key) -> params``
    callable ``search_scenarios`` consumes for rung refills."""
    def sample(key: jax.Array):
        return sample_perturbed(env, key, base=base, **kwargs)
    return sample


def scenario_names(env) -> tuple[str, ...]:
    """Names valid for ``build_for(env, ...)`` — structural (DAG-shape)
    scenarios are listed only for envs that carry a padding envelope."""
    if hasattr(env, "topo"):
        names = sorted(SCENARIOS)
        if hasattr(env, "params_for"):
            names = sorted(names + sorted(STRUCTURAL_SCENARIOS))
        return tuple(names)
    from repro.core import placement
    return tuple(sorted(placement.PLACEMENT_SCENARIOS))
