"""Queueing-network latency model of a Storm-like DSDPS — pure JAX.

Replaces the paper's physical 10-machine cluster (see DESIGN.md §3).  For a
scheduling solution ``X`` (one-hot executor→machine) and spout workload
``w`` it computes the steady-state average end-to-end tuple processing time
via:

  1. flow solve           λ = (I − Rᵀ)⁻¹ w           (executor tuple rates)
  2. CPU contention       machine utilization → processor-sharing inflation
  3. per-executor sojourn M/M/1-PS:  T_i = s_i / (1 − ρ_i)
  4. network              per-edge transfer delay w/ 1 Gbps NIC contention
  5. end-to-end           reverse-topological completion-time recursion,
                          max over parallel downstream branches (ack joins)

The model is fully differentiable, jit-able, and vmap-able over candidate
actions, which is what lets the DRL agent train thousands of epochs per
second on CPU.  Calibrated to the paper's measured operating points
(DESIGN.md §9)."""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dsdps.cluster import ClusterSpec
from repro.dsdps.topology import Topology
from repro.dsdps.workload import NEVER_SHIFT, WorkloadProcess

# Utilization is soft-clipped below 1 to keep latencies finite with useful
# gradients: rho_eff = rho_cap * sigmoid-like saturation.
_RHO_CAP = 0.97


def _soft_utilization(rho: jnp.ndarray) -> jnp.ndarray:
    """Monotone map [0, inf) -> [0, _RHO_CAP); identity-ish below ~0.8."""
    return _RHO_CAP * jnp.tanh(rho / _RHO_CAP)


def _congestion(rho: jnp.ndarray) -> jnp.ndarray:
    """1/(1-rho) with the soft cap above (finite, smooth)."""
    return 1.0 / (1.0 - _soft_utilization(rho))


@dataclasses.dataclass(frozen=True)
class SimParams:
    """Static per-topology arrays (device constants inside jit)."""

    routing: np.ndarray          # [N, N] executor routing matrix
    flow_solve: np.ndarray       # [N, N] (I - R^T)^-1, precomputed
    service_ms: np.ndarray       # [N] TRUE CPU ms / tuple (incl. per-
                                 # executor jitter: JIT state, data skew,
                                 # NUMA — invisible to component-level
                                 # profiling, which sees nominal_service_ms)
    nominal_service_ms: np.ndarray  # [N] component-level mean (what [25]
                                 # and other model-based collectors measure)
    tuple_bytes: np.ndarray      # [N]
    spout_ids: np.ndarray        # [S] executor ids of spouts
    exec_component: np.ndarray   # [N] component index per executor
    # reverse-topological component schedule: list of
    # (component_id, [downstream component ids])
    rev_schedule: tuple[tuple[int, tuple[int, ...]], ...]
    comp_members: tuple[tuple[int, ...], ...]   # executor ids per component
    acker_ms: float              # fixed ack/bookkeeping overhead

    def to_env_params(self, cluster: ClusterSpec, workload: WorkloadProcess,
                      noise_sigma: float = 0.03) -> "EnvParams":
        """The vmappable numeric half of this spec as an EnvParams pytree."""
        return to_env_params(self, cluster, workload, noise_sigma)


def build_sim_params(topo: Topology, seed: int = 0, acker_ms: float = 0.15,
                     exec_jitter_sigma: float = 0.25) -> SimParams:
    R = topo.routing_matrix(seed)
    n = topo.num_executors
    flow = np.linalg.inv(np.eye(n) - R.T)
    nominal = topo.service_demand_ms()
    rng = np.random.default_rng(seed + 104729)
    # per-executor true cost: lognormal around the component mean (mean-1
    # corrected) — the "many factors not captured by the model" of §1
    jitter = np.exp(rng.normal(-exec_jitter_sigma ** 2 / 2,
                               exec_jitter_sigma, size=n))
    true_ms = nominal * jitter
    nc = len(topo.components)
    down: list[set[int]] = [set() for _ in range(nc)]
    for e in topo.edges:
        down[topo._index[e.src]].add(topo._index[e.dst])
    rev = tuple(
        (ci, tuple(sorted(down[ci]))) for ci in reversed(topo.topo_order)
    )
    members = tuple(tuple(topo.executor_slice(c.name)) for c in topo.components)
    return SimParams(
        routing=R,
        flow_solve=flow,
        service_ms=true_ms,
        nominal_service_ms=nominal,
        tuple_bytes=topo.tuple_bytes(),
        spout_ids=topo.spout_executors,
        exec_component=topo.executor_component,
        rev_schedule=rev,
        comp_members=members,
        acker_ms=acker_ms,
    )


# --------------------------------------------------------------------------
# EnvParams — the vmappable half of the environment.
#
# SimParams above is the *structural* spec (routing schedule, component
# membership, integer indices): hashable-ish, host-side, jit-static.
# EnvParams below is the *numeric* half as a pytree of jnp arrays: anything
# a scenario might perturb — per-executor service costs, machine speeds,
# measurement noise, workload rate parameters — is a traced argument, so a
# fleet of heterogeneous scenarios is just a stacked EnvParams vmapped
# through one XLA program (gymnax/brax-style functional env API).
# --------------------------------------------------------------------------
class EnvParams(NamedTuple):
    """Per-scenario numeric parameters (all jnp arrays; leading [F] axis
    when stacked into a scenario fleet)."""

    routing: jnp.ndarray             # [N, N] executor routing matrix
    flow_solve: jnp.ndarray          # [N, N] (I - R^T)^-1
    service_ms: jnp.ndarray          # [N] true CPU ms / tuple
    nominal_service_ms: jnp.ndarray  # [N] component-level profiled mean
    tuple_bytes: jnp.ndarray         # [N]
    acker_ms: jnp.ndarray            # scalar ack/bookkeeping overhead
    speed: jnp.ndarray               # [M] machine speed factors
    noise_sigma: jnp.ndarray         # scalar measurement-noise sigma
    base_rates: jnp.ndarray          # [S] spout base arrival rates
    rate_jitter: jnp.ndarray         # scalar workload lognormal sigma
    rate_revert: jnp.ndarray         # scalar mean-reversion strength
    shift_epoch: jnp.ndarray         # scalar int32 (NEVER_SHIFT = disabled)
    shift_factor: jnp.ndarray        # scalar Fig-12 step-change factor


def to_env_params(sim: SimParams, cluster: ClusterSpec,
                  workload: WorkloadProcess,
                  noise_sigma: float = 0.03) -> EnvParams:
    """Bundle a built SimParams + cluster + workload spec into the traced
    EnvParams pytree (the `build_sim_params -> to_env_params` path)."""
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    shift = workload.shift_epoch if workload.shift_epoch is not None \
        else NEVER_SHIFT
    return EnvParams(
        routing=f32(sim.routing),
        flow_solve=f32(sim.flow_solve),
        service_ms=f32(sim.service_ms),
        nominal_service_ms=f32(sim.nominal_service_ms),
        tuple_bytes=f32(sim.tuple_bytes),
        acker_ms=f32(sim.acker_ms),
        speed=f32(cluster.speed_factors()),
        noise_sigma=f32(noise_sigma),
        base_rates=f32(workload.base_rates),
        rate_jitter=f32(workload.jitter),
        rate_revert=f32(workload.revert),
        shift_epoch=jnp.asarray(shift, jnp.int32),
        shift_factor=f32(workload.shift_factor),
    )


# -- per-field randomization helpers (pure; compose + vmap for fleets) ------
def with_noise_sigma(params: EnvParams, sigma) -> EnvParams:
    """Replace the measurement-noise level."""
    return params._replace(noise_sigma=jnp.asarray(sigma, jnp.float32))


def with_speed(params: EnvParams, speed) -> EnvParams:
    """Replace the per-machine speed-factor vector."""
    return params._replace(speed=jnp.asarray(speed, jnp.float32))


def with_straggler(params: EnvParams, machine: int, factor) -> EnvParams:
    """Slow machine ``machine`` to ``factor`` of nominal speed."""
    return params._replace(speed=params.speed.at[machine].set(factor))


def scale_rates(params: EnvParams, factor) -> EnvParams:
    """Scale every spout's base arrival rate (diurnal load, Fig-12 shifts)."""
    return params._replace(base_rates=params.base_rates * factor)


def perturb_service(params: EnvParams, key: jax.Array,
                    sigma: float = 0.15) -> EnvParams:
    """Lognormal (mean-1 corrected) jitter on the TRUE per-executor service
    costs — samples 'the many factors not captured by the model' (§1)."""
    z = jax.random.normal(key, params.service_ms.shape)
    mult = jnp.exp(z * sigma - 0.5 * sigma ** 2)
    return params._replace(service_ms=params.service_ms * mult)


def perturb_rates(params: EnvParams, key: jax.Array,
                  sigma: float = 0.15) -> EnvParams:
    """Lognormal (mean-1 corrected) jitter on the spout base rates."""
    z = jax.random.normal(key, params.base_rates.shape)
    mult = jnp.exp(z * sigma - 0.5 * sigma ** 2)
    return params._replace(base_rates=params.base_rates * mult)


def stack_env_params(params_list, broadcast_invariant: bool = False):
    """Stack per-lane params pytrees on a leading [F] fleet axis.

    With ``broadcast_invariant=True``, leaves that are bitwise identical
    across every lane (typically routing / flow_solve / tuple_bytes, which
    no scenario perturbs) are kept as a SINGLE unstacked copy instead of
    being duplicated F× — the fleet runner then vmaps them with
    ``in_axes=None`` (see :func:`params_in_axes`), dropping the duplicated
    memory and the batched-matmul FLOPs they would otherwise cost.  Works
    for any params pytree (EnvParams or PlacementParams)."""
    def stack_leaf(*xs):
        if broadcast_invariant and all(
                x is xs[0] or (jnp.shape(x) == jnp.shape(xs[0])
                               and bool(jnp.all(x == xs[0])))
                for x in xs[1:]):
            return xs[0]
        return jnp.stack(xs)

    return jax.tree.map(stack_leaf, *params_list)


def params_in_axes(params, ref):
    """Per-leaf ``jax.vmap`` in_axes for a (possibly partially) stacked
    params pytree: 0 for leaves carrying one more leading axis than the
    single-scenario reference ``ref``, None for broadcast-invariant leaves.
    Returns None when NO leaf is stacked (a plain single-scenario params).

    The result is a pytree of ints/None with the same container structure
    as ``params`` — valid both as a vmap in_axes spec and as a hashable
    jit static argument (NamedTuple of ints/None).  This stacked-vs-
    invariant distinction is also what the device-sharded fleet path keys
    on: ``repro.sharding.fleet.params_partition_specs`` maps the same
    leaves to PartitionSpecs (stacked → fleet axis over the mesh's data
    axes, invariant → replicated) for ``run_online_fleet(..., mesh=...)``."""
    flat, treedef = jax.tree_util.tree_flatten(params)
    ref_flat = jax.tree_util.tree_leaves(ref)
    if len(flat) != len(ref_flat):
        raise ValueError("params and reference pytrees differ in structure")
    axes = [0 if jnp.ndim(p) == jnp.ndim(r) + 1 else None
            for p, r in zip(flat, ref_flat)]
    if not any(a == 0 for a in axes):
        return None
    return jax.tree_util.tree_unflatten(treedef, axes)


def params_stacked(params, ref) -> bool:
    """True when ``params`` carries a leading fleet axis on ANY leaf — THE
    stacked-fleet convention shared by every params-batched code path.
    Broadcast-invariant stacks (``stack_env_params(...,
    broadcast_invariant=True)``) count as stacked even though some leaves
    stay single-copy."""
    return params_in_axes(params, ref) is not None


def lane_params(params, ref, lane: int):
    """Extract lane ``lane`` of a (possibly broadcast-invariant) stacked
    params pytree as a single-scenario pytree; single-scenario params pass
    through unchanged.  ``ref`` supplies the unstacked leaf ranks."""
    flat, treedef = jax.tree_util.tree_flatten(params)
    ref_flat = jax.tree_util.tree_leaves(ref)
    picked = [p[lane] if jnp.ndim(p) == jnp.ndim(r) + 1 else p
              for p, r in zip(flat, ref_flat)]
    return jax.tree_util.tree_unflatten(treedef, picked)


def _latency_core(
    X: jnp.ndarray,
    w: jnp.ndarray,
    *,
    routing,
    flow_solve,
    service_ms,
    tuple_bytes,
    acker_ms,
    spout_ids,
    exec_component,
    n_components: int,
    rev_schedule,
    comp_members,
    cluster: ClusterSpec,
    speed: jnp.ndarray,
    same_proc: jnp.ndarray | None,
    n_procs: jnp.ndarray | None,
) -> jnp.ndarray:
    """Shared queueing-model body; numeric arrays may be device-traced
    (EnvParams) or host constants (SimParams), structure is always static."""
    R = jnp.asarray(routing)
    n, m = X.shape

    # 1. steady-state executor tuple rates (tuples/sec)
    w_full = jnp.zeros(n).at[jnp.asarray(spout_ids)].set(w)
    lam = jnp.asarray(flow_solve) @ w_full                            # [N]

    # edge tuple rates; machine / process locality masks
    same_mach = X @ X.T                                               # [N, N]
    if same_proc is None:
        same_proc = same_mach
    else:
        same_proc = same_proc * same_mach   # same process => same machine
    edge_rate = lam[:, None] * R                                      # tuples/s
    cross_proc = edge_rate * (1.0 - same_proc)       # pays ser/deser CPU
    cross_mach = edge_rate * (1.0 - same_mach)       # additionally uses NIC

    # 2. machine CPU contention.  Demand = executor service + ser/deser CPU
    # for every inter-process tuple (the traffic-awareness mechanism that
    # T-Storm [52] and [25] exploit: remote transfers burn CPU on both ends).
    c_ms = jnp.asarray(service_ms)                                    # [N]
    ser_ms = cluster.ser_base_ms + \
        jnp.asarray(tuple_bytes) * cluster.ser_ms_per_kb / 1024.0     # [N]
    base_demand = (X * (lam * c_ms / 1e3)[:, None]).sum(0)            # [M]
    ser_out = (X * (cross_proc.sum(1) * ser_ms / 1e3)[:, None]).sum(0)
    ser_in = (X * ((cross_proc * ser_ms[:, None]).sum(0) / 1e3)[:, None]).sum(0)
    if n_procs is None:
        # paper's schedulers: one worker process per (used) machine
        n_procs = (X.sum(0) > 0).astype(jnp.float32)
    proc_burn = n_procs * cluster.proc_overhead_cores                 # cores
    # cross-component mixing interference (see ClusterSpec.mix_penalty)
    comp_onehot = jax.nn.one_hot(jnp.asarray(exec_component),
                                 n_components)
    presence = jnp.clip(comp_onehot.T @ X, 0.0, 1.0)                  # [C, M]
    n_comp = presence.sum(0)                                          # [M]
    mix = 1.0 + cluster.mix_penalty * jnp.maximum(n_comp - 1.0, 0.0)
    demand = (base_demand + ser_out + ser_in) * mix / speed + proc_burn
    rho_cpu = demand / cluster.cores_per_machine
    g_m = _congestion(rho_cpu)                                        # [M]

    # 3. per-executor sojourn (service inflated by machine contention)
    inflate = X @ (g_m / speed)                                       # [N]
    s_eff = c_ms * inflate                                            # ms
    rho_exec = lam * s_eff / 1e3
    sojourn = s_eff * _congestion(rho_exec)                           # [N] ms

    # 4. transfer delays: in-process queue < IPC < network (w/ NIC contention)
    bytes_per_s = cross_mach * jnp.asarray(tuple_bytes)[:, None]
    out_load = (X * bytes_per_s.sum(1)[:, None]).sum(0)               # [M] B/s
    in_load = (X * bytes_per_s.sum(0)[:, None]).sum(0)                # [M] B/s
    nic_cap = cluster.nic_bytes_per_ms * 1e3                          # B/s
    rho_nic = jnp.maximum(out_load, in_load) / nic_cap
    nic_g = _congestion(rho_nic)                                      # [M]
    nic_factor = 0.5 * (X @ nic_g)[:, None] + 0.5 * (X @ nic_g)[None, :]
    wire_ms = jnp.asarray(tuple_bytes)[:, None] / cluster.nic_bytes_per_ms
    # ser/deser also adds *latency* on the tuple's own path when crossing
    # process boundaries (it is in the critical path, not just CPU load):
    # serialize at the source + deserialize at the destination.
    ser_path = 2.0 * ser_ms[:, None]
    d_edge = jnp.where(
        same_proc > 0.5,
        cluster.local_base_ms,
        jnp.where(
            same_mach > 0.5,
            cluster.ipc_base_ms + ser_path,
            cluster.net_base_ms + ser_path + wire_ms * nic_factor,
        ),
    )                                                                 # [N, N]

    # 5. completion-time recursion, reverse topo order over components.
    completion = sojourn  # leaves: just their own sojourn
    for ci, downs in rev_schedule:
        if not downs:
            continue
        src_ids = jnp.asarray(comp_members[ci])
        branch_costs = []
        for dc in downs:
            dst_ids = jnp.asarray(comp_members[dc])
            p = R[jnp.ix_(src_ids, dst_ids)]                          # [s, d]
            p = p / jnp.maximum(p.sum(1, keepdims=True), 1e-12)
            hop = d_edge[jnp.ix_(src_ids, dst_ids)] + completion[dst_ids][None, :]
            branch_costs.append((p * hop).sum(1))                     # [s]
        downstream = functools.reduce(jnp.maximum, branch_costs)
        completion = completion.at[src_ids].add(downstream)

    spouts = jnp.asarray(spout_ids)
    w_safe = jnp.maximum(w, 0.0)
    avg = (w_safe * completion[spouts]).sum() / jnp.maximum(w_safe.sum(), 1e-9)
    return avg + acker_ms


def average_tuple_time_ms(
    X: jnp.ndarray,              # [N, M] one-hot (rows sum to 1); float ok
    w: jnp.ndarray,              # [S] spout executor arrival rates (tuples/s)
    params: SimParams,
    cluster: ClusterSpec,
    speed: jnp.ndarray | None = None,   # [M] machine speed factors
    same_proc: jnp.ndarray | None = None,  # [N, N] same-worker-process mask
    n_procs: jnp.ndarray | None = None,    # [M] worker processes per machine
) -> jnp.ndarray:
    """Average end-to-end tuple processing time in milliseconds (scalar).

    ``same_proc`` distinguishes worker processes *within* a machine: tuples
    between different processes pay serialization CPU + IPC latency even if
    co-located (Storm semantics, exploited by [52]/[25] and the paper).
    The paper's schedulers enforce one process per app per machine, so for
    them ``same_proc`` defaults to the same-machine mask.  Storm's default
    EvenScheduler spreads executors over ~10 processes/machine — pass its
    process mask to reproduce the default baseline's overhead."""
    speed = jnp.ones(X.shape[1]) if speed is None else speed
    return _latency_core(
        X, w,
        routing=params.routing,
        flow_solve=params.flow_solve,
        service_ms=params.service_ms,
        tuple_bytes=params.tuple_bytes,
        acker_ms=params.acker_ms,
        spout_ids=params.spout_ids,
        exec_component=params.exec_component,
        n_components=int(params.exec_component.max()) + 1,
        rev_schedule=params.rev_schedule,
        comp_members=params.comp_members,
        cluster=cluster,
        speed=speed,
        same_proc=same_proc,
        n_procs=n_procs,
    )


def average_tuple_time_from_params(
    X: jnp.ndarray,
    w: jnp.ndarray,
    env_params: EnvParams,
    sim: SimParams,
    cluster: ClusterSpec,
    speed: jnp.ndarray | None = None,
    same_proc: jnp.ndarray | None = None,
    n_procs: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """``average_tuple_time_ms`` with the numeric arrays taken from a traced
    EnvParams pytree (structure still from the static SimParams) — the
    functional-core path that makes scenario fleets vmappable."""
    speed = env_params.speed if speed is None else speed
    return _latency_core(
        X, w,
        routing=env_params.routing,
        flow_solve=env_params.flow_solve,
        service_ms=env_params.service_ms,
        tuple_bytes=env_params.tuple_bytes,
        acker_ms=env_params.acker_ms,
        spout_ids=sim.spout_ids,
        exec_component=sim.exec_component,
        n_components=int(sim.exec_component.max()) + 1,
        rev_schedule=sim.rev_schedule,
        comp_members=sim.comp_members,
        cluster=cluster,
        speed=speed,
        same_proc=same_proc,
        n_procs=n_procs,
    )


def measured_latency_from_params(
    key: jax.Array,
    X: jnp.ndarray,
    w: jnp.ndarray,
    env_params: EnvParams,
    sim: SimParams,
    cluster: ClusterSpec,
    speed: jnp.ndarray | None = None,
    n_measurements: int = 5,
    same_proc: jnp.ndarray | None = None,
    n_procs: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Noisy measurement of the EnvParams path: mean of ``n_measurements``
    lognormal-perturbed readings with params.noise_sigma."""
    base = average_tuple_time_from_params(X, w, env_params, sim, cluster,
                                          speed=speed, same_proc=same_proc,
                                          n_procs=n_procs)
    z = jax.random.normal(key, (n_measurements,)) * env_params.noise_sigma
    return (base * jnp.exp(z)).mean()


def measured_latency_ms(
    key: jax.Array,
    X: jnp.ndarray,
    w: jnp.ndarray,
    params: SimParams,
    cluster: ClusterSpec,
    speed: jnp.ndarray | None = None,
    noise_sigma: float = 0.03,
    n_measurements: int = 5,
    same_proc: jnp.ndarray | None = None,
    n_procs: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Noisy measurement: mean of ``n_measurements`` lognormal-perturbed
    readings (the framework averages 5 consecutive 10s-spaced readings)."""
    base = average_tuple_time_ms(X, w, params, cluster, speed,
                                 same_proc=same_proc, n_procs=n_procs)
    z = jax.random.normal(key, (n_measurements,)) * noise_sigma
    return (base * jnp.exp(z)).mean()
