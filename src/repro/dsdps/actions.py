"""Decision semantics beyond placement: rate control and auto-tuning.

The paper's action is an executor→machine assignment, but the same
model-free control loop generalises to the two adjacent decision kinds in
the literature (PAPERS.md): *rate control* — per-spout admission
throttles, "Generalised Rate Control for Stream Processing Applications"
— and *auto-tuning* — runtime config knobs, "Auto-tuning Distributed
Stream Processing Systems using RL".  Both act on the SAME simulator: a
decision is a pure edit of the :class:`~repro.dsdps.simulator.EnvParams`
pytree (scale ``base_rates``; scale ``acker_ms`` / ``tuple_bytes``), so
applying an action is traced, vmappable, and rides the scenario-fleet
machinery unchanged.

Encodings (both one-hot, so the MIQP-NN row-simplex feasibility predicate
from ``core/spaces.py`` applies):

* rate_control — ``[S, L]``: row s one-hot over :data:`RATE_LEVELS`,
  a discrete throttle grid of admission multipliers for spout s.
* auto_tune   — ``[K]``: one-hot over :data:`TUNE_GRID`, joint
  (acker overhead scale, tuple batch-size scale) operating points.

``decode_state`` recovers the simulator state (X, w) from the flattened
state vector the DNNs see — the serving control plane receives only
``(s_vec, cluster params)`` per request, and model-grounded policies
(``core/control_policies.py``) re-ground the decision in the queueing
model from exactly that."""
from __future__ import annotations

import jax.numpy as jnp

from repro.dsdps.simulator import EnvParams

# Admission throttle grid: fraction of the offered spout load admitted.
# 1.0 = no throttling; the levels match the coarse-grained backpressure
# settings a Storm operator can actually deploy.
RATE_LEVELS: tuple[float, ...] = (0.25, 0.5, 0.75, 1.0)

# Auto-tuning knob grid: (acker_scale, batch_scale) operating points.
# acker_scale scales the per-tuple ack/bookkeeping overhead (Storm's
# acker-executor setting: fewer ackers = less bookkeeping, weaker
# delivery guarantees); batch_scale scales tuple_bytes (transfer
# batching: bigger batches amortise per-tuple framing but pay
# serialization + wire time on every cross-machine hop).
TUNE_GRID: tuple[tuple[float, float], ...] = (
    (1.0, 1.0),     # declared configuration
    (0.5, 1.0),     # halve ack bookkeeping
    (0.25, 1.0),    # minimal acking
    (1.0, 0.5),     # smaller transfer batches
    (1.0, 2.0),     # bigger transfer batches
    (0.5, 0.5),     # both: low-latency profile
)


def rate_multipliers(action: jnp.ndarray,
                     levels: tuple[float, ...] = RATE_LEVELS) -> jnp.ndarray:
    """[S, L] one-hot rate action -> [S] admission multipliers."""
    return action @ jnp.asarray(levels, jnp.float32)


def apply_rate_action(params: EnvParams, action: jnp.ndarray,
                      levels: tuple[float, ...] = RATE_LEVELS) -> EnvParams:
    """Throttle each spout's offered load by its selected level (pure
    EnvParams edit — traced and vmappable)."""
    return params._replace(
        base_rates=params.base_rates * rate_multipliers(action, levels))


def tune_settings(action: jnp.ndarray,
                  grid: tuple[tuple[float, float], ...] = TUNE_GRID
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """[K] one-hot tune action -> (acker_scale, batch_scale) scalars."""
    g = jnp.asarray(grid, jnp.float32)                    # [K, 2]
    picked = action @ g                                   # [2]
    return picked[0], picked[1]


def apply_config_action(params: EnvParams, action: jnp.ndarray,
                        grid: tuple[tuple[float, float], ...] = TUNE_GRID
                        ) -> EnvParams:
    """Apply one auto-tuning operating point (pure EnvParams edit)."""
    acker_scale, batch_scale = tune_settings(action, grid)
    return params._replace(acker_ms=params.acker_ms * acker_scale,
                           tuple_bytes=params.tuple_bytes * batch_scale)


def decode_state(env, s_vec: jnp.ndarray,
                 params: EnvParams) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Invert ``SchedulingEnv.state_vector``: the flattened DNN state back
    to (X [N, M], w [S]).  The state vector is ``concat(X.reshape(-1),
    w / base_rates)``, so the cluster's params pin the rate scale."""
    nm = env.N * env.M
    X = s_vec[:nm].reshape(env.N, env.M)
    w = s_vec[nm:] * (params.base_rates + 1e-9)
    return X, w
