"""Physical cluster model: machines, slots, NICs, heterogeneity, faults.

Mirrors the paper's testbed: 10 worker machines (+1 Nimbus), quad-core
2.0 GHz, 10 slots each, 1 Gbps network.  Heterogeneity / straggler
multipliers and machine-down masks support the fault-tolerance and
straggler-mitigation experiments."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    num_machines: int = 10
    cores_per_machine: int = 4
    slots_per_machine: int = 10
    nic_gbps: float = 1.0
    # fixed per-hop network latency (propagation + batching, ms)
    net_base_ms: float = 0.30
    # intra-machine (same-process) handoff cost (ms)
    local_base_ms: float = 0.01
    # intra-machine inter-process (localhost socket) latency (ms)
    ipc_base_ms: float = 0.06
    # CPU cost of serializing/deserializing one cross-machine tuple (charged
    # to both endpoint machines) — the traffic-awareness lever of T-Storm[52]
    ser_base_ms: float = 0.06
    ser_ms_per_kb: float = 0.08
    # fixed CPU burn per running worker process (JVM + GC + netty polling),
    # in cores.  Storm's default scheduler spreads an app over ~slots
    # processes per machine; the paper's schedulers use one per machine.
    proc_overhead_cores: float = 0.09
    # cross-component co-location interference: mixing executors of many
    # DIFFERENT components on one machine thrashes icache/dcache and GC
    # generations — effective service inflates per extra distinct
    # component.  Aggregate demand/traffic features (what model-based
    # collectors see) cannot express this; raw (X, w) can — one of the
    # "many factors not fully captured by the model" (paper §1).
    mix_penalty: float = 0.05
    # effective CPU speed multipliers per machine: nominally identical blades
    # differ in practice (background daemons, thermal, NUMA placement) —
    # the model-free agent learns this from rewards; model-based partially
    # captures it; round-robin ignores it.
    speeds: tuple[float, ...] = (1.0, 0.92, 0.86, 1.0, 0.78, 0.97,
                                 0.83, 0.95, 0.74, 1.0)

    @property
    def nic_bytes_per_ms(self) -> float:
        return self.nic_gbps * 1e9 / 8.0 / 1e3

    def speed_factors(self, straggler: dict[int, float] | None = None) -> np.ndarray:
        """CPU speed multiplier per machine (<1 = slow)."""
        f = np.asarray(self.speeds, dtype=np.float64)[: self.num_machines].copy()
        if f.shape[0] < self.num_machines:
            f = np.resize(f, self.num_machines)
        if straggler:
            for m, s in straggler.items():
                f[m] = s
        return f

    def alive_mask(self, down: tuple[int, ...] = ()) -> np.ndarray:
        m = np.ones(self.num_machines, dtype=bool)
        for j in down:
            m[j] = False
        return m


PAPER_CLUSTER = ClusterSpec()
