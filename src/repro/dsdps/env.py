"""The scheduling environment: the decision-epoch loop of §3.1/§3.2.

State   s = (X, w)   — current assignment + spout arrival rates
Action  a ∈ {0,1}^{N×M}, row one-hot — new assignment
Reward  r = −(measured average tuple processing time, ms)

Functional core (gymnax/brax-style): ``SchedulingEnv`` is a thin STATIC
spec — shapes, topology structure, cluster constants — hashable by
identity so it can ride jit as a static argument.  Everything a scenario
might vary (service costs, machine speeds, measurement noise, workload
rate parameters) lives in an :class:`~repro.dsdps.simulator.EnvParams`
pytree passed to ``reset(key, params)`` / ``step(key, state, action,
params)`` / ``state_vector(state, params)``.  Stacking EnvParams on a
leading fleet axis and vmapping these functions runs heterogeneous
scenario fleets — workload rates × service jitter × noise × stragglers —
as ONE XLA program (core/agent.run_online_fleet).  ``params`` defaults to
``default_params()`` everywhere, so the pre-v1 object-style calls keep
working unchanged.

``step`` deploys the action with minimal-delta semantics (only changed
executors are re-assigned; the deploy cost is proportional to the number of
moved executors, modeling the re-stabilization the paper waits out), then
measures the reward (mean of 5 noisy readings)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dsdps.cluster import ClusterSpec, PAPER_CLUSTER
from repro.dsdps.simulator import (EnvParams, SimParams,
                                   average_tuple_time_from_params,
                                   build_sim_params,
                                   measured_latency_from_params,
                                   params_in_axes)
from repro.dsdps.topology import Topology
from repro.dsdps.workload import WorkloadProcess, step_rates


class EnvState(NamedTuple):
    X: jnp.ndarray          # [N, M] one-hot assignment
    w: jnp.ndarray          # [S] spout rates
    epoch: jnp.ndarray      # scalar int32
    speed: jnp.ndarray      # [M] machine speed factors (straggler model)


class StepOut(NamedTuple):
    state: EnvState
    reward: jnp.ndarray
    latency_ms: jnp.ndarray
    moved: jnp.ndarray      # number of re-assigned executors


@dataclasses.dataclass(eq=False)
class SchedulingEnv:
    """Static spec of one DSDPS control problem.

    ``eq=False`` keeps the default identity hash/eq so instances are valid
    jit static arguments — XLA executables are cached on (env, agent, T, …)
    by jit itself, with all numeric content arriving via EnvParams."""

    topo: Topology
    workload: WorkloadProcess
    cluster: ClusterSpec = PAPER_CLUSTER
    noise_sigma: float = 0.03
    seed: int = 0

    def __post_init__(self) -> None:
        self.params: SimParams = build_sim_params(self.topo, seed=self.seed)
        self.N = self.topo.num_executors
        self.M = self.cluster.num_machines
        self._default_params: EnvParams | None = None

    # -- params ------------------------------------------------------------
    def default_params(self) -> EnvParams:
        """The EnvParams pytree matching this spec's declared workload,
        cluster speeds, and noise level (cached; treat as immutable)."""
        if self._default_params is None:
            self._default_params = self.params.to_env_params(
                self.cluster, self.workload, self.noise_sigma)
        return self._default_params

    # -- helpers -----------------------------------------------------------
    def round_robin_assignment(self) -> jnp.ndarray:
        idx = np.arange(self.N) % self.M
        return jnp.asarray(np.eye(self.M)[idx], dtype=jnp.float32)

    def storm_default_assignment(
            self) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Storm EvenScheduler: executors round-robin over slots ordered
        machine-major — machine i%M, worker process (i//M) % slots.  Returns
        (X, same_proc mask, n_procs per machine); executors on one machine
        usually land in *different* processes, paying ser/deser even when
        co-located."""
        idx = np.arange(self.N) % self.M
        proc = (np.arange(self.N) // self.M) % self.cluster.slots_per_machine
        X = np.eye(self.M)[idx].astype(np.float32)
        same_proc = ((idx[:, None] == idx[None, :]) &
                     (proc[:, None] == proc[None, :])).astype(np.float32)
        n_procs = np.zeros(self.M, dtype=np.float32)
        for j in range(self.M):
            n_procs[j] = len(set(proc[idx == j]))
        return jnp.asarray(X), jnp.asarray(same_proc), jnp.asarray(n_procs)

    def random_assignment(self, key: jax.Array) -> jnp.ndarray:
        idx = jax.random.randint(key, (self.N,), 0, self.M)
        return jax.nn.one_hot(idx, self.M, dtype=jnp.float32)

    def state_vector(self, s: EnvState,
                     params: EnvParams | None = None) -> jnp.ndarray:
        """Flattened (X, w) fed to the DNNs — exactly the paper's state."""
        p = self.default_params() if params is None else params
        w_norm = s.w / (p.base_rates + 1e-9)
        return jnp.concatenate([s.X.reshape(-1), w_norm])

    @property
    def state_dim(self) -> int:
        return self.N * self.M + self.workload.num_spouts

    @property
    def action_dim(self) -> int:
        return self.N * self.M

    # -- core API ----------------------------------------------------------
    def reset(self, key: jax.Array, params: EnvParams | None = None,
              X0: jnp.ndarray | None = None) -> EnvState:
        p = self.default_params() if params is None else params
        X = self.round_robin_assignment() if X0 is None else X0
        return EnvState(
            X=X,
            w=p.base_rates,
            epoch=jnp.zeros((), jnp.int32),
            speed=p.speed,
        )

    def evaluate(self, X: jnp.ndarray, w: jnp.ndarray,
                 speed: jnp.ndarray | None = None,
                 same_proc: jnp.ndarray | None = None,
                 n_procs: jnp.ndarray | None = None,
                 params: EnvParams | None = None) -> jnp.ndarray:
        """Noise-free steady-state latency for an assignment (ms)."""
        p = self.default_params() if params is None else params
        return average_tuple_time_from_params(
            X, w, p, self.params, self.cluster, speed=speed,
            same_proc=same_proc, n_procs=n_procs)

    def step(self, key: jax.Array, s: EnvState, action: jnp.ndarray,
             params: EnvParams | None = None) -> StepOut:
        p = self.default_params() if params is None else params
        k_noise, k_w = jax.random.split(key)
        moved = (jnp.abs(action - s.X).sum(-1) > 0).sum()
        lat = measured_latency_from_params(
            k_noise, action, s.w, p, self.params, self.cluster, speed=s.speed)
        w_next = step_rates(k_w, s.w, s.epoch, p.base_rates, p.rate_jitter,
                            p.rate_revert, p.shift_epoch, p.shift_factor)
        nxt = EnvState(X=action, w=w_next, epoch=s.epoch + 1, speed=s.speed)
        return StepOut(state=nxt, reward=-lat, latency_ms=lat, moved=moved)

    def with_straggler(self, s: EnvState, machine: int, factor: float) -> EnvState:
        """Slow one machine mid-run (state-level; for param-level scenario
        fleets use repro.dsdps.scenarios / simulator.with_straggler)."""
        return s._replace(speed=s.speed.at[machine].set(factor))

    def reset_fleet(self, keys: jax.Array, X0: jnp.ndarray | None = None,
                    speed_factors: jnp.ndarray | None = None,
                    params: EnvParams | None = None) -> EnvState:
        """Stacked initial states for ``run_online_fleet``: one EnvState per
        lane ([F] leading axis).  ``params`` may be a single EnvParams or a
        stacked scenario fleet (per-leaf broadcast stacks included);
        ``speed_factors`` ([F, M]) is the legacy way to build per-lane
        straggler scenarios."""
        p = self.default_params() if params is None else params
        axes = params_in_axes(p, self.default_params())
        if axes is not None:
            states = jax.vmap(lambda k, pp: self.reset(k, pp, X0=X0),
                              in_axes=(0, axes))(keys, p)
        else:
            states = jax.vmap(lambda k: self.reset(k, p, X0=X0))(keys)
        if speed_factors is not None:
            states = states._replace(
                speed=jnp.asarray(speed_factors, jnp.float32))
        return states
