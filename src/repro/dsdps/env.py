"""The scheduling environment: the decision-epoch loop of §3.1/§3.2.

State   s = (X, w)   — current assignment + spout arrival rates
Action  a ∈ {0,1}^{N×M}, row one-hot — new assignment
Reward  r = −(measured average tuple processing time, ms)

``step`` deploys the action with minimal-delta semantics (only changed
executors are re-assigned; the deploy cost is proportional to the number of
moved executors, modeling the re-stabilization the paper waits out), then
measures the reward (mean of 5 noisy readings)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dsdps.cluster import ClusterSpec, PAPER_CLUSTER
from repro.dsdps.simulator import SimParams, build_sim_params, measured_latency_ms
from repro.dsdps.topology import Topology
from repro.dsdps.workload import WorkloadProcess


class EnvState(NamedTuple):
    X: jnp.ndarray          # [N, M] one-hot assignment
    w: jnp.ndarray          # [S] spout rates
    epoch: jnp.ndarray      # scalar int32
    speed: jnp.ndarray      # [M] machine speed factors (straggler model)


class StepOut(NamedTuple):
    state: EnvState
    reward: jnp.ndarray
    latency_ms: jnp.ndarray
    moved: jnp.ndarray      # number of re-assigned executors


@dataclasses.dataclass
class SchedulingEnv:
    topo: Topology
    workload: WorkloadProcess
    cluster: ClusterSpec = PAPER_CLUSTER
    noise_sigma: float = 0.03
    seed: int = 0

    def __post_init__(self) -> None:
        self.params: SimParams = build_sim_params(self.topo, seed=self.seed)
        self.N = self.topo.num_executors
        self.M = self.cluster.num_machines

    # -- helpers -----------------------------------------------------------
    def round_robin_assignment(self) -> jnp.ndarray:
        idx = np.arange(self.N) % self.M
        return jnp.asarray(np.eye(self.M)[idx], dtype=jnp.float32)

    def storm_default_assignment(
            self) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Storm EvenScheduler: executors round-robin over slots ordered
        machine-major — machine i%M, worker process (i//M) % slots.  Returns
        (X, same_proc mask, n_procs per machine); executors on one machine
        usually land in *different* processes, paying ser/deser even when
        co-located."""
        idx = np.arange(self.N) % self.M
        proc = (np.arange(self.N) // self.M) % self.cluster.slots_per_machine
        X = np.eye(self.M)[idx].astype(np.float32)
        same_proc = ((idx[:, None] == idx[None, :]) &
                     (proc[:, None] == proc[None, :])).astype(np.float32)
        n_procs = np.zeros(self.M, dtype=np.float32)
        for j in range(self.M):
            n_procs[j] = len(set(proc[idx == j]))
        return jnp.asarray(X), jnp.asarray(same_proc), jnp.asarray(n_procs)

    def random_assignment(self, key: jax.Array) -> jnp.ndarray:
        idx = jax.random.randint(key, (self.N,), 0, self.M)
        return jax.nn.one_hot(idx, self.M, dtype=jnp.float32)

    def state_vector(self, s: EnvState) -> jnp.ndarray:
        """Flattened (X, w) fed to the DNNs — exactly the paper's state."""
        w_norm = s.w / (jnp.asarray(self.workload.base_rates) + 1e-9)
        return jnp.concatenate([s.X.reshape(-1), w_norm])

    @property
    def state_dim(self) -> int:
        return self.N * self.M + self.workload.num_spouts

    @property
    def action_dim(self) -> int:
        return self.N * self.M

    # -- core API ----------------------------------------------------------
    def reset(self, key: jax.Array, X0: jnp.ndarray | None = None) -> EnvState:
        X = self.round_robin_assignment() if X0 is None else X0
        return EnvState(
            X=X,
            w=self.workload.init(),
            epoch=jnp.zeros((), jnp.int32),
            speed=jnp.asarray(self.cluster.speed_factors(), jnp.float32),
        )

    def evaluate(self, X: jnp.ndarray, w: jnp.ndarray,
                 speed: jnp.ndarray | None = None,
                 same_proc: jnp.ndarray | None = None,
                 n_procs: jnp.ndarray | None = None) -> jnp.ndarray:
        """Noise-free steady-state latency for an assignment (ms)."""
        from repro.dsdps.simulator import average_tuple_time_ms
        if speed is None:
            speed = jnp.asarray(self.cluster.speed_factors())
        return average_tuple_time_ms(X, w, self.params, self.cluster, speed,
                                     same_proc=same_proc, n_procs=n_procs)

    def step(self, key: jax.Array, s: EnvState, action: jnp.ndarray) -> StepOut:
        k_noise, k_w = jax.random.split(key)
        moved = (jnp.abs(action - s.X).sum(-1) > 0).sum()
        lat = measured_latency_ms(
            k_noise, action, s.w, self.params, self.cluster, s.speed,
            noise_sigma=self.noise_sigma,
        )
        w_next = self.workload.step(k_w, s.w, s.epoch)
        nxt = EnvState(X=action, w=w_next, epoch=s.epoch + 1, speed=s.speed)
        return StepOut(state=nxt, reward=-lat, latency_ms=lat, moved=moved)

    def with_straggler(self, s: EnvState, machine: int, factor: float) -> EnvState:
        return s._replace(speed=s.speed.at[machine].set(factor))

    def reset_fleet(self, keys: jax.Array, X0: jnp.ndarray | None = None,
                    speed_factors: jnp.ndarray | None = None) -> EnvState:
        """Stacked initial states for ``run_online_fleet``: one EnvState per
        lane ([F] leading axis).  ``speed_factors`` ([F, M]) builds a fleet
        of straggler scenarios — per-lane machine slowdowns."""
        states = jax.vmap(lambda k: self.reset(k, X0))(keys)
        if speed_factors is not None:
            states = states._replace(
                speed=jnp.asarray(speed_factors, jnp.float32))
        return states
