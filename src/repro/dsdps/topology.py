"""Storm-like logical topology: spouts, bolts, groupings, executor expansion.

A topology is a DAG of *components* (spouts emit, bolts process).  Each
component runs as ``parallelism`` executors (threads).  Edges carry a
grouping policy that determines how tuples emitted by an upstream executor
are distributed over the downstream component's executors:

  - ``shuffle``: uniform random split (1/P_down each)
  - ``fields``:  hash-partitioned by key -> fixed (possibly skewed) split
  - ``global``:  all tuples to executor 0 of the downstream component
  - ``all``:     every tuple replicated to every downstream executor

The executor-level routing matrix ``R[i, k]`` gives the expected number of
tuples forwarded to executor ``k`` per tuple *processed* at executor ``i``
(component selectivity folded in).  This matrix, together with spout
arrival rates, fully determines the steady-state tuple flow."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import numpy as np

SHUFFLE = "shuffle"
FIELDS = "fields"
GLOBAL = "global"
ALL = "all"


class GraphObs(NamedTuple):
    """Padded/masked executor-graph observation of one topology.

    Node arrays have length ``max_execs``; edge arrays length ``max_edges``.
    Padded edges point at the *sacrificial* node index ``max_execs`` (one past
    the last real slot): a segment-sum over ``max_execs + 1`` segments routes
    their (zero-weight) contributions into a segment that is sliced away, so
    real-node aggregates are bit-identical across padding envelopes.
    """

    service_ms: np.ndarray    # [max_execs] CPU demand per tuple (0 on padding)
    tuple_bytes: np.ndarray   # [max_execs] emitted tuple size (0 on padding)
    is_spout: np.ndarray      # [max_execs] 1.0 on spout executors
    out_mass: np.ndarray      # [max_execs] row sum of R (selectivity x fan-out)
    in_mass: np.ndarray       # [max_execs] column sum of R
    node_mask: np.ndarray     # [max_execs] 1.0 on real executors
    edge_src: np.ndarray      # [max_edges] int32; padded entries = max_execs
    edge_dst: np.ndarray      # [max_edges] int32; padded entries = max_execs
    edge_w: np.ndarray        # [max_edges] R[src, dst]; 0.0 on padding
    edge_mask: np.ndarray     # [max_edges] 1.0 on real edges
    num_executors: int        # real executor count (<= max_execs)
    num_edges: int            # real edge count (<= max_edges)


@dataclasses.dataclass(frozen=True)
class Component:
    """One spout or bolt."""

    name: str
    parallelism: int                 # number of executors
    cpu_ms_per_tuple: float          # mean CPU service demand per tuple
    selectivity: float = 1.0         # tuples emitted per tuple consumed
    tuple_bytes: int = 256           # mean emitted tuple size
    is_spout: bool = False


@dataclasses.dataclass(frozen=True)
class Edge:
    src: str
    dst: str
    grouping: str = SHUFFLE
    # fields-grouping skew: Zipf exponent over downstream executors (0 = even)
    skew: float = 0.0


@dataclasses.dataclass
class Topology:
    """Executor-level expansion of a component DAG."""

    name: str
    components: Sequence[Component]
    edges: Sequence[Edge]

    def __post_init__(self) -> None:
        names = [c.name for c in self.components]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate component names in {self.name}")
        self._index = {c.name: ci for ci, c in enumerate(self.components)}
        for e in self.edges:
            if e.src not in self._index or e.dst not in self._index:
                raise ValueError(f"edge {e.src}->{e.dst} references unknown component")
            if e.grouping not in (SHUFFLE, FIELDS, GLOBAL, ALL):
                raise ValueError(f"unknown grouping {e.grouping!r}")
        # executor id ranges per component
        starts, n = [], 0
        for c in self.components:
            starts.append(n)
            n += c.parallelism
        self._starts = starts
        self.num_executors = n
        self._validate_dag()

    # -- basic accessors ---------------------------------------------------
    def component(self, name: str) -> Component:
        return self.components[self._index[name]]

    def executor_slice(self, name: str) -> range:
        ci = self._index[name]
        s = self._starts[ci]
        return range(s, s + self.components[ci].parallelism)

    @property
    def spout_executors(self) -> np.ndarray:
        ids = []
        for c in self.components:
            if c.is_spout:
                ids.extend(self.executor_slice(c.name))
        return np.asarray(ids, dtype=np.int32)

    @property
    def executor_component(self) -> np.ndarray:
        """component index of each executor"""
        out = np.zeros(self.num_executors, dtype=np.int32)
        for ci, c in enumerate(self.components):
            out[list(self.executor_slice(c.name))] = ci
        return out

    def _validate_dag(self) -> None:
        # Kahn's algorithm over components; store topo order for the solver.
        nc = len(self.components)
        indeg = np.zeros(nc, dtype=np.int64)
        adj: list[list[int]] = [[] for _ in range(nc)]
        for e in self.edges:
            s, d = self._index[e.src], self._index[e.dst]
            adj[s].append(d)
            indeg[d] += 1
        order, queue = [], [i for i in range(nc) if indeg[i] == 0]
        while queue:
            u = queue.pop()
            order.append(u)
            for v in adj[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    queue.append(v)
        if len(order) != nc:
            raise ValueError(f"topology {self.name} has a cycle")
        self.topo_order = order

    # -- executor-level expansion -------------------------------------------
    def routing_matrix(self, seed: int = 0) -> np.ndarray:
        """R[i, k]: expected tuples forwarded to executor k per tuple
        processed at executor i (selectivity of i folded in)."""
        rng = np.random.default_rng(seed)
        n = self.num_executors
        R = np.zeros((n, n), dtype=np.float64)
        for e in self.edges:
            src_c = self.component(e.src)
            dst_c = self.component(e.dst)
            src_ids = list(self.executor_slice(e.src))
            dst_ids = list(self.executor_slice(e.dst))
            p = len(dst_ids)
            if e.grouping == SHUFFLE:
                frac = np.full(p, 1.0 / p)
            elif e.grouping == FIELDS:
                # Zipf-ish key skew, deterministic per (topology, edge, seed)
                w = (np.arange(1, p + 1, dtype=np.float64)) ** (-e.skew)
                w = rng.permutation(w)
                frac = w / w.sum()
            elif e.grouping == GLOBAL:
                frac = np.zeros(p)
                frac[0] = 1.0
            elif e.grouping == ALL:
                frac = np.ones(p)
            else:  # pragma: no cover
                raise AssertionError(e.grouping)
            for i in src_ids:
                R[i, dst_ids] += src_c.selectivity * frac
        return R

    def service_demand_ms(self) -> np.ndarray:
        """CPU ms per tuple for each executor."""
        out = np.zeros(self.num_executors, dtype=np.float64)
        for c in self.components:
            out[list(self.executor_slice(c.name))] = c.cpu_ms_per_tuple
        return out

    def tuple_bytes(self) -> np.ndarray:
        out = np.zeros(self.num_executors, dtype=np.float64)
        for c in self.components:
            out[list(self.executor_slice(c.name))] = c.tuple_bytes
        return out

    def to_graph_obs(self, max_execs: int, max_edges: int, seed: int = 0) -> GraphObs:
        """Executor-graph observation padded to a ``(max_execs, max_edges)``
        envelope.

        Edges are the nonzero entries of ``routing_matrix(seed)`` in row-major
        order (so the real-edge prefix is identical at every envelope).  Raises
        ``ValueError`` when the topology does not fit the envelope — padding
        must never silently truncate structure."""
        n = self.num_executors
        R = self.routing_matrix(seed)
        src, dst = np.nonzero(R)
        e = len(src)
        if n > max_execs or e > max_edges:
            raise ValueError(
                f"topology {self.name} exceeds graph envelope: "
                f"{n} executors / {e} edges vs max_execs={max_execs} / "
                f"max_edges={max_edges}"
            )

        def pad_nodes(x: np.ndarray) -> np.ndarray:
            out = np.zeros(max_execs, dtype=np.float32)
            out[:n] = x
            return out

        is_spout = np.zeros(n, dtype=np.float32)
        is_spout[self.spout_executors] = 1.0
        node_mask = pad_nodes(np.ones(n, dtype=np.float32))
        # sacrificial index max_execs on padded edges; gather clamps it,
        # scatter routes it into the discarded extra segment
        edge_src = np.full(max_edges, max_execs, dtype=np.int32)
        edge_dst = np.full(max_edges, max_execs, dtype=np.int32)
        edge_w = np.zeros(max_edges, dtype=np.float32)
        edge_mask = np.zeros(max_edges, dtype=np.float32)
        edge_src[:e] = src
        edge_dst[:e] = dst
        edge_w[:e] = R[src, dst]
        edge_mask[:e] = 1.0
        return GraphObs(
            service_ms=pad_nodes(self.service_demand_ms()),
            tuple_bytes=pad_nodes(self.tuple_bytes()),
            is_spout=pad_nodes(is_spout),
            out_mass=pad_nodes(R.sum(axis=1)),
            in_mass=pad_nodes(R.sum(axis=0)),
            node_mask=node_mask,
            edge_src=edge_src,
            edge_dst=edge_dst,
            edge_w=edge_w,
            edge_mask=edge_mask,
            num_executors=n,
            num_edges=e,
        )

    def describe(self) -> str:
        lines = [f"topology {self.name}: {self.num_executors} executors"]
        for c in self.components:
            kind = "spout" if c.is_spout else "bolt"
            lines.append(
                f"  {kind} {c.name}: x{c.parallelism}, {c.cpu_ms_per_tuple}ms/tuple,"
                f" sel={c.selectivity}"
            )
        for e in self.edges:
            lines.append(f"  {e.src} -[{e.grouping}]-> {e.dst}")
        return "\n".join(lines)
