from repro.dsdps.topology import Component, Edge, Topology
from repro.dsdps.cluster import ClusterSpec, PAPER_CLUSTER
from repro.dsdps.simulator import SimParams, average_tuple_time_ms, build_sim_params
from repro.dsdps.workload import WorkloadProcess
from repro.dsdps.env import EnvState, SchedulingEnv, StepOut
from repro.dsdps import apps

__all__ = [
    "Component", "Edge", "Topology", "ClusterSpec", "PAPER_CLUSTER",
    "SimParams", "average_tuple_time_ms", "build_sim_params",
    "WorkloadProcess", "EnvState", "SchedulingEnv", "StepOut", "apps",
]
