from repro.dsdps.topology import Component, Edge, GraphObs, Topology
from repro.dsdps.cluster import ClusterSpec, PAPER_CLUSTER
from repro.dsdps.simulator import (EnvParams, SimParams,
                                   average_tuple_time_from_params,
                                   average_tuple_time_ms, build_sim_params,
                                   lane_params, params_in_axes,
                                   params_stacked, perturb_rates,
                                   perturb_service, scale_rates,
                                   stack_env_params, to_env_params,
                                   with_noise_sigma, with_speed,
                                   with_straggler)
from repro.dsdps.workload import WorkloadProcess, step_rates
from repro.dsdps.env import EnvState, SchedulingEnv, StepOut
from repro.dsdps.structural import (Envelope, GraphEnvParams,
                                    StructuralSchedulingEnv, graph_latency_ms)
from repro.dsdps import actions, apps, scenarios

__all__ = [
    "actions",
    "Component", "Edge", "GraphObs", "Topology", "ClusterSpec",
    "PAPER_CLUSTER",
    "Envelope", "GraphEnvParams", "StructuralSchedulingEnv",
    "graph_latency_ms",
    "SimParams", "EnvParams", "average_tuple_time_ms",
    "average_tuple_time_from_params", "build_sim_params", "to_env_params",
    "params_stacked", "params_in_axes", "lane_params",
    "perturb_rates", "perturb_service", "scale_rates", "stack_env_params",
    "with_noise_sigma", "with_speed", "with_straggler",
    "WorkloadProcess", "step_rates", "EnvState", "SchedulingEnv", "StepOut",
    "apps", "scenarios",
]
