"""Deterministic, host-sharded synthetic data pipeline with prefetch.

Every batch is a pure function of (seed, host_id, step): restarts replay
the exact token stream (fault-tolerance invariant, tested), and each host
of a multi-host job draws a disjoint shard of the global batch.  A
background thread keeps ``prefetch`` batches ahead of the trainer."""
from __future__ import annotations

import dataclasses
import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def batch_at(cfg: DataConfig, step: int) -> dict:
    """Pure function of (cfg.seed, cfg.host_id, step) -> training batch.
    Tokens follow a Zipf-ish distribution so losses are non-degenerate."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, cfg.host_id, step]))
    z = rng.zipf(1.3, size=(cfg.host_batch, cfg.seq_len + 1))
    tokens = (z % (cfg.vocab_size - 1)).astype(np.int32) + 1
    return {
        "tokens": jnp.asarray(tokens[:, :-1]),
        "targets": jnp.asarray(tokens[:, 1:]),
    }


class PrefetchIterator:
    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._next_to_produce = start_step
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self) -> None:
        while not self._stop.is_set():
            b = batch_at(self.cfg, self._next_to_produce)
            self._q.put((self._next_to_produce, b))
            self._next_to_produce += 1

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        step, b = self._q.get()
        self.step = step
        return b

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def pack_sequences(docs: list[np.ndarray], seq_len: int,
                   pad_id: int = 0) -> np.ndarray:
    """Greedy sequence packing: concatenate documents into rows of exactly
    seq_len tokens (no padding waste except the final row)."""
    flat = np.concatenate(docs) if docs else np.zeros(0, np.int32)
    n_rows = max(int(np.ceil(len(flat) / seq_len)), 1)
    out = np.full((n_rows, seq_len), pad_id, dtype=np.int32)
    for r in range(n_rows):
        row = flat[r * seq_len:(r + 1) * seq_len]
        out[r, : len(row)] = row
    return out
