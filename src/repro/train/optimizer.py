"""Pure-JAX optimizers (no optax in the environment).

Functional API mirroring optax: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``; apply with
``apply_updates``.  AdamW with decoupled weight decay, Adam, SGD+momentum,
global-norm clipping, and warmup-cosine schedules — everything the LM
trainer and the DRL agents need."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: jax.Array | dict
    nu: jax.Array | dict


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable   # (grads, state, params) -> (updates, new_state)


def _tree_zeros_like(tree, dtype=jnp.float32):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), tree)


def adamw(
    learning_rate: float | Callable[[jnp.ndarray], jnp.ndarray],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    mask: Callable | None = None,   # pytree-of-bool fn for decay mask
) -> Optimizer:
    def lr_at(step):
        return learning_rate(step) if callable(learning_rate) else learning_rate

    def init(params):
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=_tree_zeros_like(params),
            nu=_tree_zeros_like(params),
        )

    def update(grads, state, params):
        step = state.step + 1
        lr = lr_at(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
                          state.nu, grads)
        decay_mask = mask(params) if mask is not None else jax.tree.map(
            lambda p: p.ndim >= 2, params)

        def upd(m, v, p, dm):
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u + weight_decay * jnp.where(dm, p.astype(u.dtype), 0.0)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params, decay_mask)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def adam(learning_rate, b1=0.9, b2=0.999, eps=1e-8) -> Optimizer:
    return adamw(learning_rate, b1=b1, b2=b2, eps=eps, weight_decay=0.0)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: jax.Array | dict


def sgd(learning_rate, momentum: float = 0.0) -> Optimizer:
    def lr_at(step):
        return learning_rate(step) if callable(learning_rate) else learning_rate

    def init(params):
        return SGDState(jnp.zeros((), jnp.int32), _tree_zeros_like(params))

    def update(grads, state, params):
        step = state.step + 1
        mom = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                           state.momentum, grads)
        updates = jax.tree.map(lambda m, p: (-lr_at(step) * m).astype(p.dtype),
                               mom, params)
        return updates, SGDState(step, mom)

    return Optimizer(init=init, update=update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1) -> Callable:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule


def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)
