"""Training step: microbatched gradient accumulation + AdamW + options.

``make_train_step`` builds the jit-able step used by both the real trainer
(launch/train.py) and the multi-pod dry-run.  Structure:

  batch [B_global, S]  ->  reshape [n_micro, B_micro, S]
  lax.scan over microbatches: remat'd loss+grad, accumulated in bf16/fp32
  (optional) int8 error-feedback compression of the cross-pod all-reduce
  global-norm clip -> AdamW update (moments in cfg-selected dtype)

Grad accumulation bounds activation memory (the scan carries only the grad
buffer); XLA overlaps the per-microbatch collectives with the next
microbatch's compute (latency hiding)."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class TrainSetup:
    micro_batches: int = 4
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"       # "bfloat16" halves optimizer memory
    accum_dtype: str = "float32"
    compress_grads: bool = False        # int8 EF all-reduce (train/compression)
    b1: float = 0.9
    b2: float = 0.95


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt: opt_lib.AdamState
    # error-feedback residual for gradient compression (zeros if unused)
    ef_residual: Any


def make_optimizer(setup: TrainSetup):
    sched = opt_lib.warmup_cosine(setup.learning_rate, setup.warmup_steps,
                                  setup.total_steps)
    return opt_lib.adamw(sched, b1=setup.b1, b2=setup.b2,
                         weight_decay=setup.weight_decay)


def init_train_state(cfg: ModelConfig, setup: TrainSetup, key) -> TrainState:
    params = lm.init_params(cfg, key)
    return _finish_init(params, setup)


def _finish_init(params, setup: TrainSetup) -> TrainState:
    optz = make_optimizer(setup)
    mdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[setup.moment_dtype]
    st = optz.init(params)
    st = opt_lib.AdamState(
        step=st.step,
        mu=jax.tree.map(lambda m: m.astype(mdt), st.mu),
        nu=jax.tree.map(lambda v: v.astype(mdt), st.nu),
    )
    if setup.compress_grads:
        ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
    else:
        ef = jax.tree.map(lambda p: jnp.zeros((), jnp.bfloat16), params)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt=st, ef_residual=ef)


def abstract_train_state(cfg: ModelConfig, setup: TrainSetup):
    """Shape/dtype pytree of the full train state — no allocation."""
    return jax.eval_shape(
        lambda: _finish_init(lm.init_params(cfg, jax.random.PRNGKey(0)), setup))


@functools.lru_cache(maxsize=None)
def jitted_train_step(cfg: ModelConfig, setup: TrainSetup) -> Callable:
    """One donating jitted step per (cfg, setup) — callers that jit the
    factory's closure per run pay a full retrace every launch."""
    return jax.jit(make_train_step(cfg, setup), donate_argnums=(0,))


def make_train_step(cfg: ModelConfig, setup: TrainSetup) -> Callable:
    loss_fn = lm.train_loss(cfg)
    optz = make_optimizer(setup)
    adt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[setup.accum_dtype]

    def train_step(state: TrainState, batch: dict):
        n_micro = setup.micro_batches

        def reshape_micro(x):
            b = x.shape[0]
            assert b % n_micro == 0, (b, n_micro)
            return x.reshape(n_micro, b // n_micro, *x.shape[1:])

        micro = jax.tree.map(reshape_micro, batch)

        def micro_step(carry, mb):
            g_acc, loss_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, mb)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(adt), g_acc, grads)
            return (g_acc, loss_acc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), state.params)
        (g_sum, loss_sum), _ = jax.lax.scan(
            micro_step, (g0, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / n_micro, g_sum)
        loss = loss_sum / n_micro

        ef = state.ef_residual
        if setup.compress_grads:
            from repro.train.compression import ef_compress_grads
            grads, ef = ef_compress_grads(grads, ef)

        grads, gnorm = opt_lib.clip_by_global_norm(grads, setup.clip_norm)
        updates, opt_state = optz.update(grads, state.opt, state.params)
        params = opt_lib.apply_updates(state.params, updates)
        new_state = TrainState(step=state.step + 1, params=params,
                               opt=opt_state, ef_residual=ef)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt_lib.warmup_cosine(
                       setup.learning_rate, setup.warmup_steps,
                       setup.total_steps)(state.step + 1)}
        return new_state, metrics

    return train_step
