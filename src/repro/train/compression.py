"""Int8 error-feedback gradient compression for cross-pod all-reduce.

At 1000+-node scale the pod-to-pod (DCN/ICI-bridge) all-reduce of dense
gradients is the scarcest bandwidth.  We quantize each gradient leaf to
int8 with a per-leaf scale *before* the reduction and keep the
quantization error as residual state that is re-added next step
(error feedback, Seide et al. / 1-bit SGD lineage; convergence-neutral in
practice).  In HLO this shows as a 4× reduction in all-reduce operand
bytes — directly visible in the dry-run's collective roofline term.

Used inside the jitted train step; shard_map-free (works under plain pjit
because quantize/dequantize are elementwise and GSPMD keeps the reduce on
the int8 tensor)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_leaf(g: jnp.ndarray, residual: jnp.ndarray):
    """Error-feedback compress one gradient leaf."""
    corrected = g.astype(jnp.float32) + residual.astype(jnp.float32)
    q, scale = quantize_int8(corrected)
    deq = dequantize_int8(q, scale)
    new_residual = (corrected - deq).astype(residual.dtype)
    return deq.astype(g.dtype), new_residual


def ef_compress_grads(grads, residuals):
    """Apply EF-int8 to every leaf.  Returns (compressed grads, residuals)."""
    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        cg, cr = ef_compress_leaf(g, r)
        out_g.append(cg)
        out_r.append(cr)
    return jax.tree.unflatten(tree, out_g), jax.tree.unflatten(tree, out_r)


def compression_error(g: jnp.ndarray) -> jnp.ndarray:
    """Relative L2 error of a single (non-EF) int8 round trip — used by
    property tests to bound worst-case distortion."""
    q, s = quantize_int8(g)
    deq = dequantize_int8(q, s)
    return jnp.linalg.norm(deq - g) / jnp.maximum(jnp.linalg.norm(g), 1e-12)
