"""Continuous batching: per-slot positions, admission, and eviction.

Real serving at scale cannot wait for the whole batch to finish — slots
are recycled as requests complete (vLLM-style iteration-level scheduling).
This scheduler keeps a queue of pending requests and a fixed pool of
batch slots; each engine step decodes one token for every active slot,
retires slots that emit EOS or exhaust their budget, and immediately
re-fills them with queued prompts (whose prefill proceeds in-slot,
token-by-token, interleaved with other slots' decode — chunked-prefill
semantics with chunk = 1)."""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig
from repro.serve.engine import SamplingParams, jitted_serve_step, sample_token


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Host-side slot scheduler around a per-slot-position decode step."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int, n_slots: int,
                 eos_id: int = 0, sp: SamplingParams | None = None):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.n_slots = n_slots
        self.eos_id = eos_id
        self.sp = sp if sp is not None else SamplingParams()
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)       # prompt cursor
        self.cache = lm.init_cache(cfg, batch=n_slots, max_seq=max_seq)
        self._step = jitted_serve_step(cfg)
        self._finished: list[Request] = []

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, key, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or any(self.slots)) and steps < max_steps:
            key, k = jax.random.split(key)
            self.step(k)
            steps += 1
        return self._finished

    # -- one engine iteration ---------------------------------------------------
    def step(self, key) -> None:
        self._admit()
        tokens = np.zeros((self.n_slots, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            cur = self.slot_pos[i]
            if cur < len(req.prompt):
                tokens[i, 0] = req.prompt[cur]            # in-slot prefill
            elif req.out:
                tokens[i, 0] = req.out[-1]
            else:
                tokens[i, 0] = req.prompt[-1]
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(tokens))
        sampled = np.asarray(sample_token(key, logits, self.sp))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.slot_pos[i] += 1
            if self.slot_pos[i] < len(req.prompt):
                continue                                   # still prefilling
            tok = int(sampled[i])
            req.out.append(tok)
            if (tok == self.eos_id
                    or len(req.out) >= req.max_new_tokens
                    or int(self.slot_pos[i]) + len(req.out) >= self.max_seq):
                req.done = True
                self._finished.append(req)
                self.slots[i] = None                       # recycle slot

    # NOTE: the shared cache["len"] advances for all slots; per-slot state
    # (attention over stale prefixes of retired slots) is masked out by the
    # fresh prompt overwriting the slot's positions during in-slot prefill.
    # A production engine would use paged caches; this models the schedule.
    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                self.slot_pos[i] = 0

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slots)
