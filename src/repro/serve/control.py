"""Serving control plane: batched low-latency decisions for many clusters.

The paper's end state is *online control* — a trained policy continuously
issuing scheduling decisions to live DSDPS clusters, where decision
latency is part of the control loop.  This module is the inference-side
counterpart of the fleet trainer: a :class:`ControlPlane` accepts
concurrent per-cluster :class:`DecisionRequest`\\ s (state vector +
cluster id), batches every active request into ONE jitted
``Agent.select`` call, and streams :class:`DecisionRequest` results back.

The scheduler is the slot-admission/eviction design of
``serve/continuous.py`` (ContinuousBatcher) adapted from LM tokens to
scheduling decisions: a FIFO queue feeds a fixed pool of batch slots,
each engine step serves every active slot in one dispatch, and — because
a scheduling decision completes in a single step, unlike a token stream —
every served slot retires immediately and is recycled on the next
admission pass.  The batch width is therefore ``min(n_slots, backlog)``
every step, and queueing delay (not just compute) shows up in the
reported latency percentiles, exactly as in a real service.

Heterogeneous clusters share one XLA program: each registered cluster's
:class:`~repro.dsdps.simulator.EnvParams` joins a
``stack_env_params(..., broadcast_invariant=True)`` stack, the jitted
program gathers each slot's cluster row with a ``[n_slots]`` int32
index, and ``params_in_axes`` drives the vmap — invariant leaves
(routing, flow_solve, ...) stay single-copy and broadcast.  On
accelerator backends the per-step input buffers (keys + state-vector
batch) are donated; agent state and the cluster stack are long-lived and
never donated.

The serving contract is ``select(s_vec, cluster params)``: the decision
policies it dispatches (``ddpg`` placement, ``rate_control``,
``auto_tune`` — see ``core/spaces.py``) decide from the state vector and
the cluster's parameters alone.  Agents whose select needs a live
``EnvState`` (dqn's incremental move, model_based's search) are not
servable through this path.

Steady-state discipline: a plane exposes its jitted program for
``diagnostics.guards(track=...)`` — after warmup, serving any request mix
over a FIXED cluster registry compiles exactly once (asserted in
tests/test_control_plane.py and the launch entry points).  Registering a
new cluster changes the stack's shapes and costs one recompile."""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spaces
from repro.core.api import Agent
from repro.dsdps.simulator import params_in_axes, stack_env_params


# --------------------------------------------------------------------------
# Request / decision types
# --------------------------------------------------------------------------
@dataclasses.dataclass
class DecisionRequest:
    """One cluster's ask for a decision.

    Submit with ``rid``/``cluster``/``s_vec`` (and ``kind`` when routing
    through a multi-kind :class:`ControlService`); the plane fills
    ``action`` / ``latency_ms`` / ``done`` when the decision is served.
    ``latency_ms`` is submit→decision wall time — queueing included."""

    rid: int
    cluster: str
    s_vec: Any                       # [state_dim] float32
    kind: str | None = None
    action: Any = None               # np.ndarray once decided
    latency_ms: float = 0.0
    submitted_at: float = 0.0
    done: bool = False


def nearest_rank_percentile(samples, q: float) -> float:
    """Deterministic nearest-rank percentile (no interpolation): the
    smallest sample with at least q% of the trace at or below it."""
    if not len(samples):
        raise ValueError("percentile of an empty trace")
    xs = sorted(float(x) for x in samples)
    rank = max(1, math.ceil(q / 100.0 * len(xs)))
    return xs[rank - 1]


def latency_stats(samples_ms) -> dict:
    """p50/p99/mean over a latency trace (ms) — the serve_bench schema."""
    samples = [float(x) for x in samples_ms]
    return {
        "n": len(samples),
        "p50_ms": nearest_rank_percentile(samples, 50.0),
        "p99_ms": nearest_rank_percentile(samples, 99.0),
        "mean_ms": sum(samples) / len(samples),
    }


# --------------------------------------------------------------------------
# Jitted select programs — module-level lru_cache'd builders (a
# per-instance jax.jit would start every plane with a cold trace cache,
# and an inline jit would re-wrap per call: the serve/engine.py pattern).
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def single_select_program(agent: Agent, explore: bool = False):
    """One request's ``Agent.select`` as a jitted program — the
    sequential baseline path serve_bench compares the batched plane to."""

    def fn(key, state, s_vec, env_params):
        action, _ = agent.select_fn(key, agent.cfg, state, s_vec, None,
                                    env_params, explore)
        return action

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def batched_select_program(agent: Agent, params_axes, explore: bool = False,
                           donate: bool = False):
    """Every active slot's select as ONE jitted vmapped call.

    ``params_axes`` is the hashable per-leaf in_axes pytree from
    :func:`params_in_axes` over the cluster stack (None = every cluster
    identical → params broadcast whole).  The program gathers each slot's
    cluster row from the stacked leaves with the ``[n_slots]`` lane index
    (invariant leaves pass through single-copy), then vmaps the agent's
    select over slots with shared agent state.  ``donate=True`` donates
    the per-step key and state-vector buffers (rebuilt every step; the
    agent state and cluster stack are long-lived and never donated)."""

    def fn(keys, state, s_mat, lane_idx, stacked_params):
        if params_axes is None:
            lanes, in_axes = stacked_params, None
        else:
            flat, treedef = jax.tree_util.tree_flatten(stacked_params)
            flat_axes = jax.tree_util.tree_flatten(
                params_axes, is_leaf=lambda x: x is None)[0]
            lanes = jax.tree_util.tree_unflatten(treedef, [
                jnp.take(p, lane_idx, axis=0) if a == 0 else p
                for p, a in zip(flat, flat_axes)])
            in_axes = params_axes

        def one(k, sv, lane_p):
            action, _ = agent.select_fn(k, agent.cfg, state, sv, None,
                                        lane_p, explore)
            return action

        return jax.vmap(one, in_axes=(0, 0, in_axes))(keys, s_mat, lanes)

    if donate:
        return jax.jit(fn, donate_argnums=(0, 2))
    return jax.jit(fn)


# --------------------------------------------------------------------------
# The control plane
# --------------------------------------------------------------------------
class ControlPlane:
    """Host-side slot scheduler around one batched decision program.

    One plane serves ONE decision kind (an ``core.spaces`` action space)
    with one agent + agent state shared across clusters; clusters differ
    by their registered EnvParams.  ``donate=None`` donates per-step
    buffers on accelerator backends only (donation is a no-op on CPU)."""

    def __init__(self, env, agent: Agent, agent_state,
                 kind: str = "placement", n_slots: int = 8,
                 explore: bool = False, donate: bool | None = None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.space = spaces.action_space(kind)     # unknown kind -> KeyError
        self.kind = kind
        self.env = env
        self.agent = agent
        self.state = agent_state
        self.n_slots = int(n_slots)
        self.explore = bool(explore)
        self.donate = (jax.default_backend() != "cpu"
                       if donate is None else bool(donate))
        self.queue: deque[DecisionRequest] = deque()
        self.slots: list[Optional[DecisionRequest]] = [None] * self.n_slots
        self._ref = env.default_params()
        self._clusters: dict[str, int] = {}
        self._params_list: list[Any] = []
        self._stacked = None
        self._axes = None
        self._finished: list[DecisionRequest] = []
        self._latencies_ms: list[float] = []

    # -- cluster registry ----------------------------------------------------
    def register_cluster(self, name: str, env_params=None) -> int:
        """Attach a live cluster (default: the env's declared params).
        Returns its index.  Register clusters BEFORE steady-state serving:
        growing the registry re-stacks the params and changes the batched
        program's shapes, costing one recompile."""
        if name in self._clusters:
            raise ValueError(f"cluster {name!r} already registered")
        self._clusters[name] = len(self._params_list)
        self._params_list.append(
            self.env.default_params() if env_params is None else env_params)
        self._stacked = None                       # re-stack lazily
        return self._clusters[name]

    @property
    def clusters(self) -> tuple[str, ...]:
        return tuple(self._clusters)

    def _ensure_stacked(self) -> None:
        if self._stacked is not None:
            return
        if not self._params_list:
            raise RuntimeError("no clusters registered — call "
                               "register_cluster() before serving")
        # setup work crosses host<->device (the invariant-leaf comparison
        # pulls to host): lift the diagnostics transfer guard, as the
        # fleet runner's prepare_fleet does
        with jax.transfer_guard("allow"):
            self._stacked = stack_env_params(self._params_list,
                                             broadcast_invariant=True)
            self._axes = params_in_axes(self._stacked, self._ref)

    @property
    def program(self):
        """The plane's jitted batched-select program (stable identity per
        (agent, cluster-stack layout, explore, donate) — hand this to
        ``diagnostics.guards(track=...)``)."""
        self._ensure_stacked()
        return batched_select_program(self.agent, self._axes, self.explore,
                                      self.donate)

    # -- public API ----------------------------------------------------------
    def submit(self, req: DecisionRequest) -> None:
        if req.cluster not in self._clusters:
            raise KeyError(f"cluster {req.cluster!r} not registered; "
                           f"known: {sorted(self._clusters)}")
        if req.kind is None:
            req.kind = self.kind
        elif req.kind != self.kind:
            raise ValueError(f"request kind {req.kind!r} routed to the "
                             f"{self.kind!r} plane")
        req.submitted_at = time.perf_counter()
        self.queue.append(req)

    def run(self, key, max_steps: int = 10_000) -> list[DecisionRequest]:
        """Drain the queue; returns every request finished so far."""
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            key, k = jax.random.split(key)
            self.step(k)
            steps += 1
        return self._finished

    # -- one engine iteration ------------------------------------------------
    def step(self, key) -> list[DecisionRequest]:
        """Admit from the queue, serve every active slot in one batched
        dispatch, retire + recycle all served slots.  Returns the requests
        decided this step (in slot order: admission order)."""
        self._admit()
        active = [(i, r) for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return []
        self._ensure_stacked()
        program = self.program
        # batch assembly is boundary work (host buffers -> device): lift
        # the transfer guard here; the dispatch below runs guarded
        with jax.transfer_guard("allow"):
            s_mat = np.zeros((self.n_slots, self.env.state_dim), np.float32)
            lane_idx = np.zeros(self.n_slots, np.int32)
            for i, req in active:
                s_mat[i] = np.asarray(req.s_vec, np.float32)
                lane_idx[i] = self._clusters[req.cluster]
            keys = jax.random.split(key, self.n_slots)
            s_dev = jnp.asarray(s_mat)
            idx_dev = jnp.asarray(lane_idx)
        out = program(keys, self.state, s_dev, idx_dev, self._stacked)
        actions = np.asarray(out)                  # explicit pull (+sync)
        now = time.perf_counter()
        served = []
        for i, req in active:
            req.action = actions[i]
            req.latency_ms = (now - req.submitted_at) * 1e3
            req.done = True
            self.slots[i] = None                   # recycle slot
            self._latencies_ms.append(req.latency_ms)
            self._finished.append(req)
            served.append(req)
        return served

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.popleft()

    # -- introspection -------------------------------------------------------
    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slots)

    @property
    def pending(self) -> int:
        return len(self.queue) + self.active

    def decision_stats(self) -> dict:
        """p50/p99/mean decision latency over everything served so far."""
        return latency_stats(self._latencies_ms)

    def reset_stats(self) -> None:
        """Forget finished requests + the latency trace (queue and slots
        must be drained) — lets a bench warm the program up, then measure
        a clean steady-state window."""
        if self.pending:
            raise RuntimeError("reset_stats with in-flight requests")
        self._finished.clear()
        self._latencies_ms.clear()


class ControlService:
    """One serving endpoint dispatching several decision kinds.

    A thin router over per-kind :class:`ControlPlane`\\ s: requests carry
    ``kind`` and land on the matching plane; one :meth:`step` advances
    every plane (each runs its own batched program — decision kinds have
    different action shapes, so they cannot share a dispatch)."""

    def __init__(self, planes: dict[str, ControlPlane]):
        for kind, plane in planes.items():
            if plane.kind != kind:
                raise ValueError(f"plane for {kind!r} serves {plane.kind!r}")
        self.planes = dict(planes)

    @property
    def kinds(self) -> tuple[str, ...]:
        return tuple(sorted(self.planes))

    def register_cluster(self, name: str, env_params=None) -> None:
        """Register a cluster with EVERY plane (one live cluster asks for
        all decision kinds)."""
        for kind in self.kinds:
            self.planes[kind].register_cluster(name, env_params)

    def submit(self, req: DecisionRequest) -> None:
        if req.kind is None:
            raise ValueError("service requests must carry kind=")
        if req.kind not in self.planes:
            raise KeyError(f"no plane serves kind {req.kind!r}; "
                           f"known: {list(self.kinds)}")
        self.planes[req.kind].submit(req)

    def step(self, key) -> list[DecisionRequest]:
        served: list[DecisionRequest] = []
        for kind in self.kinds:
            key, k = jax.random.split(key)
            served.extend(self.planes[kind].step(k))
        return served

    def run(self, key, max_steps: int = 10_000) -> list[DecisionRequest]:
        steps = 0
        while any(p.pending for p in self.planes.values()) \
                and steps < max_steps:
            key, k = jax.random.split(key)
            self.step(k)
            steps += 1
        return [r for kind in self.kinds
                for r in self.planes[kind]._finished]

    def programs(self) -> tuple:
        """Every plane's jitted program, for ``guards(track=...)``."""
        return tuple(self.planes[k].program for k in self.kinds)

    def decision_stats(self) -> dict[str, dict]:
        return {k: self.planes[k].decision_stats() for k in self.kinds
                if self.planes[k]._latencies_ms}
