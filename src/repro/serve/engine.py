"""Batched serving engine: prefill + decode with sampling.

Static-batch engine over models/lm.serve_step (all slots advance in
lockstep — the configuration the decode dry-run cells lower).  The
continuous-batching engine with per-slot positions lives in
serve/continuous.py."""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig


@dataclasses.dataclass
class SamplingParams:
    temperature: float = 0.0          # 0 => greedy
    top_k: int = 0                    # 0 => no top-k filter


@functools.lru_cache(maxsize=None)
def jitted_serve_step(cfg: ModelConfig):
    """One jitted decode step per config, shared across engine instances —
    a per-instance jax.jit would start every engine with a cold trace cache."""
    return jax.jit(lm.serve_step(cfg))


def sample_token(key, logits: jnp.ndarray, sp: SamplingParams) -> jnp.ndarray:
    """logits: [B, V] -> [B] int32."""
    if sp.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / sp.temperature
    if sp.top_k > 0:
        vals, _ = jax.lax.top_k(logits, sp.top_k)
        cut = vals[:, -1:]
        logits = jnp.where(logits < cut, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


class Engine:
    """Minimal but complete: prompt prefill (token-by-token scan through
    the same decode step — exact, cache-consistent for every family),
    then batched autoregressive decode."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int,
                 batch_size: int, enc_len: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch_size = batch_size
        self.enc_len = enc_len
        self._step = jitted_serve_step(cfg)

    def new_cache(self):
        return lm.init_cache(self.cfg, batch=self.batch_size,
                             max_seq=self.max_seq, enc_len=self.enc_len)

    def prefill(self, cache, prompt_tokens: jnp.ndarray):
        """prompt_tokens: [B, T] — scan the decode step over the prompt."""
        def body(cache, tok_col):
            logits, cache = self._step(self.params, cache, tok_col[:, None])
            return cache, logits

        cache, logits = jax.lax.scan(body, cache, prompt_tokens.T)
        return cache, logits[-1]                      # last-position logits

    def generate(self, key, prompt_tokens: jnp.ndarray, max_new_tokens: int,
                 sp: SamplingParams | None = None,
                 frames: jnp.ndarray | None = None) -> jnp.ndarray:
        """Returns [B, max_new_tokens] sampled continuations."""
        sp = sp if sp is not None else SamplingParams()
        cache = self.new_cache()
        if self.cfg.encoder_layers and frames is not None:
            cache = lm.prefill_encoder(self.cfg, self.params, cache, frames)
        cache, logits = self.prefill(cache, prompt_tokens)

        def body(carry, k):
            cache, logits = carry
            tok = sample_token(k, logits, sp)
            logits, cache = self._step(self.params, cache, tok[:, None])
            return (cache, logits), tok

        keys = jax.random.split(key, max_new_tokens)
        (_, _), toks = jax.lax.scan(body, (cache, logits), keys)
        return toks.T                                  # [B, max_new]
