"""Public serving surface.

Two families live here:

* LM token serving — :class:`~repro.serve.engine.Engine` (static batch)
  and :class:`~repro.serve.continuous.ContinuousBatcher` (slot
  admission/eviction over a fixed pool).
* The scheduling control plane — :class:`~repro.serve.control.ControlPlane`
  / :class:`~repro.serve.control.ControlService`, the same slot scheduler
  adapted from tokens to batched low-latency scheduling decisions for
  many live clusters (docs/serving.md).
"""
from repro.serve.continuous import ContinuousBatcher, Request
from repro.serve.control import (ControlPlane, ControlService,
                                 DecisionRequest, batched_select_program,
                                 latency_stats, nearest_rank_percentile,
                                 single_select_program)
from repro.serve.engine import (Engine, SamplingParams, jitted_serve_step,
                                sample_token)

__all__ = [
    "Engine", "SamplingParams", "jitted_serve_step", "sample_token",
    "ContinuousBatcher", "Request",
    "ControlPlane", "ControlService", "DecisionRequest",
    "batched_select_program", "single_select_program",
    "latency_stats", "nearest_rank_percentile",
]
