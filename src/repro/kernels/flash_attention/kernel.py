"""Pallas TPU flash-attention kernel (GQA, causal) with explicit BlockSpec
VMEM tiling.

Grid: (batch·q_heads, S/q_blk, S/kv_blk) — the kv axis is innermost and
accumulates into VMEM scratch (running max / sum / output block), the
standard online-softmax schedule.  Block shapes are MXU-aligned
(q_blk × kv_blk × head_dim multiples of 128 on real TPU); causal blocks
above the diagonal are skipped with pl.when so no FLOPs are wasted.

VMEM working set per step:
  q (q_blk·hd) + k,v (kv_blk·hd) + scores (q_blk·kv_blk) + acc (q_blk·hd)
  ≈ (512·128 + 2·512·128 + 512·512 + 512·128)·4B ≈ 1.8 MB  « 16 MB VMEM.

Validated in interpret mode against ref.attention_ref (CPU has no TPU;
the kernel body itself executes in Python under interpret=True)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               causal: bool, q_blk: int, kv_blk: int, scale: float,
               n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip blocks strictly above the diagonal
    run = (ki * kv_blk <= qi * q_blk + q_blk - 1) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                     # [q_blk, hd]
        k = k_ref[0].astype(jnp.float32)                     # [kv_blk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32,
                                                          (q_blk, kv_blk), 0)
            kv_pos = ki * kv_blk + jax.lax.broadcasted_iota(jnp.int32,
                                                            (q_blk, kv_blk), 1)
            s = jnp.where(q_pos >= kv_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_blk", "kv_blk",
                                             "interpret", "num_kv_heads"))
def flash_attention_kernel(
    q: jnp.ndarray,               # [BH, S, hd]  (batch×q_heads flattened)
    k: jnp.ndarray,               # [BKH, S, hd] (batch×kv_heads flattened)
    v: jnp.ndarray,
    *,
    num_kv_heads: int,
    causal: bool = True,
    q_blk: int = 128,
    kv_blk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    BH, S, hd = q.shape
    BKH = k.shape[0]
    H = BH // (BKH // num_kv_heads)      # q heads per batch
    G = BH // BKH                         # q heads per kv head
    q_blk = min(q_blk, S)
    kv_blk = min(kv_blk, S)
    n_q, n_kv = S // q_blk, S // kv_blk
    scale = 1.0 / (hd ** 0.5)

    grid = (BH, n_q, n_kv)
    kernel = functools.partial(_fa_kernel, causal=causal, q_blk=q_blk,
                               kv_blk=kv_blk, scale=scale, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_blk, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kv_blk, hd), lambda bh, qi, ki: (bh // G, ki, 0)),
            pl.BlockSpec((1, kv_blk, hd), lambda bh, qi, ki: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_blk, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            # running max / sum / accumulator live in VMEM across kv steps
            pltpu.VMEM((q_blk,), jnp.float32),
            pltpu.VMEM((q_blk,), jnp.float32),
            pltpu.VMEM((q_blk, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
