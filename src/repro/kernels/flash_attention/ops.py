"""jit'd public wrapper: [B,S,H,hd] layout in, kernel layout inside.

On a real TPU backend set interpret=False; the CPU container always runs
interpret=True (kernel body executed in Python for validation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def flash_attention(q, k, v, *, causal: bool = True, q_blk: int = 128,
                    kv_blk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """q: [B,S,H,hd]; k/v: [B,S,Hkv,hd] -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    # kernel layout: heads-major so each grid step owns one (head, q-block)
    qk = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    vk = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    o = flash_attention_kernel(qk, kk, vk, num_kv_heads=Hkv, causal=causal,
                               q_blk=q_blk, kv_blk=kv_blk, interpret=interpret)
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
