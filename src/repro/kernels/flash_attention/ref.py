"""Pure-jnp oracle for the flash-attention kernel (GQA, optional causal)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q: [B,S,H,hd]; k/v: [B,S,Hkv,hd] -> [B,S,H,hd] (fp32 math)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, S, Hkv, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) / jnp.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return o.reshape(B, S, H, hd).astype(q.dtype)
