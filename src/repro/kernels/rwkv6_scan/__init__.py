from repro.kernels.rwkv6_scan.ops import wkv6
from repro.kernels.rwkv6_scan.ref import wkv6_ref

__all__ = ["wkv6", "wkv6_ref"]
