"""Pure-jnp oracle for the RWKV6 WKV recurrence kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(w, r, k, v, u, S0=None):
    """w,r,k,v: [B,T,H,hd] (w = per-step decay in (0,1)); u: [H,hd] bonus.
    Returns (out [B,T,H,hd] fp32, S_T [B,H,hd,hd] fp32).

      S_t   = diag(w_t) S_{t-1} + k_t v_tᵀ
      out_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)
    """
    B, T, H, hd = r.shape
    if S0 is None:
        S0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(S, inp):
        w_t, r_t, k_t, v_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]            # [B,H,hd,hd]
        out = jnp.einsum("bhij,bhi->bhj", S + u[None, :, :, None] * kv, r_t)
        S = w_t[..., None] * S + kv
        return S, out

    seq = tuple(a.swapaxes(0, 1).astype(jnp.float32) for a in (w, r, k, v))
    S_T, out = jax.lax.scan(step, S0, seq)
    return out.swapaxes(0, 1), S_T
