"""Pallas TPU kernel for the RWKV6 WKV recurrence (chunked scan).

Grid: (B·H, T/chunk) — the chunk axis is innermost and *sequential*; the
[hd, hd] recurrent state lives in VMEM scratch and persists across chunk
steps of the same (batch, head) program (it is re-zeroed at chunk 0).
Within a chunk the recurrence runs as an unrolled fori_loop over
timesteps; each step is one rank-1 update + one [hd]·[hd,hd] contraction
— hd=64 keeps the state tile (64·64·4B = 16 KB) and the per-chunk
operands (4·chunk·hd·4B ≈ 128 KB at chunk=128) comfortably in VMEM.

TPU adaptation note (DESIGN.md §3): CUDA RWKV kernels assign one thread
per channel with warp-level reductions; on TPU the natural unit is the
whole [hd, hd] state tile in VMEM with VPU outer products — same math,
different blocking."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(w_ref, r_ref, k_ref, v_ref, u_ref, o_ref, s_ref, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)                       # [hd]

    def step(t, S):
        w_t = w_ref[0, t].astype(jnp.float32)              # [hd]
        r_t = r_ref[0, t].astype(jnp.float32)
        k_t = k_ref[0, t].astype(jnp.float32)
        v_t = v_ref[0, t].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]                   # [hd, hd]
        out = ((S + u[:, None] * kv) * r_t[:, None]).sum(axis=0)
        o_ref[0, t] = out.astype(o_ref.dtype)
        return w_t[:, None] * S + kv

    s_ref[...] = jax.lax.fori_loop(0, chunk, step, s_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_kernel(
    w: jnp.ndarray,               # [BH, T, hd] decay in (0,1)
    r: jnp.ndarray,               # [BH, T, hd]
    k: jnp.ndarray,
    v: jnp.ndarray,
    u: jnp.ndarray,               # [BH, hd] (bonus, broadcast per head)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    BH, T, hd = r.shape
    chunk = min(chunk, T)
    n_chunks = T // chunk
    grid = (BH, n_chunks)

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, hd), lambda bh, ci: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(w, r, k, v, u)
