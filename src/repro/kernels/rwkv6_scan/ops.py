"""jit'd public wrapper for the WKV6 kernel: [B,T,H,hd] layout in/out."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.rwkv6_scan.kernel import wkv6_kernel


def wkv6(w, r, k, v, u, *, chunk: int = 128, interpret: bool = True):
    """w,r,k,v: [B,T,H,hd]; u: [H,hd] -> out [B,T,H,hd] fp32."""
    B, T, H, hd = r.shape

    def flat(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, T, hd)

    u_b = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    o = wkv6_kernel(flat(w), flat(r), flat(k), flat(v), u_b,
                    chunk=chunk, interpret=interpret)
    return o.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
