"""Pallas TPU kernels for the framework's perf-critical compute:

  flash_attention — GQA causal attention (dense/moe/vlm/encdec archs)
  rwkv6_scan      — WKV6 recurrence with data-dependent decay (rwkv6-7b)
  knn_topk        — row top-2 + regret for the paper's MIQP-NN projection

The paper itself has no kernel-level contribution (it is a scheduling
paper — DESIGN.md §3); these kernels serve the surrounding framework's
hot spots plus the paper's optimizer inner step.  Each ships a pure-jnp
oracle (ref.py) and is validated in interpret=True mode (this container
is CPU-only; TPU is the target)."""
