"""Pure-jnp oracle for the K-NN projection row-reduction kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def row_top2_regret_ref(proto: jnp.ndarray):
    """proto: [N, M] -> (best_idx [N] i32, second_idx [N] i32, regret [N] f32)

    regret[i] = 2·(proto[i, best] − proto[i, second]) — the cost of flipping
    row i to its 2nd-best machine (DESIGN.md §2)."""
    vals, idx = jax.lax.top_k(proto.astype(jnp.float32), 2)
    regret = 2.0 * (vals[:, 0] - vals[:, 1])
    return idx[:, 0].astype(jnp.int32), idx[:, 1].astype(jnp.int32), regret
