"""Pallas kernel for the MIQP-NN projection's hot inner step.

Computes, for every row of the proto-action matrix [N, M], the best and
second-best machine and the flip regret Δᵢ = 2(âᵢ,(1) − âᵢ,(2)) — the
quantities the exact k-best enumeration consumes (core/knn_projection.py).
Replaces the paper's per-instance Gurobi MIQP solve (~10 ms on a desktop)
with one vectorized pass (<1 µs/row on TPU).

Grid: (N / row_blk,) — each program reduces a [row_blk, M] VMEM tile with
two masked max-reductions (no sort needed for top-2)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _top2_kernel(proto_ref, best_ref, second_ref, regret_ref):
    p = proto_ref[...].astype(jnp.float32)                  # [row_blk, M]
    rows, m = p.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (rows, m), 1)
    best_val = p.max(axis=1)
    best_idx = jnp.argmax(p, axis=1).astype(jnp.int32)
    masked = jnp.where(cols == best_idx[:, None], NEG_INF, p)
    second_val = masked.max(axis=1)
    second_idx = jnp.argmax(masked, axis=1).astype(jnp.int32)
    best_ref[...] = best_idx
    second_ref[...] = second_idx
    regret_ref[...] = 2.0 * (best_val - second_val)


@functools.partial(jax.jit, static_argnames=("row_blk", "interpret"))
def row_top2_regret(proto: jnp.ndarray, *, row_blk: int = 128,
                    interpret: bool = True):
    """proto: [N, M] -> (best [N] i32, second [N] i32, regret [N] f32)."""
    N, M = proto.shape
    row_blk = min(row_blk, N)
    pad = (-N) % row_blk
    if pad:
        proto = jnp.pad(proto, ((0, pad), (0, 0)), constant_values=NEG_INF)
    Np = proto.shape[0]
    grid = (Np // row_blk,)
    best, second, regret = pl.pallas_call(
        _top2_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((row_blk, M), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((row_blk,), lambda i: (i,)),
            pl.BlockSpec((row_blk,), lambda i: (i,)),
            pl.BlockSpec((row_blk,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((Np,), jnp.int32),
            jax.ShapeDtypeStruct((Np,), jnp.int32),
            jax.ShapeDtypeStruct((Np,), jnp.float32),
        ),
        interpret=interpret,
    )(proto)
    return best[:N], second[:N], regret[:N]
