from repro.kernels.knn_topk.ops import row_top2_regret
from repro.kernels.knn_topk.ref import row_top2_regret_ref

__all__ = ["row_top2_regret", "row_top2_regret_ref"]
