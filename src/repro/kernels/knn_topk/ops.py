"""jit'd wrapper for the K-NN row-reduction kernel."""
from repro.kernels.knn_topk.kernel import row_top2_regret

__all__ = ["row_top2_regret"]
