"""Pure-JAX neural-net primitives (no flax): param-dict init + apply fns.

Parameters are plain nested dicts of jnp arrays so they pytree-map cleanly
onto sharding specs (sharding/policy.py matches on path names)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.bfloat16, scale: float | None = None) -> dict:
    scale = (1.0 / np.sqrt(d_in)) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embedding_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(p: dict, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], ids, axis=0)


def unembed(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Logits against the (possibly tied) embedding table."""
    return x @ p["table"].T


def rmsnorm_init(d: int, dtype=jnp.bfloat16) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
    return (xf * rms).astype(x.dtype) * p["scale"]


def layernorm_init(d: int, dtype=jnp.bfloat16) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * p["scale"] + p["bias"]


def swiglu_init(key, d: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": linear_init(k1, d, d_ff, dtype=dtype),
        "up": linear_init(k2, d, d_ff, dtype=dtype),
        "down": linear_init(k3, d_ff, d, dtype=dtype),
    }


def swiglu(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    from repro.sharding import ctx
    h = linear(p["gate"], x)
    u = linear(p["up"], x)
    if h.ndim == 3:
        # Megatron column-parallel: d_ff lives on the model axis — without
        # this GSPMD replicates the FFN across tp (16× wasted FLOPs)
        h = ctx.constrain(h, "dp", None, "tp")
        u = ctx.constrain(u, "dp", None, "tp")
    return linear(p["down"], jax.nn.silu(h) * u)


# -- rotary position embeddings ----------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                                   # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs    # [..., S, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]                          # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
