"""Recurrent mixers: Mamba-1 selective SSM (Jamba's backbone) and RWKV6
"Finch" time-mix with data-dependent decay.

Both use the same chunked-scan execution scheme: an outer ``lax.scan``
carries the recurrent state across sequence chunks (so checkpointed
activations are only chunk boundaries), and the inner chunk is processed
step-by-step under ``jax.checkpoint`` (backward recomputes the chunk).
This bounds live memory to O(state × S/chunk) instead of O(state × S),
which is what makes the jamba/rwkv long_500k cells fit (DESIGN.md §5).

Decode is a single-step state update — O(1) per token, the reason these
families run the long_500k shape at all."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.sharding import ctx


# ===========================================================================
# Mamba-1 selective SSM
# ===========================================================================
def mamba_init(key, d: int, d_inner: int, d_state: int, d_conv: int,
               dtype=jnp.bfloat16) -> dict:
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": nn.linear_init(ks[0], d, 2 * d_inner, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner), jnp.float32)
                   / jnp.sqrt(d_conv)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": nn.linear_init(ks[2], d_inner, dt_rank + 2 * d_state, dtype=dtype),
        "dt_proj": nn.linear_init(ks[3], dt_rank, d_inner, bias=True, dtype=dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                                  (d_inner, 1))),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": nn.linear_init(ks[4], d_inner, d, dtype=dtype),
    }


def _mamba_scan_chunk(h0, dA, dBx, C):
    """Sequential in-chunk recurrence.  h0:[B,di,ds], dA/dBx:[B,T,di,ds],
    C:[B,T,ds] -> (hT, y:[B,T,di])."""
    def step(h, inp):
        dA_t, dBx_t, C_t = inp
        h = dA_t * h + dBx_t
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y
    hT, y = jax.lax.scan(step, h0,
                         (dA.swapaxes(0, 1), dBx.swapaxes(0, 1), C.swapaxes(0, 1)))
    return hT, y.swapaxes(0, 1)


def _mamba_scan_chunk_fused(h0, delta, Bm, C, x, A):
    """In-chunk recurrence with per-step discretization (no [B,T,di,ds]
    materialization).  delta/x: [B,T,di]; Bm/C: [B,T,ds]."""
    def step(h, inp):
        d_t, B_t, C_t, x_t = inp
        dA_t = jnp.exp(d_t[..., None] * A)                # [B,di,ds]
        h = dA_t * h + (d_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y
    hT, y = jax.lax.scan(step, h0,
                         (delta.swapaxes(0, 1), Bm.swapaxes(0, 1),
                          C.swapaxes(0, 1), x.swapaxes(0, 1)))
    return hT, y.swapaxes(0, 1)


def mamba_forward(p: dict, u: jnp.ndarray, *, d_state: int, d_conv: int,
                  chunk: int = 128, fused: bool = False) -> jnp.ndarray:
    """Full-sequence training/prefill path.  u: [B, S, d]."""
    B, S, d = u.shape
    xz = nn.linear(p["in_proj"], u)
    x, z = jnp.split(xz, 2, axis=-1)                       # [B,S,di]
    x = ctx.constrain(x, "dp", None, "tp")
    z = ctx.constrain(z, "dp", None, "tp")
    di = x.shape[-1]

    # causal depthwise conv1d
    x_pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    x = sum(x_pad[:, i:i + S, :] * p["conv_w"][i] for i in range(d_conv))
    x = jax.nn.silu(x + p["conv_b"])

    dbc = nn.linear(p["x_proj"], x)
    dt_rank = dbc.shape[-1] - 2 * d_state
    dt, Bm, Cm = jnp.split(dbc, [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(nn.linear(p["dt_proj"], dt).astype(jnp.float32))  # [B,S,di]
    A = -jnp.exp(p["A_log"])                               # [di, ds]
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    xf = x.astype(jnp.float32)

    n_chunks = max(S // chunk, 1)
    chunk = S // n_chunks

    def outer(h, idx):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * chunk, chunk, axis=1)
        d_c, B_c, C_c, x_c = sl(delta), sl(Bm), sl(Cm), sl(xf)

        @jax.checkpoint
        def run(h, d_c, B_c, C_c, x_c):
            if fused:
                return _mamba_scan_chunk_fused(h, d_c, B_c, C_c, x_c, A)
            dA = jnp.exp(d_c[..., None] * A)               # [B,T,di,ds]
            dBx = (d_c * x_c)[..., None] * B_c[:, :, None, :]
            return _mamba_scan_chunk(h, dA, dBx, C_c)

        h, y = run(h, d_c, B_c, C_c, x_c)
        return h, y

    h0 = jnp.zeros((B, di, d_state), jnp.float32)
    _, ys = jax.lax.scan(outer, h0, jnp.arange(n_chunks))
    y = ys.swapaxes(0, 1).reshape(B, S, di)
    y = y + xf * p["D"]
    y = y.astype(u.dtype) * jax.nn.silu(z)
    return nn.linear(p["out_proj"], y)


def mamba_init_cache(cfg_B: int, d_inner: int, d_state: int, d_conv: int,
                     dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((cfg_B, d_inner, d_state), jnp.float32),
        "conv": jnp.zeros((cfg_B, d_conv - 1, d_inner), dtype),
    }


def mamba_step(p: dict, u_t: jnp.ndarray, cache: dict, *, d_state: int,
               d_conv: int) -> tuple[jnp.ndarray, dict]:
    """Single-token decode.  u_t: [B, 1, d]."""
    B = u_t.shape[0]
    xz = nn.linear(p["in_proj"], u_t[:, 0])
    x, z = jnp.split(xz, 2, axis=-1)                       # [B, di]
    conv_buf = jnp.concatenate([cache["conv"], x[:, None]], axis=1)  # [B,dc,di]
    x = jnp.einsum("bcd,cd->bd", conv_buf, p["conv_w"]) + p["conv_b"]
    x = jax.nn.silu(x)
    dbc = nn.linear(p["x_proj"], x)
    dt_rank = dbc.shape[-1] - 2 * d_state
    dt, Bm, Cm = jnp.split(dbc, [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(nn.linear(p["dt_proj"], dt).astype(jnp.float32))
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(delta[..., None] * A)                     # [B,di,ds]
    dBx = (delta * x.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[:, None, :]
    h = dA * cache["h"] + dBx
    y = jnp.einsum("bds,bs->bd", h, Cm.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * p["D"]
    y = y.astype(u_t.dtype) * jax.nn.silu(z)
    out = nn.linear(p["out_proj"], y)[:, None]
    return out, {"h": h, "conv": conv_buf[:, 1:]}


# ===========================================================================
# RWKV6 (Finch): time-mix with data-dependent decay + channel-mix
# ===========================================================================
def rwkv6_init(key, d: int, d_ff: int, head_size: int, dtype=jnp.bfloat16) -> dict:
    H = d // head_size
    # derive the channel-mix receptance key BEFORE split() consumes `key`
    # (same bits as the old fold_in-after-split, minus the key reuse)
    k_wcr = jax.random.fold_in(key, 99)
    ks = jax.random.split(key, 12)
    lora = max(d // 64, 32)
    return {
        # time-mix
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dtype),
        "w_base": jnp.zeros((d,), jnp.float32) - 6.0,       # decay bias
        "w_lora1": nn.linear_init(ks[1], d, lora, dtype=dtype),
        "w_lora2": nn.linear_init(ks[2], lora, d, dtype=dtype, scale=0.01),
        "Wr": nn.linear_init(ks[3], d, d, dtype=dtype),
        "Wk": nn.linear_init(ks[4], d, d, dtype=dtype),
        "Wv": nn.linear_init(ks[5], d, d, dtype=dtype),
        "Wg": nn.linear_init(ks[6], d, d, dtype=dtype),
        "u": jnp.zeros((H, head_size), jnp.float32),        # bonus
        "Wo": nn.linear_init(ks[7], d, d, dtype=dtype),
        "ln_x": nn.layernorm_init(d, dtype=dtype),          # per-head groupnorm
        # channel-mix
        "mu_ck": jax.random.uniform(ks[8], (d,), jnp.float32).astype(dtype),
        "mu_cr": jax.random.uniform(ks[9], (d,), jnp.float32).astype(dtype),
        "Wck": nn.linear_init(ks[10], d, d_ff, dtype=dtype),
        "Wcv": nn.linear_init(ks[11], d_ff, d, dtype=dtype),
        "Wcr": nn.linear_init(k_wcr, d, d, dtype=dtype),
    }


def _rwkv_mix_projections(p, x, x_prev, head_size):
    """Token-shift lerps + projections.  x/x_prev: [B,T,d]."""
    B, T, d = x.shape
    H = d // head_size
    dx = x_prev - x
    xw = x + dx * p["mu"][0]
    xk = x + dx * p["mu"][1]
    xv = x + dx * p["mu"][2]
    xr = x + dx * p["mu"][3]
    xg = x + dx * p["mu"][4]
    # data-dependent decay (the Finch signature)
    w_dd = nn.linear(p["w_lora2"], jnp.tanh(nn.linear(p["w_lora1"], xw)))
    w = jnp.exp(-jnp.exp(p["w_base"] + w_dd.astype(jnp.float32)))   # [B,T,d] in (0,1)
    r = nn.linear(p["Wr"], xr).reshape(B, T, H, head_size)
    k = nn.linear(p["Wk"], xk).reshape(B, T, H, head_size)
    v = nn.linear(p["Wv"], xv).reshape(B, T, H, head_size)
    g = jax.nn.silu(nn.linear(p["Wg"], xg))
    r = ctx.constrain(r, "dp", None, "tp", None)
    k = ctx.constrain(k, "dp", None, "tp", None)
    v = ctx.constrain(v, "dp", None, "tp", None)
    return w.reshape(B, T, H, head_size), r, k, v, g


def _wkv_chunk(S0, w, r, k, v, u):
    """Sequential WKV recurrence over one chunk.
    S0: [B,H,hd,hd]; w,r,k,v: [B,T,H,hd]; u: [H,hd] -> (S_T, out [B,T,H,hd])."""
    def step(S, inp):
        w_t, r_t, k_t, v_t = inp                           # [B,H,hd] each
        kv = k_t[..., :, None] * v_t[..., None, :]         # [B,H,hd,hd]
        out = jnp.einsum("bhij,bhi->bhj", S + u[None, :, :, None] * kv, r_t)
        S = w_t[..., None] * S + kv
        return S, out
    seq = tuple(a.swapaxes(0, 1) for a in
                (w.astype(jnp.float32), r.astype(jnp.float32),
                 k.astype(jnp.float32), v.astype(jnp.float32)))
    S_T, out = jax.lax.scan(step, S0, seq)
    return S_T, out.swapaxes(0, 1)


def rwkv6_time_mix(p: dict, x: jnp.ndarray, *, head_size: int,
                   chunk: int = 128) -> jnp.ndarray:
    """Full-sequence path.  x: [B, S, d]."""
    B, S, d = x.shape
    H = d // head_size
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :S]
    w, r, k, v, g = _rwkv_mix_projections(p, x, x_prev, head_size)

    n_chunks = max(S // chunk, 1)
    chunk = S // n_chunks

    def outer(S0, idx):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * chunk, chunk, axis=1)

        @jax.checkpoint
        def run(S0, w_c, r_c, k_c, v_c):
            return _wkv_chunk(S0, w_c, r_c, k_c, v_c, p["u"])

        S_T, out = run(S0, sl(w), sl(r), sl(k), sl(v))
        return S_T, out

    S0 = jnp.zeros((B, H, head_size, head_size), jnp.float32)
    _, outs = jax.lax.scan(outer, S0, jnp.arange(n_chunks))
    out = outs.swapaxes(0, 1).reshape(B, S, d)
    out = nn.layernorm(p["ln_x"], out.astype(x.dtype))
    return nn.linear(p["Wo"], out * g)


def rwkv6_channel_mix(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    B, S, d = x.shape
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :S]
    dx = x_prev - x
    xk = x + dx * p["mu_ck"]
    xr = x + dx * p["mu_cr"]
    k = jnp.square(jax.nn.relu(nn.linear(p["Wck"], xk)))
    k = ctx.constrain(k, "dp", None, "tp")    # column-parallel channel mix
    return jax.nn.sigmoid(nn.linear(p["Wcr"], xr)) * nn.linear(p["Wcv"], k)


def rwkv6_init_cache(B: int, d: int, head_size: int, dtype=jnp.float32) -> dict:
    H = d // head_size
    return {
        "S": jnp.zeros((B, H, head_size, head_size), jnp.float32),
        "x_tm": jnp.zeros((B, d), dtype),    # last token (time-mix shift)
        "x_cm": jnp.zeros((B, d), dtype),    # last token (channel-mix shift)
    }


def rwkv6_time_mix_step(p: dict, x_t: jnp.ndarray, cache: dict, *,
                        head_size: int) -> tuple[jnp.ndarray, dict]:
    """x_t: [B, 1, d] single-token decode."""
    B, _, d = x_t.shape
    x_prev = cache["x_tm"][:, None]
    w, r, k, v, g = _rwkv_mix_projections(p, x_t, x_prev, head_size)
    S_T, out = _wkv_chunk(cache["S"], w, r, k, v, p["u"])
    out = out.reshape(B, 1, d)
    out = nn.layernorm(p["ln_x"], out.astype(x_t.dtype))
    y = nn.linear(p["Wo"], out * g)
    cache = dict(cache, S=S_T, x_tm=x_t[:, 0])
    return y, cache


def rwkv6_channel_mix_step(p: dict, x_t: jnp.ndarray, cache: dict) -> tuple[jnp.ndarray, dict]:
    x_prev = cache["x_cm"][:, None]
    dx = x_prev - x_t
    xk = x_t + dx * p["mu_ck"]
    xr = x_t + dx * p["mu_cr"]
    k = jnp.square(jax.nn.relu(nn.linear(p["Wck"], xk)))
    y = jax.nn.sigmoid(nn.linear(p["Wcr"], xr)) * nn.linear(p["Wcv"], k)
    return y, dict(cache, x_cm=x_t[:, 0])
