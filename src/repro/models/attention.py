"""GQA attention: chunked (flash-style) training/prefill path, cached decode.

The chunked path is the pure-JAX twin of kernels/flash_attention (same
online-softmax algorithm, same tiling) — it bounds live memory to one
(q_chunk × kv_chunk) score tile per head instead of the full S×S matrix,
which is what lets 32k prefill compile inside a 16 GB HBM budget.  Causal
chunks above the diagonal are *not computed at all* (the q-chunk loop is
unrolled in Python, inner kv scan runs only over j ≤ i), so compiled HLO
FLOPs stay ≈ the useful S²/2 — this matters for the roofline's
MODEL_FLOPS/HLO_FLOPS ratio."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _attend_chunk(q, k, v, mask, scale):
    """One (q_tile × kv_tile) online-softmax step.

    q: [B, Tq, H, hd]; k/v: [B, Tk, Hkv, hd]; mask: [Tq, Tk] or None.
    Returns unnormalized (o, m, l) contributions in fp32."""
    B, Tq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Tq, Hkv, G, hd)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale        # [B,Tq,Hkv,G,Tk]
    if mask is not None:
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)                                    # [B,Tq,Hkv,G]
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o, m, l


def _merge(acc, new):
    """Merge two online-softmax partials."""
    o1, m1, l1 = acc
    o2, m2, l2 = new
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    return (o1 * a1[..., None] + o2 * a2[..., None],
            m,
            l1 * a1 + l2 * a2)


def flash_attention(
    q: jnp.ndarray,               # [B, S, H, hd]
    k: jnp.ndarray,               # [B, S, Hkv, hd]
    v: jnp.ndarray,               # [B, S, Hkv, hd]
    *,
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    assert S % q_chunk == 0 and S % kv_chunk == 0, (S, q_chunk, kv_chunk)
    nq, nkv = S // q_chunk, S // kv_chunk

    outs = []
    for i in range(nq):                       # unrolled: exact causal FLOPs
        qi = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        q_pos = i * q_chunk + jnp.arange(q_chunk)
        # kv chunks fully below the diagonal (no mask needed)
        hi = ((i + 1) * q_chunk) // kv_chunk if causal else nkv
        full = (i * q_chunk) // kv_chunk if causal else nkv

        def kv_step(carry, j, qi=qi):
            kj = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, axis=1)
            return _merge(carry, _attend_chunk(qi, kj, vj, None, scale)), None

        G = H // Hkv
        init = (
            jnp.zeros((B, q_chunk, Hkv, G, hd), jnp.float32),
            jnp.full((B, q_chunk, Hkv, G), NEG_INF, jnp.float32),
            jnp.zeros((B, q_chunk, Hkv, G), jnp.float32),
        )
        acc, _ = jax.lax.scan(kv_step, init, jnp.arange(full)) if full > 0 \
            else (init, None)
        if causal:
            # diagonal chunks need the triangular mask
            for j in range(full, hi):
                kj = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, axis=1)
                vj = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, axis=1)
                kv_pos = j * kv_chunk + jnp.arange(kv_chunk)
                mask = q_pos[:, None] >= kv_pos[None, :]
                acc = _merge(acc, _attend_chunk(qi, kj, vj, mask, scale))
        o, m, l = acc
        o = o / jnp.maximum(l[..., None], 1e-30)
        outs.append(o.reshape(B, q_chunk, H, hd))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def flash_attention_seqpar(
    q: jnp.ndarray,               # [B, S, H, hd]
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Sequence-parallel attention: q rows sharded over the model axis,
    K/V replicated (ring-attention-style work split, gather done by GSPMD).

    Used when the head count doesn't divide the model axis (yi-34b's 56
    heads, granite's 24): head-dim sharding would turn every score matmul
    into a partial-sum all-reduce of the full score tensor, which measured
    ~100× worse in the dry-run.  Trade-off: no causal chunk skipping
    (every kv chunk is visited), so prefill FLOPs are ~2× the causal
    minimum — still 8× better than replicated compute."""
    from repro.sharding import ctx

    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    kv_chunk = min(kv_chunk, S)
    nkv = S // kv_chunk
    q = ctx.constrain(q, "dp", "tp", None, None)
    q_pos = jnp.arange(S)

    def shard(t):
        return ctx.constrain(t, "dp", "tp", None, None, None)

    def kv_step(carry, j):
        kj = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, axis=1)
        mask = None
        if causal:
            kv_pos = j * kv_chunk + jnp.arange(kv_chunk)
            mask = q_pos[:, None] >= kv_pos[None, :]
        new = _attend_chunk(q, kj, vj, mask, scale)
        o, m, l = _merge(carry, new)
        return (shard(o), ctx.constrain(m, "dp", "tp", None, None),
                ctx.constrain(l, "dp", "tp", None, None)), None

    init = (
        shard(jnp.zeros((B, S, Hkv, G, hd), jnp.float32)),
        ctx.constrain(jnp.full((B, S, Hkv, G), NEG_INF, jnp.float32),
                      "dp", "tp", None, None),
        ctx.constrain(jnp.zeros((B, S, Hkv, G), jnp.float32),
                      "dp", "tp", None, None),
    )
    (o, m, l), _ = jax.lax.scan(kv_step, init, jnp.arange(nkv))
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, S, H, hd).astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,               # [B, 1, H, hd] — one new token
    k_cache: jnp.ndarray,         # [B, Smax, Hkv, hd]
    v_cache: jnp.ndarray,         # [B, Smax, Hkv, hd]
    cache_len: jnp.ndarray,       # scalar int32 — valid prefix length
) -> jnp.ndarray:
    B, _, H, hd = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale   # [B,Hkv,G,Smax]
    pos = jnp.arange(k_cache.shape[1])
    s = jnp.where(pos[None, None, None, :] < cache_len, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def update_kv_cache(k_cache, v_cache, k_new, v_new, cache_len):
    """Insert [B, T, Hkv, hd] new keys/values at position cache_len."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype),
                                                  cache_len, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype),
                                                  cache_len, axis=1)
    return k_cache, v_cache
