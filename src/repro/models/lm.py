"""Unified language-model builder for all assigned architectures.

One parameter layout + three entry points per config:

  init_params(cfg, key)                  -> param pytree (bf16)
  train_loss(cfg)(params, batch)         -> (loss, metrics)     [train_4k]
  serve_step(cfg)(params, cache, tok, t) -> (logits, new_cache) [decode_*]
  encode(cfg)(params, frames)            -> encoder memory      [encdec]

Layers execute as ``lax.scan`` over identical *blocks* (cfg.block_program()),
each block rematerialized, so compiled HLO stays small and backward memory
is O(block boundaries).  Families:

  dense   — GQA transformer (llama3 / qwen1.5 / yi / command-r parallel-block)
  moe     — + routed top-k FFN (granite / qwen2-moe shared+routed)
  ssm     — RWKV6 Finch (attention-free)
  hybrid  — Jamba: 1:7 attn:mamba, MoE every 2nd layer
  encdec  — seamless-m4t backbone (frame-embedding frontend stub)
  vlm     — phi-3-vision backbone (patch-embedding frontend stub)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn as ffn_lib
from repro.models import nn, ssm
from repro.models.config import ModelConfig
from repro.sharding import ctx

Params = dict
Batch = dict


def _dt(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# ===========================================================================
# Parameter construction
# ===========================================================================
def _attn_init(key, cfg: ModelConfig, dtype) -> Params:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": nn.linear_init(ks[0], d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": nn.linear_init(ks[1], d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": nn.linear_init(ks[2], d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": nn.linear_init(ks[3], h * hd, d, dtype=dtype),
    }


def _mixer_init(key, cfg: ModelConfig, mixer: str, dtype) -> Params:
    if mixer == "attn":
        return _attn_init(key, cfg, dtype)
    if mixer == "mamba":
        return ssm.mamba_init(key, cfg.d_model, cfg.mamba_d_inner,
                              cfg.mamba_d_state, cfg.mamba_d_conv, dtype=dtype)
    if mixer == "rwkv":
        return ssm.rwkv6_init(key, cfg.d_model, cfg.d_ff,
                              cfg.rwkv_head_size, dtype=dtype)
    raise ValueError(mixer)


def _ffn_init(key, cfg: ModelConfig, kind: str, dtype) -> Params:
    if kind == "moe":
        return ffn_lib.moe_init(key, cfg.d_model, cfg.d_ff, cfg.num_experts,
                                cfg.num_shared_experts, dtype=dtype)
    return ffn_lib.dense_ffn_init(key, cfg.d_model, cfg.d_ff, dtype=dtype)


def _block_position_init(key, cfg: ModelConfig, mixer: str, fkind: str,
                         dtype, cross: bool) -> Params:
    ks = jax.random.split(key, 5)
    p: Params = {
        "norm1": nn.rmsnorm_init(cfg.d_model, dtype=dtype),
        "mixer": _mixer_init(ks[0], cfg, mixer, dtype),
    }
    # RWKV folds its FFN (channel-mix) into the mixer params; others add one.
    if mixer != "rwkv":
        p["norm2"] = nn.rmsnorm_init(cfg.d_model, dtype=dtype)
        p["ffn"] = _ffn_init(ks[1], cfg, fkind, dtype)
    else:
        p["norm2"] = nn.rmsnorm_init(cfg.d_model, dtype=dtype)
    if cross:
        p["norm_cross"] = nn.rmsnorm_init(cfg.d_model, dtype=dtype)
        p["cross"] = _attn_init(ks[2], cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = _dt(cfg)
    keys = jax.random.split(key, 8)
    program = cfg.block_program()
    cross = cfg.encoder_layers > 0

    def stack_init(k, mixer, fkind, cross_):
        def one(kk):
            return _block_position_init(kk, cfg, mixer, fkind, dtype, cross_)
        return jax.vmap(one)(jax.random.split(k, cfg.num_blocks))

    layers = {}
    for pos, (mixer, fkind) in enumerate(program):
        layers[f"pos{pos}"] = stack_init(
            jax.random.fold_in(keys[0], pos), mixer, fkind, cross)

    params: Params = {
        "embed": nn.embedding_init(keys[1], cfg.vocab_size, cfg.d_model, dtype=dtype),
        "layers": layers,
        "final_norm": nn.rmsnorm_init(cfg.d_model, dtype=dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = nn.linear_init(keys[2], cfg.d_model, cfg.vocab_size,
                                           dtype=dtype)
    if cfg.encoder_layers:
        def enc_one(kk):
            return _block_position_init(kk, cfg, "attn", "dense", dtype, False)
        params["enc_layers"] = jax.vmap(enc_one)(
            jax.random.split(keys[3], cfg.encoder_layers))
        params["enc_final_norm"] = nn.rmsnorm_init(cfg.d_model, dtype=dtype)
    return params


def abstract_params(cfg: ModelConfig):
    """Shapes/dtypes only — used by the dry-run (no allocation)."""
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


# ===========================================================================
# Block forward (training / full-sequence)
# ===========================================================================
def _run_attn(p: Params, x, cfg: ModelConfig, positions, causal=True,
              memory=None):
    """memory: encoder output for cross-attention (keys/values source)."""
    B, S, d = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    src = x if memory is None else memory
    q = nn.linear(p["wq"], x).reshape(B, S, h, hd)
    k = nn.linear(p["wk"], src).reshape(B, src.shape[1], hkv, hd)
    v = nn.linear(p["wv"], src).reshape(B, src.shape[1], hkv, hd)
    tp = max(ctx.axis_size("tp"), 1)
    head_par = cfg.num_heads % tp == 0
    use_seqpar = (not head_par and cfg.seqpar_attention and S % tp == 0
                  and memory is None)
    if head_par:
        q = ctx.constrain(q, "dp", None, "tp", None)
        k = ctx.constrain(k, "dp", None, "tp", None)
        v = ctx.constrain(v, "dp", None, "tp", None)
    elif not use_seqpar:
        # baseline fallback for unsplittable head counts: shard head_dim
        # (partial-sum attention; see flash_attention_seqpar for the fix)
        q = ctx.constrain(q, "dp", None, None, "tp")
        k = ctx.constrain(k, "dp", None, None, "tp")
        v = ctx.constrain(v, "dp", None, None, "tp")
    if memory is None:
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        k = nn.apply_rope(k, positions, cfg.rope_theta)
        if use_seqpar:
            # heads unsplittable (yi 56H, granite 24H): split the q rows
            # over the model axis instead (sequence-parallel attention)
            o = attn.flash_attention_seqpar(q, k, v, causal=causal)
        else:
            o = attn.flash_attention(q, k, v, causal=causal)
    else:
        # cross-attention: no rope, non-causal over memory
        o = attn.flash_attention(q, k, v, causal=False)
    return nn.linear(p["wo"], o.reshape(B, S, h * hd))


def _run_ffn(p: Params, x, cfg: ModelConfig, kind: str):
    if kind == "moe":
        return ffn_lib.moe_ffn(
            p, x, experts_per_token=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor,
            router_aux_coef=cfg.router_aux_coef)
    return ffn_lib.dense_ffn(p, x), jnp.zeros((), jnp.float32)


def _position_forward(cfg: ModelConfig, p: Params, mixer: str, fkind: str,
                      x, positions, memory=None, causal=True):
    """One sub-layer position within a block.  Returns (x, aux_loss)."""
    zero = jnp.zeros((), jnp.float32)
    if mixer == "rwkv":
        x = x + ssm.rwkv6_time_mix(
            p["mixer"], nn.rmsnorm(p["norm1"], x, cfg.norm_eps),
            head_size=cfg.rwkv_head_size)
        x = x + ssm.rwkv6_channel_mix(
            p["mixer"], nn.rmsnorm(p["norm2"], x, cfg.norm_eps))
        return x, zero
    if cfg.parallel_block and mixer == "attn":
        hshared = nn.rmsnorm(p["norm1"], x, cfg.norm_eps)
        a = _run_attn(p["mixer"], hshared, cfg, positions, causal=causal)
        f, aux = _run_ffn(p["ffn"], hshared, cfg, fkind)
        return x + a + f, aux
    h = nn.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        x = x + _run_attn(p["mixer"], h, cfg, positions, causal=causal)
    else:  # mamba
        x = x + ssm.mamba_forward(p["mixer"], h, d_state=cfg.mamba_d_state,
                                  d_conv=cfg.mamba_d_conv,
                                  fused=cfg.mamba_fused_discretization)
    if "cross" in p and memory is not None:
        hc = nn.rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        x = x + _run_attn(p["cross"], hc, cfg, positions, memory=memory)
    f, aux = _run_ffn(p["ffn"], nn.rmsnorm(p["norm2"], x, cfg.norm_eps),
                      cfg, fkind)
    return x + f, aux


def _block_forward(cfg: ModelConfig, block_params: Params, x, positions,
                   memory=None, causal=True):
    """One block (cfg.block_period sub-layers).  Returns (x, aux_loss)."""
    aux_total = jnp.zeros((), jnp.float32)
    for pos, (mixer, fkind) in enumerate(cfg.block_program()):
        x, aux = _position_forward(cfg, block_params[f"pos{pos}"], mixer,
                                   fkind, x, positions, memory, causal)
        aux_total = aux_total + aux
    return x, aux_total


def _scan_blocks(cfg: ModelConfig, layers: Params, x, positions,
                 memory=None, causal=True):
    block_fn = functools.partial(_block_forward, cfg, positions=positions,
                                 memory=memory, causal=causal)

    res_spec = ("dp", "tp", None) if cfg.seq_sharded_residual else \
        ("dp", None, None)

    def body(carry, block_params):
        x, aux = carry
        x = ctx.constrain(x, *res_spec)
        fn = block_fn
        if cfg.remat:
            fn = jax.checkpoint(block_fn,
                                policy=jax.checkpoint_policies.nothing_saveable)
        x, aux_b = fn(block_params, x)
        x = ctx.constrain(x, *res_spec)
        return (x, aux + aux_b), None

    if cfg.scan_blocks:
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), layers)
    else:
        aux = jnp.zeros((), jnp.float32)
        nb = cfg.num_blocks
        for b in range(nb):
            blk = jax.tree.map(lambda a, b=b: a[b], layers)
            (x, aux), _ = body((x, aux), blk)
    return x, aux


# ===========================================================================
# Encoder (enc-dec family)
# ===========================================================================
def encode(cfg: ModelConfig, params: Params, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: [B, S_src, d_model] — precomputed frontend embeddings (stub)."""
    B, S, _ = frames.shape
    positions = jnp.arange(S)[None, :]
    x = frames.astype(_dt(cfg))

    def layer_fwd(block_params, x):
        # encoder layers are single (attn, dense) sub-layers; wrap as a
        # period-1 block for _block_forward
        h = nn.rmsnorm(block_params["norm1"], x, cfg.norm_eps)
        x = x + _run_attn(block_params["mixer"], h, cfg, positions, causal=False)
        f, _ = _run_ffn(block_params["ffn"],
                        nn.rmsnorm(block_params["norm2"], x, cfg.norm_eps),
                        cfg, "dense")
        return x + f

    def body(x, block_params):
        fn = layer_fwd
        if cfg.remat:
            fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
        return fn(block_params, x), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return nn.rmsnorm(params["enc_final_norm"], x, cfg.norm_eps)


# ===========================================================================
# Training loss
# ===========================================================================
def chunked_cross_entropy(x, table_T, targets, mask, chunk: int = 512):
    """Per-token CE against a [d, V] head without materializing [B,S,V].

    x: [B,S,d] final hidden; targets/mask: [B,S].  Scans over sequence
    chunks; each chunk's logits are rematerialized in backward."""
    B, S, d = x.shape
    n = max(S // chunk, 1)
    chunk = S // n

    def chunk_loss(args):
        xc, tc, mc = args
        logits = (xc @ table_T).astype(jnp.float32)
        logits = ctx.constrain(logits, "dp", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return ((lse - gold) * mc).sum(), mc.sum()

    def body(carry, idx):
        tot, cnt = carry
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * chunk, chunk, axis=1)
        l, c = jax.checkpoint(chunk_loss)((sl(x), sl(targets), sl(mask)))
        return (tot + l, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 jnp.arange(n))
    return tot / jnp.maximum(cnt, 1.0)


def _head_table_T(cfg: ModelConfig, params: Params):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


def _embed_inputs(cfg: ModelConfig, params: Params, batch: Batch):
    """Returns (x [B,S,d], targets [B,S], mask [B,S], positions [B,S])."""
    tokens = batch["tokens"]
    x = nn.embed(params["embed"], tokens)
    B, S = tokens.shape
    if cfg.frontend is not None and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(x.dtype)     # [B, P, d]
        x = jnp.concatenate([fe, x], axis=1)
        P = fe.shape[1]
        pad = jnp.zeros((B, P), tokens.dtype)
        targets = jnp.concatenate([pad, batch["targets"]], axis=1)
        mask = jnp.concatenate([jnp.zeros((B, P), jnp.float32),
                                batch.get("mask", jnp.ones((B, S), jnp.float32))],
                               axis=1)
    else:
        targets = batch["targets"]
        mask = batch.get("mask", jnp.ones((B, S), jnp.float32))
    S_tot = x.shape[1]
    positions = jnp.arange(S_tot)[None, :]
    x = ctx.constrain(x, "dp", None, None)
    return x, targets, mask, positions


def train_loss(cfg: ModelConfig):
    """Returns loss_fn(params, batch) -> (loss, metrics)."""

    def loss_fn(params: Params, batch: Batch):
        memory = None
        if cfg.encoder_layers:
            memory = encode(cfg, params, batch["frames"])
        x, targets, mask, positions = _embed_inputs(cfg, params, batch)
        x, aux = _scan_blocks(cfg, params["layers"], x, positions,
                              memory=memory, causal=True)
        if cfg.seq_sharded_residual:
            # gather the (single) final activation for the vocab projection
            x = ctx.constrain(x, "dp", None, None)
        x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        ce = chunked_cross_entropy(x, _head_table_T(cfg, params), targets, mask)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}

    return loss_fn


# ===========================================================================
# Inference prefill: forward-only, emits the KV cache + last-token logits
# ===========================================================================
def prefill_forward(cfg: ModelConfig):
    """Returns fn(params, batch) -> (last_logits [B,V], kv_outputs).

    kv_outputs: per attention position, post-RoPE K/V for the whole prompt
    (stacked [nb, B, S, hkv, hd]) — exactly what init_cache-shaped decode
    consumes.  Recurrent positions (mamba/rwkv) expose their final states.
    Forward-only: no loss, no remat-backward, O(carry) live memory."""

    def fn(params: Params, batch: Batch):
        memory = None
        if cfg.encoder_layers:
            memory = encode(cfg, params, batch["frames"])
        x, _, _, positions = _embed_inputs(cfg, params, batch)
        B, S, _ = x.shape
        h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

        def body(x, block_params):
            ys = {}
            for pos, (mixer, _) in enumerate(cfg.block_program()):
                p = block_params[f"pos{pos}"]
                if mixer == "attn":
                    # tap the post-RoPE K/V of this layer for the cache
                    # output (re-projection: 2 of ~12 layer matmuls)
                    hh = nn.rmsnorm(p["norm1"], x, cfg.norm_eps)
                    k = nn.linear(p["mixer"]["wk"], hh).reshape(B, S, hkv, hd)
                    v = nn.linear(p["mixer"]["wv"], hh).reshape(B, S, hkv, hd)
                    k = nn.apply_rope(k, positions, cfg.rope_theta)
                    ys[f"pos{pos}"] = {"k": k, "v": v}
                # (the tap reads the same normed input position_forward
                # will consume, so K/V match decode exactly)
                x, _ = _position_forward(cfg, p, mixer, cfg.ffn_at(pos),
                                         x, positions, memory)
            return x, ys

        x, kv = jax.lax.scan(body, x, params["layers"])
        x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        last = x[:, -1]
        logits = (last @ _head_table_T(cfg, params)).astype(jnp.float32)
        return logits, kv

    return fn


# ===========================================================================
# Serving: cache init + single-token decode step
# ===========================================================================
def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               enc_len: int = 0) -> dict:
    """Decode-state pytree, stacked over blocks per position."""
    dtype = _dt(cfg)
    nb = cfg.num_blocks
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    cache: dict = {"len": jnp.zeros((), jnp.int32)}
    for pos, (mixer, _) in enumerate(cfg.block_program()):
        if mixer == "attn":
            c = {"k": jnp.zeros((nb, batch, max_seq, hkv, hd), dtype),
                 "v": jnp.zeros((nb, batch, max_seq, hkv, hd), dtype)}
        elif mixer == "mamba":
            c = {"h": jnp.zeros((nb, batch, cfg.mamba_d_inner, cfg.mamba_d_state),
                                jnp.float32),
                 "conv": jnp.zeros((nb, batch, cfg.mamba_d_conv - 1,
                                    cfg.mamba_d_inner), dtype)}
        else:  # rwkv
            H = cfg.rwkv_heads
            c = {"S": jnp.zeros((nb, batch, H, cfg.rwkv_head_size,
                                 cfg.rwkv_head_size), jnp.float32),
                 "x_tm": jnp.zeros((nb, batch, cfg.d_model), dtype),
                 "x_cm": jnp.zeros((nb, batch, cfg.d_model), dtype)}
        if cfg.encoder_layers:
            c["ck"] = jnp.zeros((nb, batch, enc_len, hkv, hd), dtype)
            c["cv"] = jnp.zeros((nb, batch, enc_len, hkv, hd), dtype)
        cache[f"pos{pos}"] = c
    return cache


def _decode_attn(p: Params, x_t, cfg: ModelConfig, kc, vc, t):
    """x_t: [B,1,d]; kc/vc: [B,Smax,hkv,hd]; t: scalar position."""
    B = x_t.shape[0]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pos = jnp.full((B, 1), t, jnp.int32)
    q = nn.apply_rope(nn.linear(p["wq"], x_t).reshape(B, 1, h, hd), pos, cfg.rope_theta)
    k = nn.apply_rope(nn.linear(p["wk"], x_t).reshape(B, 1, hkv, hd), pos, cfg.rope_theta)
    v = nn.linear(p["wv"], x_t).reshape(B, 1, hkv, hd)
    kc, vc = attn.update_kv_cache(kc, vc, k, v, t)
    o = attn.decode_attention(q, kc, vc, t + 1)
    return nn.linear(p["wo"], o.reshape(B, 1, h * hd)), kc, vc


def _decode_cross_attn(p: Params, x_t, cfg: ModelConfig, ck, cv, enc_len):
    B = x_t.shape[0]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = nn.linear(p["wq"], x_t).reshape(B, 1, h, hd)
    o = attn.decode_attention(q, ck, cv, enc_len)
    return nn.linear(p["wo"], o.reshape(B, 1, h * hd))


def serve_step(cfg: ModelConfig):
    """Returns step_fn(params, cache, tokens [B,1]) -> (logits [B,V], cache).

    The enc-dec family reads precomputed cross-attention KV from the cache
    (written by `prefill_encoder`)."""

    def step_fn(params: Params, cache: dict, tokens: jnp.ndarray):
        t = cache["len"]
        x = nn.embed(params["embed"], tokens)          # [B,1,d]
        new_cache: dict = {"len": t + 1}

        def body(x, scan_in):
            block_params, block_cache = scan_in
            ys = {}
            for pos, (mixer, fkind) in enumerate(cfg.block_program()):
                p = block_params[f"pos{pos}"]
                c = block_cache[f"pos{pos}"]
                yc = dict(c)
                if mixer == "rwkv":
                    h = nn.rmsnorm(p["norm1"], x, cfg.norm_eps)
                    y, tm_cache = ssm.rwkv6_time_mix_step(
                        p["mixer"], h, {"S": c["S"], "x_tm": c["x_tm"],
                                        "x_cm": c["x_cm"]},
                        head_size=cfg.rwkv_head_size)
                    x = x + y
                    h2 = nn.rmsnorm(p["norm2"], x, cfg.norm_eps)
                    y2, cm_cache = ssm.rwkv6_channel_mix_step(
                        p["mixer"], h2, tm_cache)
                    x = x + y2
                    yc.update(S=cm_cache["S"], x_tm=cm_cache["x_tm"],
                              x_cm=cm_cache["x_cm"])
                    ys[f"pos{pos}"] = yc
                    continue
                h = nn.rmsnorm(p["norm1"], x, cfg.norm_eps)
                if mixer == "attn":
                    if cfg.parallel_block:
                        a, kc, vc = _decode_attn(p["mixer"], h, cfg, c["k"], c["v"], t)
                        f, _ = _run_ffn(p["ffn"], h, cfg, fkind)
                        x = x + a + f
                        yc.update(k=kc, v=vc)
                        ys[f"pos{pos}"] = yc
                        continue
                    a, kc, vc = _decode_attn(p["mixer"], h, cfg, c["k"], c["v"], t)
                    x = x + a
                    yc.update(k=kc, v=vc)
                else:  # mamba
                    y, mcache = ssm.mamba_step(
                        p["mixer"], h, {"h": c["h"], "conv": c["conv"]},
                        d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv)
                    x = x + y
                    yc.update(h=mcache["h"], conv=mcache["conv"])
                if "cross" in p and "ck" in c:
                    hc = nn.rmsnorm(p["norm_cross"], x, cfg.norm_eps)
                    x = x + _decode_cross_attn(p["cross"], hc, cfg,
                                               c["ck"], c["cv"],
                                               c["ck"].shape[1])
                f, _ = _run_ffn(p["ffn"], nn.rmsnorm(p["norm2"], x, cfg.norm_eps),
                                cfg, fkind)
                x = x + f
                ys[f"pos{pos}"] = yc
            return x, ys

        block_caches = {k: v for k, v in cache.items() if k.startswith("pos")}
        x, new_block_caches = jax.lax.scan(
            body, x, (params["layers"], block_caches))
        new_cache.update(new_block_caches)
        x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = (x[:, 0] @ _head_table_T(cfg, params)).astype(jnp.float32)
        return logits, new_cache

    return step_fn


def prefill_encoder(cfg: ModelConfig, params: Params, cache: dict,
                    frames: jnp.ndarray) -> dict:
    """Run the encoder and write cross-attention KV into the cache."""
    memory = encode(cfg, params, frames)
    B, Se, _ = memory.shape
    hkv, hd = cfg.num_kv_heads, cfg.head_dim

    def per_block(block_params):
        out = {}
        for pos in range(cfg.block_period):
            p = block_params[f"pos{pos}"]
            k = nn.linear(p["cross"]["wk"], memory).reshape(B, Se, hkv, hd)
            v = nn.linear(p["cross"]["wv"], memory).reshape(B, Se, hkv, hd)
            out[f"pos{pos}"] = (k, v)
        return out

    kv = jax.lax.map(per_block, params["layers"])
    for pos in range(cfg.block_period):
        k, v = kv[f"pos{pos}"]
        cache[f"pos{pos}"] = dict(cache[f"pos{pos}"], ck=k, cv=v)
    return cache
