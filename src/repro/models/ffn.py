"""FFN layers: dense SwiGLU and routed top-k MoE (+ shared experts).

The MoE uses the GShard-style capacity discipline but dispatches via
sort-free rank-scatter: tokens are ranked within their expert by a cumsum
over the one-hot routing matrix, then scattered into a per-expert
[E, C, d] buffer, processed with stacked-expert einsums, and combined
back with the router weights.  Per-expert compute is exactly capacity-
bounded — compiled FLOPs stay ~E_active/E_total of the dense-all-experts
formulation, which is what the roofline's useful-FLOPs ratio wants.
Experts shard over the "model" mesh axis when divisible (true EP — the
placement controller in core/placement.py owns that mapping, DESIGN.md
§6); otherwise each expert's d_ff shards (TP fallback)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn


def dense_ffn_init(key, d: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    return nn.swiglu_init(key, d, d_ff, dtype=dtype)


def dense_ffn(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return nn.swiglu(p, x)


def moe_init(key, d: int, d_ff: int, num_experts: int, num_shared: int,
             dtype=jnp.bfloat16) -> dict:
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    p = {
        "router": {"w": (jax.random.normal(k_r, (d, num_experts), jnp.float32)
                         * 0.02).astype(jnp.float32)},   # router stays fp32
        "gate": (jax.random.normal(k_g, (num_experts, d, d_ff), jnp.float32)
                 * scale).astype(dtype),
        "up": (jax.random.normal(k_u, (num_experts, d, d_ff), jnp.float32)
               * scale).astype(dtype),
        "down": (jax.random.normal(k_d, (num_experts, d_ff, d), jnp.float32)
                 * scale).astype(dtype),
    }
    if num_shared:
        p["shared"] = nn.swiglu_init(k_s, d, num_shared * d_ff, dtype=dtype)
    return p


def moe_ffn(
    p: dict,
    x: jnp.ndarray,                  # [B, S, d]
    *,
    experts_per_token: int,
    capacity_factor: float = 1.25,
    router_aux_coef: float = 0.01,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output [B,S,d], aux_loss scalar).

    Dispatch is *per sequence* (vmapped over batch): ranks/capacity are
    computed within each batch row, so with batch sharded over dp no
    cross-data-parallel communication is needed — only the expert (tp)
    axis moves tokens, exactly the EP all-to-all pattern."""
    from repro.sharding import ctx

    B, S, d = x.shape
    E = p["gate"].shape[0]
    K = experts_per_token
    cap = int(capacity_factor * S * K / E) + 1

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"]["w"])                           # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                          # [B,S,K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean((0, 1))                                         # [E]
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0) / (B * S * K)
    aux = router_aux_coef * E * jnp.sum(me * ce)

    def dispatch_one(xs, es):
        """xs: [S,d]; es: [S,K] -> (buf [E,cap+1,d], slot [S*K], keep)."""
        flat_e = es.reshape(-1)                                     # [S*K]
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        rank = jnp.cumsum(onehot, axis=0) - onehot
        my_rank = jnp.take_along_axis(rank, flat_e[:, None], axis=1)[:, 0]
        keep = my_rank < cap
        slot = jnp.where(keep, my_rank, cap)                        # drop bin
        buf = jnp.zeros((E, cap + 1, d), xs.dtype)
        tok_idx = jnp.repeat(jnp.arange(S), K)
        buf = buf.at[flat_e, slot].add(xs[tok_idx])
        return buf, slot, keep, flat_e, tok_idx

    buf, slot, keep, flat_e, tok_idx = jax.vmap(dispatch_one)(x, top_e)
    ep = E % max(ctx.axis_size("tp"), 1) == 0
    if ep:
        # expert parallelism: experts live on the model axis
        buf = ctx.constrain(buf, "dp", "tp", None, None)            # [B,E,C,d]
    else:
        buf = ctx.constrain(buf, "dp", None, None, None)

    # ---- stacked-expert FFN (E is a batch dim -> EP-local when sharded;
    # non-divisible expert counts fall back to TP over each expert's d_ff)
    h = jnp.einsum("becd,edf->becf", buf, p["gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["up"])
    if ep:
        h = ctx.constrain(h, "dp", "tp", None, None)
        u = ctx.constrain(u, "dp", "tp", None, None)
    else:
        h = ctx.constrain(h, "dp", None, None, "tp")
        u = ctx.constrain(u, "dp", None, None, "tp")
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(h) * u, p["down"])
    y = ctx.constrain(y, "dp", "tp" if ep else None, None, None)

    def combine_one(yb, slot, keep, flat_e, tok_idx, wk):
        gathered = yb[flat_e, slot]                                 # [S*K, d]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        out = jnp.zeros((S, d), yb.dtype).at[tok_idx].add(
            gathered * wk.reshape(-1)[:, None].astype(yb.dtype))
        return out

    out = jax.vmap(combine_one)(y, slot, keep, flat_e, tok_idx, top_w)
    out = ctx.constrain(out, "dp", None, None)

    if "shared" in p:
        out = out + nn.swiglu(p["shared"], x)
    return out, aux
