"""Unified model configuration covering all 10 assigned architectures.

One frozen dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM
families.  Layers are organized as a repeating *block program* of period
``block_period`` so ``lax.scan`` can run over identical blocks (Jamba's
1:7 attn:mamba interleave with MoE every other layer becomes one period-8
program; dense models have period 1)."""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
Mixer = Literal["attn", "mamba", "rwkv"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                     # 0 -> d_model // num_heads

    # attention details
    qkv_bias: bool = False
    parallel_block: bool = False          # Cohere/command-r: x+attn(ln)+mlp(ln)
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_every: int = 1                    # MoE FFN on layers l % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # hybrid (jamba): attention on positions p % attn_every == attn_offset
    attn_every: int = 0                   # 0 -> all layers attention
    attn_offset: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # rwkv6
    rwkv_head_size: int = 64

    # enc-dec
    encoder_layers: int = 0               # >0 -> encoder-decoder

    # modality frontend STUB (audio frames / vision patches): input_specs()
    # provides precomputed embeddings of this many positions
    frontend: str | None = None           # None | "frames" | "patches"
    frontend_positions: int = 0

    # execution
    scan_blocks: bool = True
    remat: bool = True
    use_pallas: bool = False              # TPU kernels (tests use interpret)
    # sequence-parallel attention for head counts that don't divide the
    # model axis (§Perf lever; default off = baseline hd-sharding fallback)
    seqpar_attention: bool = False
    # compute the SSM discretization (exp(Δ·A), Δ·B·x) per scan step
    # instead of materializing [B,T,d_inner,d_state] tensors — the Mamba
    # CUDA kernel's fusion, as a §Perf lever (default off = baseline)
    mamba_fused_discretization: bool = False
    # Megatron-style sequence parallelism: the residual stream is sharded
    # over the model axis between blocks, dividing saved-activation memory
    # by tp (§Perf lever for large-model low-microbatch training)
    seq_sharded_residual: bool = False
    dtype: str = "bfloat16"

    # -- derived -------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §7)."""
        return self.family in ("ssm", "hybrid")

    @property
    def block_period(self) -> int:
        periods = [1]
        if self.attn_every:
            periods.append(self.attn_every)
        if self.num_experts:
            periods.append(self.moe_every)
        import math
        p = 1
        for q in periods:
            p = p * q // math.gcd(p, q)
        return p

    @property
    def num_blocks(self) -> int:
        assert self.num_layers % self.block_period == 0, (
            f"{self.name}: num_layers {self.num_layers} not divisible by "
            f"block period {self.block_period}")
        return self.num_layers // self.block_period

    def mixer_at(self, pos: int) -> Mixer:
        """Mixer type for position ``pos`` within a block."""
        if self.family == "ssm":
            return "rwkv"
        if self.attn_every:
            return "attn" if pos % self.attn_every == self.attn_offset else "mamba"
        return "attn"

    def ffn_at(self, pos: int) -> str:
        if self.num_experts and pos % self.moe_every == self.moe_offset:
            return "moe"
        return "dense"

    def block_program(self) -> list[tuple[Mixer, str]]:
        return [(self.mixer_at(p), self.ffn_at(p)) for p in range(self.block_period)]

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    # -- parameter counting (6ND roofline term) -------------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, h, hkv = self.head_dim, self.num_heads, self.num_kv_heads
        attn = d * hd * h + 2 * d * hd * hkv + hd * h * d
        if self.qkv_bias:
            attn += hd * (h + 2 * hkv)
        dense_ffn = 3 * d * f
        moe_k = self.experts_per_token if active_only else self.num_experts
        moe_ffn = moe_k * 3 * d * f + d * self.num_experts  # + router
        moe_ffn += self.num_shared_experts * 3 * d * f
        di, ds = self.mamba_d_inner, self.mamba_d_state
        mamba = d * 2 * di + di * self.mamba_d_conv + \
            di * (2 * ds + max(d // 16, 1)) + max(d // 16, 1) * di + di * d
        # rwkv folds channel-mix into the mixer: 5 tm mats + Wcr + cm pair
        rwkv = 5 * d * d + d * d + 2 * d * f
        total = 0
        for (mix, ffn) in self.block_program():
            if mix == "attn":
                total += attn
            elif mix == "mamba":
                total += mamba
            else:
                total += rwkv
            if mix != "rwkv":   # rwkv's FFN is its channel-mix (counted above)
                total += moe_ffn if ffn == "moe" else dense_ffn
            total += 2 * d  # norms
        total *= self.num_blocks
        if self.encoder_layers:
            enc = self.encoder_layers * (attn + dense_ffn + 2 * d)
            dec_cross = self.num_layers * (attn + d)  # cross-attention
            total += enc + dec_cross
        total += v * d * (1 if self.tie_embeddings else 2)
        return total
