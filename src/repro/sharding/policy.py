"""Logical-axis sharding policy with divisibility fallback (DESIGN.md §5).

Maps every parameter / activation / cache tensor to a PartitionSpec over
the production mesh axes:

  dp  = ("pod", "data")  (or ("data",) single-pod)  — FSDP / batch
  tp  = "model"                                      — TP / EP / SP

Rules are name-based on the param-tree path and *shape-aware*: a dimension
is only sharded if divisible by the mesh-axis size, otherwise the policy
falls back to sharding the other (contraction) dimension — e.g. yi-34b's
56 heads don't split 16 ways, so its attention projections shard d_model
and GSPMD inserts the partial-sum all-reduce; granite's 40 experts aren't
16-divisible so experts stay local and each expert FFN tensor-parallelizes
over d_ff."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.treepath import keystr_path


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp: tuple[str, ...]          # data/FSDP axes, e.g. ("pod", "data")
    tp: str = "model"

    @classmethod
    def from_mesh(cls, mesh: Mesh) -> "MeshAxes":
        names = mesh.axis_names
        return cls(dp=tuple(n for n in names if n != "model"), tp="model")


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


class ShardingPolicy:
    def __init__(self, mesh: Mesh, cfg: ModelConfig, fsdp: bool = True):
        """fsdp=False replicates parameters across the data axes (pure DP +
        TP): no per-layer weight all-gathers, grads all-reduce once — the
        right trade below ~30B params where weights fit replicated (a §Perf
        hillclimb lever)."""
        self.mesh = mesh
        self.cfg = cfg
        self.fsdp = fsdp
        self.axes = MeshAxes.from_mesh(mesh)
        self.dp_size = _axis_size(mesh, self.axes.dp)
        self.tp_size = _axis_size(mesh, self.axes.tp)

    # -- helpers -------------------------------------------------------------
    def _fits(self, dim: int, axes) -> bool:
        if axes == self.axes.dp and not self.fsdp:
            return False          # parameters never shard over dp
        return dim % _axis_size(self.mesh, axes) == 0

    def _mm(self, shape, out_dim: int, in_dim: int) -> P:
        """Matmul weight [*, in, out]: prefer (in->dp, out->tp); fall back to
        (in->tp, out->dp); else replicate what doesn't fit."""
        dp, tp = self.axes.dp, self.axes.tp
        lead = (None,) * (len(shape) - 2)
        din, dout = shape[in_dim], shape[out_dim]
        if self._fits(dout, tp) and self._fits(din, dp):
            return P(*lead, dp, tp)
        if self._fits(dout, dp) and self._fits(din, tp):
            return P(*lead, tp, dp)
        if self._fits(dout, tp):
            return P(*lead, None, tp)
        if self._fits(din, tp):
            return P(*lead, tp, None)
        if self._fits(dout, dp):
            return P(*lead, None, dp)
        return P(*lead, None, None)

    def _mm_T(self, shape) -> P:
        """Weight [*, in, out] where in = the 'wide' model dim (down/out
        projections): prefer (in->tp, out->dp)."""
        dp, tp = self.axes.dp, self.axes.tp
        lead = (None,) * (len(shape) - 2)
        din, dout = shape[-2], shape[-1]
        if self._fits(din, tp) and self._fits(dout, dp):
            return P(*lead, tp, dp)
        if self._fits(din, dp) and self._fits(dout, tp):
            return P(*lead, dp, tp)
        if self._fits(din, tp):
            return P(*lead, tp, None)
        if self._fits(dout, tp):
            return P(*lead, None, tp)
        return P(*lead, None, None)

    def _vec(self, shape) -> P:
        lead = (None,) * (len(shape) - 1)
        if self._fits(shape[-1], self.axes.tp):
            return P(*lead, self.axes.tp)
        return P(*lead, None)

    # -- parameters ------------------------------------------------------------
    def param_spec(self, path: str, shape: tuple[int, ...]) -> P:
        dp, tp = self.axes.dp, self.axes.tp
        lead = (None,) * max(len(shape) - 2, 0)

        if "embed/table" in path:
            # [V, d]: vocab->tp when divisible (sharded logits); replicating
            # otherwise is cheaper than d-sharding (the token gather's
            # jvp/transpose trips the SPMD partitioner on d-sharded tables)
            if self._fits(shape[0], tp) and self._fits(shape[1], dp):
                return P(tp, dp)
            if self._fits(shape[0], tp):
                return P(tp, None)
            return P(None, None)
        if "lm_head" in path:
            return self._mm(shape, out_dim=-1, in_dim=-2)
        if "gnn/" in path:
            # graph-policy message-passing layers (core/graph_policy.py):
            # matrices tensor-parallelize over the model axis — the first
            # agent family where that axis is non-degenerate (the fleet's
            # data axes carry lanes, so pass fsdp=False)
            if len(shape) >= 2:
                return self._mm(shape, out_dim=-1, in_dim=-2)
            return self._vec(shape)
        if path.endswith("/b"):
            return self._vec(shape)
        if "norm" in path or "ln_x" in path:
            return P(*((None,) * len(shape)))
        if "router" in path:
            return P(*((None,) * len(shape)))

        # MoE stacked experts [..., E, in, out] (leading scan-block dim)
        if (any(k in path for k in ("ffn/gate", "ffn/up", "ffn/down"))
                and "shared" not in path and len(shape) >= 3):
            lead3 = (None,) * (len(shape) - 3)
            E = shape[-3]
            if self._fits(E, tp):
                # expert parallelism: experts over tp, d_ff over dp
                wide = -2 if "down" in path else -1   # the d_ff dimension
                spec = [None, None, None]
                spec[0] = tp
                if self._fits(shape[wide], dp):
                    spec[wide] = dp
                return P(*lead3, *spec)
            # TP fallback inside each expert
            if "down" in path:
                return P(*lead3, None, *self._mm_T(shape[-2:]))
            return P(*lead3, None, *self._mm(shape[-2:], out_dim=-1, in_dim=-2))

        if any(k in path for k in ("/gate/w", "/up/w", "wq/w", "wk/w", "wv/w",
                                   "in_proj/w", "Wr/w", "Wk/w", "Wv/w", "Wg/w",
                                   "Wck/w", "Wcr/w", "x_proj/w", "dt_proj/w",
                                   "w_lora1/w", "cross")):
            if "cross" in path and ("wo/w" in path):
                return self._mm_T(shape)
            return self._mm(shape, out_dim=-1, in_dim=-2)
        if any(k in path for k in ("/down/w", "wo/w", "out_proj/w", "Wo/w",
                                   "Wcv/w", "w_lora2/w")):
            return self._mm_T(shape)
        if "conv_w" in path:
            return P(*lead, None, tp) if self._fits(shape[-1], tp) else \
                P(*((None,) * len(shape)))
        if "A_log" in path or path.endswith("/D"):
            if self._fits(shape[-2] if len(shape) >= 2 else shape[-1], tp):
                return P(*((None,) * (len(shape) - 2)), tp, None) \
                    if len(shape) >= 2 else P(tp)
            return P(*((None,) * len(shape)))
        if path.endswith("/u") or "/mu" in path or "w_base" in path:
            return P(*((None,) * len(shape)))
        # default: replicate
        return P(*((None,) * len(shape)))

    def params_tree(self, abstract_params) -> Any:
        def spec_for(path, leaf):
            pstr = keystr_path(path, separator="/")
            return self.param_spec(pstr, leaf.shape)
        return jax.tree_util.tree_map_with_path(spec_for, abstract_params)

    def params_sharding(self, abstract_params) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.params_tree(abstract_params))

    # -- batch / activations ----------------------------------------------------
    def batch_spec(self, batch_size: int) -> P:
        if batch_size % self.dp_size == 0:
            return P(self.axes.dp)
        return P(None)

    def batch_sharding(self, abstract_batch) -> Any:
        def spec(path, leaf):
            b = leaf.shape[0]
            base = self.batch_spec(b)
            return NamedSharding(self.mesh,
                                 P(*base, *([None] * (len(leaf.shape) - 1))))
        return jax.tree_util.tree_map_with_path(spec, abstract_batch)

    # -- decode cache -------------------------------------------------------------
    def cache_spec(self, path: str, shape: tuple[int, ...]) -> P:
        """Cache leaves are stacked [nb, B, ...]."""
        dp, tp = self.axes.dp, self.axes.tp
        if path.endswith("len") or len(shape) < 2:
            return P(*([None] * len(shape)))
        batch_ax = dp if shape[1] % self.dp_size == 0 else None
        if any(k in path for k in ("/k", "/v", "/ck", "/cv")):
            nb, B, S, hkv, hd = shape
            if hkv % self.tp_size == 0:
                return P(None, batch_ax, None, tp, None)
            if S % self.tp_size == 0:
                # sequence-sharded cache (flash-decoding style partial softmax)
                return P(None, batch_ax, tp, None, None)
            return P(None, batch_ax, None, None, None)
        if path.endswith("/h"):       # mamba state [nb,B,di,ds]
            return P(None, batch_ax, tp if shape[2] % self.tp_size == 0 else None, None)
        if path.endswith("/conv"):    # [nb,B,dc-1,di]
            return P(None, batch_ax, None, tp if shape[3] % self.tp_size == 0 else None)
        if path.endswith("/S"):       # rwkv state [nb,B,H,hd,hd]
            return P(None, batch_ax, tp if shape[2] % self.tp_size == 0 else None,
                     None, None)
        if "x_tm" in path or "x_cm" in path:
            return P(None, batch_ax, None)
        return P(*([None] * len(shape)))

    def cache_sharding(self, abstract_cache) -> Any:
        def spec(path, leaf):
            pstr = keystr_path(path, separator="/")
            return NamedSharding(self.mesh, self.cache_spec(pstr, leaf.shape))
        return jax.tree_util.tree_map_with_path(spec, abstract_cache)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())
