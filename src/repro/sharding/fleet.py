"""Fleet-axis sharding: partition scenario-fleet carries over a mesh.

The fleet runner (``core/agent.run_online_fleet``) vmaps one online run
over a leading ``[fleet]`` axis; everything here is about placing that
axis over hardware.  A mesh's *data* axes (every axis except ``"model"``,
matching :class:`repro.sharding.policy.MeshAxes`) carry the fleet: lane
arrays — stacked PRNG keys, agent states, env states, and the stacked
leaves of a scenario ``EnvParams`` fleet — shard their leading axis over
those devices, while broadcast-invariant params leaves (kept single-copy
by ``stack_env_params(..., broadcast_invariant=True)``) replicate.

Two entry points:

* :func:`fleet_shardings` — a matching pytree of ``NamedSharding`` for
  any fleet-stacked carry tree (used by elastic checkpoint restore to
  re-place loaded lanes against the *current* mesh);
* :func:`shard_fleet` — ``device_put`` the runner's four input trees onto
  the mesh and return the hashable params PartitionSpec tree the sharded
  program needs.

Meshes may SPAN processes (``launch.mesh.make_fleet_mesh(spanning=True)``
under ``jax.distributed`` — the multi-host mega-fleet axis):
:func:`put_global` then assembles global arrays from each process's
addressable shards instead of ``device_put``, and :func:`fleet_host`
brings fleet arrays home with a cross-process allgather so every process
sees identical full traces (docs/sharded_fleets.md#multi-host-fleets).

On :func:`repro.launch.mesh.make_host_mesh` (one CPU device) every spec
degenerates to a single shard, so the sharded code path stays
bit-comparable to the plain vmap path — that is what the CPU equivalence
tests pin."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def is_spanning(mesh: Mesh) -> bool:
    """True when ``mesh`` spans devices of more than one process — the
    multi-host fleet case (``launch.mesh.make_fleet_mesh(spanning=True)``
    under ``jax.distributed``).  Spanning meshes change how arrays are
    placed (each process feeds only its addressable shard:
    :func:`put_global`) and how results come home
    (:func:`fleet_host`)."""
    return any(d.process_index != jax.process_index()
               for d in mesh.devices.flat)


def put_global(x, sharding: NamedSharding):
    """Place a host (or process-local) value onto ``sharding``.

    For fully-addressable shardings this is plain ``jax.device_put``.
    For process-spanning shardings ``device_put`` of a host array is
    illegal, so the global array is assembled with
    ``jax.make_array_from_callback``: every process holds the SAME full
    host value (fleet carries are built deterministically from shared
    seeds, or read back from a checkpoint every process can see) and
    contributes only the slices its own devices own."""
    if sharding.is_fully_addressable:
        return jax.device_put(x, sharding)
    host = np.asarray(x)
    return jax.make_array_from_callback(host.shape, sharding,
                                        lambda idx: host[idx])


def fleet_host(x) -> np.ndarray:
    """Full host value of a fleet array on EVERY process.

    ``np.asarray`` for ordinary (fully-addressable) arrays; for arrays
    sharded over a process-spanning mesh the fleet-axis shards are
    re-assembled with a cross-process allgather
    (``multihost_utils.process_allgather``), and fully-replicated
    spanning arrays just read their local copy.  Deterministic and
    identical across processes — which is what lets every process run
    the same host-side trace accounting / elastic lane bookkeeping
    without diverging."""
    if isinstance(x, jax.Array) and not x.is_fully_addressable:
        if x.sharding.is_fully_replicated:
            return np.asarray(x.addressable_shards[0].data)
        from jax.experimental import multihost_utils
        return np.asarray(multihost_utils.process_allgather(x, tiled=True))
    return np.asarray(x)


def fleet_host_tree(tree):
    """:func:`fleet_host` over every leaf of a pytree."""
    return jax.tree.map(fleet_host, tree)


def fleet_axes(mesh: Mesh) -> tuple[str, ...]:
    """The mesh axes that carry the fleet: every axis except ``"model"``
    (the same data/FSDP grouping as ``sharding.policy.MeshAxes``)."""
    return tuple(n for n in mesh.axis_names if n != "model")


def fleet_size(mesh: Mesh) -> int:
    """Number of devices the fleet axis is partitioned over."""
    return int(np.prod([mesh.shape[a] for a in fleet_axes(mesh)]))


def fleet_spec(mesh: Mesh) -> P:
    """PartitionSpec sharding an array's leading (fleet) axis over the
    mesh's data axes; trailing dims stay unsharded."""
    return P(fleet_axes(mesh))


def fleet_shardings(mesh: Mesh, tree):
    """Matching pytree of ``NamedSharding`` placing every leaf's leading
    ``[fleet]`` axis over the mesh's data axes.

    Leaves that cannot shard — scalars, or a leading dim not divisible by
    the data-axis size — fall back to replication instead of erroring, so
    a checkpoint written for fleet=8 restores on a 3-device mesh (lanes
    replicated) rather than crashing: the elastic-restore contract."""
    axes = fleet_axes(mesh)
    n = fleet_size(mesh)

    def leaf_sharding(x):
        shape = np.shape(x)
        if len(shape) >= 1 and n > 0 and shape[0] % n == 0:
            return NamedSharding(mesh, P(axes))
        return NamedSharding(mesh, P())

    return jax.tree.map(leaf_sharding, tree)


def params_partition_specs(params, ref, mesh: Mesh):
    """Per-leaf PartitionSpec tree for a (possibly broadcast-invariant)
    stacked params fleet: stacked leaves shard their leading ``[F]`` axis
    over the mesh's data axes, broadcast-invariant leaves replicate.  A
    single-scenario ``params`` (nothing stacked vs ``ref``) replicates
    everywhere.  The result has the params' own container structure
    (a NamedTuple of PartitionSpecs → hashable → valid jit static arg)."""
    axes = fleet_axes(mesh)
    flat, treedef = jax.tree_util.tree_flatten(params)
    ref_flat = jax.tree_util.tree_leaves(ref)
    if len(flat) != len(ref_flat):
        raise ValueError("params and reference pytrees differ in structure")
    specs = [P(axes) if np.ndim(p) == np.ndim(r) + 1 else P()
             for p, r in zip(flat, ref_flat)]
    return jax.tree_util.tree_unflatten(treedef, specs)


def compaction_size(n_live: int, mesh: Mesh | None) -> int:
    """Smallest lane count ≥ ``n_live`` a compacted fleet may shrink to.

    ``shard_map`` partitions the fleet axis evenly, so on a mesh the
    elastic lane lifecycle (repro/fleet/lifecycle.py) can only compact to
    multiples of the data-axis device count — the gap is padded with
    already-stopped "passenger" lanes whose extra epochs are discarded.
    Without a mesh (plain vmap) any size works and this is ``n_live``."""
    if mesh is None:
        return int(n_live)
    n = fleet_size(mesh)
    return int(-(-int(n_live) // n) * n)          # ceil to a multiple of n


def shard_fleet(mesh: Mesh, keys, states, env_states, env_params, ref):
    """Place the fleet runner's carries on ``mesh``.

    ``keys``/``states``/``env_states`` shard their leading fleet axis over
    the mesh's data axes; ``env_params`` shards only its stacked leaves
    (``ref`` — the env's single-scenario ``default_params()`` — tells the
    two apart), replicating broadcast-invariant ones.  The fleet size must
    divide the data-axis device count (``shard_map`` partitions evenly).

    Returns ``(keys, states, env_states, env_params, params_specs)`` with
    every array committed to its ``NamedSharding`` and ``params_specs``
    the hashable PartitionSpec tree for the sharded program."""
    n = fleet_size(mesh)
    F = int(np.shape(keys)[0])
    if F % n != 0:
        raise ValueError(
            f"fleet size {F} does not divide over the mesh's {n} data-axis "
            f"devices; pick a fleet that is a multiple of {n} (or run the "
            f"un-sharded vmap path with mesh=None)")
    spec = fleet_spec(mesh)
    shard = NamedSharding(mesh, spec)
    put = lambda tree: jax.tree.map(lambda x: put_global(x, shard), tree)
    keys = put_global(keys, shard)
    states = put(states)
    env_states = put(env_states)
    params_specs = params_partition_specs(env_params, ref, mesh)
    env_params = jax.tree.map(
        lambda x, s: put_global(x, NamedSharding(mesh, s)),
        env_params, params_specs)
    return keys, states, env_states, env_params, params_specs
