"""Activation-sharding context: mesh-aware with_sharding_constraint hooks.

Model code calls ``constrain(x, "dp", None, "tp")`` at key activation
boundaries; when a mesh context is active (set by the dry-run / trainer)
this lowers to ``with_sharding_constraint`` with divisibility-checked
axes, and when no context is set (CPU smoke tests) it is a no-op.  This
keeps GSPMD's propagation on the intended Megatron-style layout instead
of letting it invent per-d_model shardings (which caused involuntary
full-rematerialization resharding in early dry-runs)."""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE: dict = {"mesh": None, "dp": (), "tp": "model"}


def set_mesh(mesh: Optional[Mesh]) -> None:
    if mesh is None:
        _STATE.update(mesh=None, dp=())
        return
    names = mesh.axis_names
    _STATE.update(mesh=mesh,
                  dp=tuple(n for n in names if n != "model"),
                  tp="model" if "model" in names else None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = _STATE["mesh"]
    set_mesh(mesh)
    try:
        yield
    finally:
        set_mesh(prev)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def axis_size(which: str) -> int:
    """Size of the 'dp'/'tp' axis group under the active mesh (1 if none)."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return 1
    axes = _STATE["dp"] if which == "dp" else _STATE["tp"]
    if not axes:
        return 1
    return _axis_size(mesh, axes)


def divides(dim: int, which: str) -> bool:
    return dim % axis_size(which) == 0


def constrain(x: jax.Array, *axes) -> jax.Array:
    """axes: per-dim "dp" | "tp" | None.  Non-divisible dims are left
    unsharded rather than erroring."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    spec = []
    for dim, a in zip(x.shape, axes):
        if a is None:
            spec.append(None)
            continue
        mesh_axes = _STATE["dp"] if a == "dp" else _STATE["tp"]
        if mesh_axes and dim % _axis_size(mesh, mesh_axes) == 0:
            spec.append(mesh_axes)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
