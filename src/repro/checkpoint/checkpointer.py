"""Sharded, async, integrity-checked checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
           manifest.json       tree structure, shapes, dtypes, crc32 per leaf
           <leaf-id>.npy       one file per pytree leaf

Design points for 1000+-node operation (DESIGN.md §5):
  * save is ASYNC and the device→host transfer is OVERLAPPED: the caller
    thread only dispatches a donation-safe on-device snapshot + async D2H
    copy per leaf; a background thread completes the transfer and writes
    — training blocks on neither the interconnect nor the filesystem
    (double-buffered: at most two snapshots in flight, see
    AsyncCheckpointer);
  * writes are ATOMIC: a step directory is staged as .tmp and renamed only
    after every leaf + manifest hit disk, so a mid-write failure never
    corrupts the latest checkpoint;
  * restore is ELASTIC: leaves are loaded as full arrays and re-placed
    with ``jax.device_put`` against the *current* mesh's shardings — a job
    restarted on a different device count resumes from the same file set;
  * integrity: per-leaf crc32 is verified on load (bit-rot / truncation).
"""
from __future__ import annotations

import json
import pathlib
import queue
import shutil
import threading
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.treepath import keystr_path


def _flatten(state):
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


def _leaf_paths(state):
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    return [keystr_path(kp, separator=".") for kp, _ in flat]


class Checkpointer:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state) -> pathlib.Path:
        leaves, _ = _flatten(state)
        names = _leaf_paths(state)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        return self._write(step, names, host)

    def _write(self, step: int, names, host_leaves) -> pathlib.Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": []}
        for i, (name, arr) in enumerate(zip(names, host_leaves)):
            fn = f"leaf_{i:05d}.npy"
            logical_dtype = str(arr.dtype)
            to_write = arr
            if logical_dtype == "bfloat16":
                # numpy can't serialize ml_dtypes natively: store raw bits
                to_write = arr.view(np.uint16)
            np.save(tmp / fn, to_write, allow_pickle=False)
            manifest["leaves"].append({
                "name": name, "file": fn,
                "shape": list(arr.shape), "dtype": logical_dtype,
                "crc32": zlib.crc32(np.ascontiguousarray(to_write).tobytes()),
            })
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                      # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*"))

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: int | None = None,
                shardings=None, verify: bool = True):
        """state_like: pytree with the target structure (abstract ok).
        shardings: optional matching pytree of NamedSharding for elastic
        re-placement on the current mesh."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        _, treedef = _flatten(state_like)
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else None)
        out = []
        for i, ent in enumerate(manifest["leaves"]):
            arr = np.load(d / ent["file"], allow_pickle=False)
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != ent["crc32"]:
                    raise IOError(
                        f"checkpoint corruption in {ent['name']}: "
                        f"crc {crc} != {ent['crc32']}")
            if ent["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if shard_leaves is not None:
                # put_global handles process-spanning shardings (each
                # process feeds its addressable slice); it degenerates to
                # device_put on ordinary meshes
                from repro.sharding.fleet import put_global
                arr = put_global(arr, shard_leaves[i])
            out.append(arr)
        return jax.tree.unflatten(treedef, out)


class AsyncCheckpointer(Checkpointer):
    """save_async(): snapshot now, write in the background.

    With ``overlap_transfer=True`` (the default) the device→host transfer
    itself moves off the caller thread: ``save_async`` dispatches an
    on-device SNAPSHOT copy per jax leaf (eager ``jnp.copy`` — enqueued
    on the device stream before any later computation, and never itself
    donated, so a donating caller like the sharded fleet runner's
    in-place chunk scan cannot invalidate it), starts its async D2H copy,
    and hands the snapshot references to the background worker, which
    blocks on the transfer there and then serializes.  The caller —
    typically a chunked training loop — dispatches its next chunk
    immediately, so accelerator meshes keep scanning while the previous
    chunk's snapshot drains over PCIe/ICI and hits disk.

    The queue is DOUBLE-BUFFERED (``max_inflight=1``): one snapshot being
    written plus one queued; a third ``save_async`` blocks until the
    oldest write completes, bounding host memory at ~2 snapshots no matter
    how fast chunks finish.  ``overlap_transfer=False`` restores the old
    synchronous-transfer behavior (host copies taken on the caller thread
    before ``save_async`` returns — needed if the caller mutates buffers
    in place outside jax's view)."""

    def __init__(self, directory, keep: int = 3,
                 overlap_transfer: bool = True, max_inflight: int = 1):
        super().__init__(directory, keep)
        self.overlap_transfer = overlap_transfer
        self._q: queue.Queue = queue.Queue(maxsize=max(int(max_inflight), 1))
        self._err: list[BaseException] = []
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, names, leaves = item
            try:
                # completes the D2H transfer when leaves are device arrays
                # (overlap path); no-op copies when already host snapshots
                host = [np.asarray(jax.device_get(l)) for l in leaves]
                self._write(step, names, host)
            except BaseException as e:  # surfaced on wait()
                self._err.append(e)
            finally:
                self._q.task_done()

    def save_async(self, step: int, state) -> None:
        leaves, _ = _flatten(state)
        names = _leaf_paths(state)
        if self.overlap_transfer:
            payload = []
            for leaf in leaves:
                if isinstance(leaf, jax.Array):
                    # device-side snapshot: ordered after the producing
                    # computation, independent of the original buffer (a
                    # later donating dispatch deletes the ORIGINAL, not
                    # this copy), then start its D2H transfer
                    leaf = jnp.copy(leaf)
                    leaf.copy_to_host_async()    # enqueue DMA, don't block
                payload.append(leaf)
        else:
            payload = [np.asarray(jax.device_get(l)) for l in leaves]
        self._q.put((step, names, payload))      # blocks when 2 in flight

    def wait(self) -> None:
        self._q.join()
        if self._err:
            raise self._err.pop()

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._worker.join()
