"""Fleet-training checkpoints: periodic, async, atomic, elastic.

:class:`FleetCheckpoint` wraps the generic async atomic
:class:`~repro.checkpoint.checkpointer.Checkpointer` around the fleet
runner's carries — per-lane agent states, env states, and PRNG keys —
tagged by absolute decision epoch.  ``core.agent.run_online_fleet(...,
checkpoint=ck)`` chunks its epoch scan every ``ck.every`` epochs and calls
:meth:`FleetCheckpoint.save` after each chunk: arrays are snapshotted to
host synchronously (cheap) and written by a background thread, and a step
directory only renames into place once every leaf + manifest hit disk, so
a kill mid-write never corrupts the newest restorable state.

Restore is ELASTIC: :meth:`restore` loads the lane arrays as full host
arrays and — given a mesh — re-places them with the *current* mesh's
fleet shardings (``sharding.fleet.fleet_shardings``), so a run
checkpointed on an 8-device mesh resumes on 4 devices (or on the host
mesh) from the same file set.  The resume walkthrough lives in
docs/sharded_fleets.md; the bit-exactness contract is pinned by
tests/test_fleet_checkpoint.py."""
from __future__ import annotations

import pathlib

from repro.checkpoint.checkpointer import AsyncCheckpointer, Checkpointer


class FleetCheckpoint:
    """Checkpoint policy + storage for ``run_online_fleet`` carries.

    ``every`` — checkpoint cadence in decision epochs (the runner chunks
    its scan on this boundary); ``keep`` — retained checkpoints (older
    step directories are garbage-collected); ``use_async=False`` swaps
    the background writer for synchronous writes (tests, final flush)."""

    def __init__(self, directory: str | pathlib.Path, every: int = 50,
                 keep: int = 3, use_async: bool = True):
        if every < 1:
            raise ValueError(f"checkpoint cadence must be >= 1, got {every}")
        self.every = int(every)
        self._ck = (AsyncCheckpointer(directory, keep=keep) if use_async
                    else Checkpointer(directory, keep=keep))

    @property
    def directory(self) -> pathlib.Path:
        return self._ck.dir

    @staticmethod
    def _bundle(agent_states, env_states, keys) -> dict:
        return {"agent": agent_states, "env": env_states, "keys": keys}

    # -- save ----------------------------------------------------------------
    def save(self, epoch: int, agent_states, env_states, keys) -> None:
        """Snapshot the fleet carries at absolute ``epoch`` (async when
        constructed with ``use_async=True`` — training never blocks on the
        filesystem; the write publishes atomically)."""
        bundle = self._bundle(agent_states, env_states, keys)
        if isinstance(self._ck, AsyncCheckpointer):
            self._ck.save_async(epoch, bundle)
        else:
            self._ck.save(epoch, bundle)

    def wait(self) -> None:
        """Block until queued async writes are on disk (raises the first
        background write error, if any)."""
        if isinstance(self._ck, AsyncCheckpointer):
            self._ck.wait()

    def close(self) -> None:
        if isinstance(self._ck, AsyncCheckpointer):
            self._ck.close()

    # -- restore -------------------------------------------------------------
    def all_epochs(self) -> list[int]:
        return self._ck.all_steps()

    def latest_epoch(self) -> int | None:
        """Newest restorable epoch, or None when the directory is empty."""
        return self._ck.latest_step()

    def restore(self, agent_states, env_states, keys, epoch: int | None = None,
                mesh=None):
        """Load the carries saved at ``epoch`` (default: latest).

        ``agent_states`` / ``env_states`` / ``keys`` supply the target tree
        STRUCTURE (values are ignored — pass freshly-initialized carries).
        With ``mesh``, every lane array is re-placed against the current
        mesh's fleet shardings (leading axis over the data axes,
        replication fallback when the fleet no longer divides the device
        count) — the elastic path that lets a run resume after the device
        count changed.  Returns ``(epoch, agent_states, env_states,
        keys)``."""
        self.wait()                       # flush our own pending writes
        epoch = self.latest_epoch() if epoch is None else epoch
        if epoch is None:
            raise FileNotFoundError(f"no fleet checkpoints in {self.directory}")
        like = self._bundle(agent_states, env_states, keys)
        shardings = None
        if mesh is not None:
            from repro.sharding.fleet import fleet_shardings
            shardings = fleet_shardings(mesh, like)
        out = self._ck.restore(like, step=epoch, shardings=shardings)
        return epoch, out["agent"], out["env"], out["keys"]
