"""Fleet-training checkpoints: periodic, async, atomic, elastic.

:class:`FleetCheckpoint` wraps the generic async atomic
:class:`~repro.checkpoint.checkpointer.Checkpointer` around the fleet
runner's carries — per-lane agent states, env states, and PRNG keys —
tagged by absolute decision epoch.  ``core.agent.run_online_fleet(...,
checkpoint=ck)`` chunks its epoch scan every ``ck.every`` epochs and calls
:meth:`FleetCheckpoint.save` after each chunk: the device→host transfer is
OVERLAPPED (the caller only dispatches a donation-safe on-device snapshot
plus an async D2H copy per leaf; the background writer completes the
transfer, double-buffered at two snapshots in flight), so accelerator
meshes keep scanning the next chunk — which donates the live carries —
while the previous one serializes.  A step directory only renames into place once
every leaf + manifest hit disk, so a kill mid-write never corrupts the
newest restorable state.

Elastic-lifecycle runs (repro/fleet/lifecycle.py) COMPACT their fleet as
lanes converge, so consecutive snapshots can hold different lane counts;
``save(..., lane_map=...)`` records which ORIGINAL lanes the surviving
rows are, and ``restore(..., with_lane_map=True)`` recovers that map
alongside the carries (see docs/elastic_fleets.md for the elastic-restore
story).

Restore is ELASTIC: :meth:`restore` loads the lane arrays as full host
arrays and — given a mesh — re-places them with the *current* mesh's
fleet shardings (``sharding.fleet.fleet_shardings``), so a run
checkpointed on an 8-device mesh resumes on 4 devices (or on the host
mesh) from the same file set.  The resume walkthrough lives in
docs/sharded_fleets.md; the bit-exactness contract is pinned by
tests/test_fleet_checkpoint.py.

MULTI-HOST runs (``jax.distributed`` + a process-spanning mesh,
``launch.mesh.init_distributed``) switch to a per-process shard layout:
every process writes ONLY the fleet rows its devices own into its own
``step_N/proc_P/`` directory (atomic tmp-rename per process, manifest
with global row offsets per shard), replicated / host leaves are written
once by process 0, and the step is published by process 0 writing
``meta.json`` after a cross-process barrier — an incomplete step (a
process died mid-save) is never visible to ``latest_epoch``.  Restore is
elastic across HOST-count changes: the reader re-assembles full arrays
from however many ``proc_*`` shard dirs the save had, then re-places
them against the CURRENT mesh — so a fleet checkpointed by 2 processes
resumes on 1 (and a single-process checkpoint resumes on a spanning
mesh).  Multi-host saves are synchronous (the cross-process barrier is
the cadence governor); the async overlap machinery stays single-process."""
from __future__ import annotations

import json
import pathlib
import shutil
import zlib

import jax
import numpy as np

from repro.checkpoint.checkpointer import (AsyncCheckpointer, Checkpointer,
                                           _leaf_paths)


def _write_leaf(directory: pathlib.Path, index: int, name: str,
                arr: np.ndarray, rows: list[int] | None = None,
                global_shape: list[int] | None = None) -> dict:
    """Write one (shard of a) leaf with the Checkpointer's conventions
    (npy file, crc32, bfloat16 stored as raw uint16 bits); returns its
    manifest entry.  ``rows=[start, stop)`` tags a fleet-axis shard with
    the global rows it covers; ``rows=None`` is a whole leaf."""
    fn = f"leaf_{index:05d}.npy"
    logical_dtype = str(arr.dtype)
    to_write = arr.view(np.uint16) if logical_dtype == "bfloat16" else arr
    np.save(directory / fn, to_write, allow_pickle=False)
    entry = {"name": name, "file": fn, "shape": list(arr.shape),
             "dtype": logical_dtype,
             "crc32": zlib.crc32(np.ascontiguousarray(to_write).tobytes())}
    if rows is not None:
        entry["rows"] = [int(rows[0]), int(rows[1])]
        entry["global_shape"] = list(global_shape)
    return entry


def _read_leaf(directory: pathlib.Path, ent: dict) -> np.ndarray:
    arr = np.load(directory / ent["file"], allow_pickle=False)
    crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
    if crc != ent["crc32"]:
        raise IOError(f"checkpoint corruption in {ent['name']}: "
                      f"crc {crc} != {ent['crc32']}")
    if ent["dtype"] == "bfloat16":
        import ml_dtypes
        arr = arr.view(ml_dtypes.bfloat16)
    return arr


class FleetCheckpoint:
    """Checkpoint policy + storage for ``run_online_fleet`` carries.

    ``every`` — checkpoint cadence in decision epochs (the runner chunks
    its scan on this boundary); ``keep`` — retained checkpoints (older
    step directories are garbage-collected); ``use_async=False`` swaps
    the background writer for synchronous writes (tests, final flush);
    ``overlap_transfer=False`` additionally forces the device→host
    transfer back onto the caller thread (async writes only)."""

    def __init__(self, directory: str | pathlib.Path, every: int = 50,
                 keep: int = 3, use_async: bool = True,
                 overlap_transfer: bool = True):
        if every < 1:
            raise ValueError(f"checkpoint cadence must be >= 1, got {every}")
        self.every = int(every)
        self._ck = (AsyncCheckpointer(directory, keep=keep,
                                      overlap_transfer=overlap_transfer)
                    if use_async else Checkpointer(directory, keep=keep))

    @property
    def directory(self) -> pathlib.Path:
        return self._ck.dir

    @staticmethod
    def _bundle(agent_states, env_states, keys, lane_map=None) -> dict:
        bundle = {"agent": agent_states, "env": env_states, "keys": keys}
        if lane_map is not None:
            bundle["lanes"] = lane_map
        return bundle

    # -- save ----------------------------------------------------------------
    def save(self, epoch: int, agent_states, env_states, keys,
             lane_map=None) -> None:
        """Snapshot the fleet carries at absolute ``epoch`` (async when
        constructed with ``use_async=True`` — training blocks on neither
        the device→host transfer nor the filesystem; the write publishes
        atomically).  ``lane_map`` — optional ``[fleet]`` int array naming
        the ORIGINAL lane each row is (elastic-lifecycle runs compact
        their fleet between snapshots; plain fleet runs omit it).

        In a multi-process job every process must call this with the
        same ``epoch`` (the chunk schedule is deterministic, so they do):
        the save switches to the per-process shard layout — each process
        writes its addressable fleet rows, process 0 publishes the step
        after a barrier."""
        bundle = self._bundle(agent_states, env_states, keys, lane_map)
        if jax.process_count() > 1:
            self._save_multihost(epoch, bundle)
        elif isinstance(self._ck, AsyncCheckpointer):
            self._ck.save_async(epoch, bundle)
        else:
            self._ck.save(epoch, bundle)

    def _save_multihost(self, epoch: int, bundle: dict) -> None:
        """Per-process shard save (synchronous, collective).

        Layout: ``step_N/proc_P/`` holds process P's manifest + leaf
        files.  A leaf sharded over the spanning fleet mesh contributes
        one file PER ADDRESSABLE SHARD, tagged with the global row range
        it covers (``rows``); replicated / host leaves are written once,
        by process 0.  Each process stages its directory as ``.tmp`` and
        renames atomically; the step only becomes restorable when
        process 0 writes ``meta.json`` after the cross-process barrier —
        so a process dying mid-save can never publish a half-step."""
        from jax.experimental import multihost_utils
        from repro.sharding.fleet import fleet_host
        pid, nprocs = jax.process_index(), jax.process_count()
        step_dir = self._ck.dir / f"step_{epoch:08d}"
        step_dir.mkdir(parents=True, exist_ok=True)
        tmp = self._ck.dir / f".tmp_step_{epoch:08d}_proc{pid:05d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        leaves = jax.tree.leaves(bundle)
        names = _leaf_paths(bundle)
        entries, n_files = [], 0
        for name, leaf in zip(names, leaves):
            sharded = (isinstance(leaf, jax.Array)
                       and not leaf.is_fully_addressable
                       and not leaf.sharding.is_fully_replicated)
            if sharded:
                for s in leaf.addressable_shards:
                    start = s.index[0].start or 0
                    arr = np.asarray(s.data)
                    entries.append(_write_leaf(
                        tmp, n_files, name, arr,
                        rows=[int(start), int(start) + int(arr.shape[0])],
                        global_shape=list(leaf.shape)))
                    n_files += 1
            elif pid == 0:     # replicated / host leaves: one copy, proc 0
                entries.append(_write_leaf(tmp, n_files, name,
                                           np.asarray(fleet_host(leaf))))
                n_files += 1
        (tmp / "manifest.json").write_text(json.dumps(
            {"epoch": int(epoch), "process": pid, "leaves": entries}))
        proc_dir = step_dir / f"proc_{pid:05d}"
        if proc_dir.exists():
            shutil.rmtree(proc_dir)
        tmp.rename(proc_dir)                             # atomic per process
        multihost_utils.sync_global_devices(f"fleet_ckpt_{epoch}")
        if pid == 0:
            (step_dir / "meta.json").write_text(json.dumps(
                {"epoch": int(epoch), "process_count": nprocs,
                 "layout": "multihost-v1"}))
            self._gc_multihost()

    def _gc_multihost(self) -> None:
        steps = self.all_epochs()
        for s in steps[: max(len(steps) - self._ck.keep, 0)]:
            shutil.rmtree(self._ck.dir / f"step_{s:08d}", ignore_errors=True)

    def wait(self) -> None:
        """Block until queued async writes are on disk (raises the first
        background write error, if any)."""
        if isinstance(self._ck, AsyncCheckpointer):
            self._ck.wait()

    def close(self) -> None:
        if isinstance(self._ck, AsyncCheckpointer):
            self._ck.close()

    # -- restore -------------------------------------------------------------
    def all_epochs(self) -> list[int]:
        """Restorable epochs: single-process steps (``manifest.json``)
        plus COMPLETE multi-host steps (``meta.json`` — written by
        process 0 only after every process's shard dir hit disk)."""
        steps = []
        for p in self._ck.dir.glob("step_*"):
            if (p / "manifest.json").exists() or (p / "meta.json").exists():
                steps.append(int(p.name.split("_")[1]))
        return sorted(steps)

    def latest_epoch(self) -> int | None:
        """Newest restorable epoch, or None when the directory is empty."""
        steps = self.all_epochs()
        return steps[-1] if steps else None

    def _manifests(self, epoch: int) -> list[dict]:
        """Every manifest of the step: one for the single-process layout,
        one per ``proc_*`` shard dir for the multi-host layout."""
        d = self._ck.dir / f"step_{epoch:08d}"
        if (d / "manifest.json").exists():
            return [json.loads((d / "manifest.json").read_text())]
        return [json.loads((p / "manifest.json").read_text())
                for p in sorted(d.glob("proc_*"))]

    def has_lane_map(self, epoch: int | None = None) -> bool:
        """True when the snapshot at ``epoch`` (default: latest) was
        written by an elastic-lifecycle run (``save(..., lane_map=...)``)
        — i.e. it must be restored with ``with_lane_map=True`` /
        ``fleet.lifecycle.restore_elastic``."""
        self.wait()
        epoch = self.latest_epoch() if epoch is None else epoch
        if epoch is None:
            return False
        return any("lanes" in ent["name"]
                   for m in self._manifests(epoch) for ent in m["leaves"])

    def is_multihost(self, epoch: int | None = None) -> bool:
        """True when the snapshot at ``epoch`` (default: latest) was
        written in the per-process shard layout (``meta.json`` +
        ``proc_*`` dirs)."""
        self.wait()
        epoch = self.latest_epoch() if epoch is None else epoch
        if epoch is None:
            return False
        return (self._ck.dir / f"step_{epoch:08d}" / "meta.json").exists()

    def restore(self, agent_states, env_states, keys, epoch: int | None = None,
                mesh=None, with_lane_map: bool = False):
        """Load the carries saved at ``epoch`` (default: latest).

        ``agent_states`` / ``env_states`` / ``keys`` supply the target tree
        STRUCTURE (values are ignored — pass freshly-initialized carries).
        With ``mesh``, every lane array is re-placed against the current
        mesh's fleet shardings (leading axis over the data axes,
        replication fallback when the fleet no longer divides the device
        count) — the elastic path that lets a run resume after the device
        count changed.  Returns ``(epoch, agent_states, env_states,
        keys)``.

        ``with_lane_map=True`` reads a snapshot written by an
        elastic-lifecycle run (``save(..., lane_map=...)``): the structure
        templates then describe the COMPACTED (surviving) fleet, and the
        return grows a fifth element — the ``[fleet]`` original-lane index
        array."""
        self.wait()                       # flush our own pending writes
        epoch = self.latest_epoch() if epoch is None else epoch
        if epoch is None:
            raise FileNotFoundError(f"no fleet checkpoints in {self.directory}")
        like = self._bundle(agent_states, env_states, keys,
                            lane_map=(np.zeros(1, np.int32)
                                      if with_lane_map else None))
        shardings = None
        if mesh is not None:
            from repro.sharding.fleet import fleet_shardings
            shardings = fleet_shardings(mesh, like)
        if self.is_multihost(epoch):
            out = self._restore_multihost(like, epoch, shardings)
        else:
            out = self._ck.restore(like, step=epoch, shardings=shardings)
        if with_lane_map:
            return (epoch, out["agent"], out["env"], out["keys"],
                    np.asarray(out["lanes"]))
        return epoch, out["agent"], out["env"], out["keys"]

    def _restore_multihost(self, like, epoch: int, shardings=None):
        """Re-assemble a per-process shard save into full arrays and
        (optionally) re-place them against the current mesh.

        Elastic across HOST-count changes by construction: the reader
        concatenates whatever ``proc_*`` shard dirs the save produced —
        2-process shards restore on 1 process, a single-process save
        restores onto a spanning mesh (``sharding.fleet.put_global``
        feeds each process its addressable slice), and any fleet/device
        mismatch falls back to replication exactly as the single-process
        elastic restore does."""
        d = self._ck.dir / f"step_{epoch:08d}"
        full: dict[str, np.ndarray] = {}
        covered: dict[str, int] = {}
        for proc_dir in sorted(d.glob("proc_*")):
            manifest = json.loads((proc_dir / "manifest.json").read_text())
            for ent in manifest["leaves"]:
                arr = _read_leaf(proc_dir, ent)
                name = ent["name"]
                if ent.get("rows") is None:
                    full[name] = arr
                    covered[name] = -1            # whole leaf present
                else:
                    start, stop = ent["rows"]
                    buf = full.get(name)
                    if buf is None:
                        buf = np.zeros(tuple(ent["global_shape"]), arr.dtype)
                        full[name] = buf
                        covered[name] = 0
                    buf[start:stop] = arr
                    if covered[name] >= 0:
                        covered[name] += stop - start
        for name, got in covered.items():
            if got >= 0 and got < full[name].shape[0]:
                raise IOError(
                    f"multi-host checkpoint step {epoch} is missing fleet "
                    f"rows of {name}: {got}/{full[name].shape[0]} covered "
                    f"(incomplete shard set in {d})")
        names = _leaf_paths(like)
        missing = [n for n in names if n not in full]
        if missing:
            raise IOError(f"multi-host checkpoint step {epoch} lacks "
                          f"leaves {missing} (template/layout mismatch)")
        leaves = [full[n] for n in names]
        if shardings is not None:
            from repro.sharding.fleet import put_global
            shard_leaves = jax.tree.leaves(shardings)
            leaves = [put_global(a, s)
                      for a, s in zip(leaves, shard_leaves)]
        _, treedef = jax.tree.flatten(like)
        return jax.tree.unflatten(treedef, leaves)
