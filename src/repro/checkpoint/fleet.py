"""Fleet-training checkpoints: periodic, async, atomic, elastic.

:class:`FleetCheckpoint` wraps the generic async atomic
:class:`~repro.checkpoint.checkpointer.Checkpointer` around the fleet
runner's carries — per-lane agent states, env states, and PRNG keys —
tagged by absolute decision epoch.  ``core.agent.run_online_fleet(...,
checkpoint=ck)`` chunks its epoch scan every ``ck.every`` epochs and calls
:meth:`FleetCheckpoint.save` after each chunk: the device→host transfer is
OVERLAPPED (the caller only dispatches a donation-safe on-device snapshot
plus an async D2H copy per leaf; the background writer completes the
transfer, double-buffered at two snapshots in flight), so accelerator
meshes keep scanning the next chunk — which donates the live carries —
while the previous one serializes.  A step directory only renames into place once
every leaf + manifest hit disk, so a kill mid-write never corrupts the
newest restorable state.

Elastic-lifecycle runs (repro/fleet/lifecycle.py) COMPACT their fleet as
lanes converge, so consecutive snapshots can hold different lane counts;
``save(..., lane_map=...)`` records which ORIGINAL lanes the surviving
rows are, and ``restore(..., with_lane_map=True)`` recovers that map
alongside the carries (see docs/elastic_fleets.md for the elastic-restore
story).

Restore is ELASTIC: :meth:`restore` loads the lane arrays as full host
arrays and — given a mesh — re-places them with the *current* mesh's
fleet shardings (``sharding.fleet.fleet_shardings``), so a run
checkpointed on an 8-device mesh resumes on 4 devices (or on the host
mesh) from the same file set.  The resume walkthrough lives in
docs/sharded_fleets.md; the bit-exactness contract is pinned by
tests/test_fleet_checkpoint.py."""
from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.checkpoint.checkpointer import AsyncCheckpointer, Checkpointer


class FleetCheckpoint:
    """Checkpoint policy + storage for ``run_online_fleet`` carries.

    ``every`` — checkpoint cadence in decision epochs (the runner chunks
    its scan on this boundary); ``keep`` — retained checkpoints (older
    step directories are garbage-collected); ``use_async=False`` swaps
    the background writer for synchronous writes (tests, final flush);
    ``overlap_transfer=False`` additionally forces the device→host
    transfer back onto the caller thread (async writes only)."""

    def __init__(self, directory: str | pathlib.Path, every: int = 50,
                 keep: int = 3, use_async: bool = True,
                 overlap_transfer: bool = True):
        if every < 1:
            raise ValueError(f"checkpoint cadence must be >= 1, got {every}")
        self.every = int(every)
        self._ck = (AsyncCheckpointer(directory, keep=keep,
                                      overlap_transfer=overlap_transfer)
                    if use_async else Checkpointer(directory, keep=keep))

    @property
    def directory(self) -> pathlib.Path:
        return self._ck.dir

    @staticmethod
    def _bundle(agent_states, env_states, keys, lane_map=None) -> dict:
        bundle = {"agent": agent_states, "env": env_states, "keys": keys}
        if lane_map is not None:
            bundle["lanes"] = lane_map
        return bundle

    # -- save ----------------------------------------------------------------
    def save(self, epoch: int, agent_states, env_states, keys,
             lane_map=None) -> None:
        """Snapshot the fleet carries at absolute ``epoch`` (async when
        constructed with ``use_async=True`` — training blocks on neither
        the device→host transfer nor the filesystem; the write publishes
        atomically).  ``lane_map`` — optional ``[fleet]`` int array naming
        the ORIGINAL lane each row is (elastic-lifecycle runs compact
        their fleet between snapshots; plain fleet runs omit it)."""
        bundle = self._bundle(agent_states, env_states, keys, lane_map)
        if isinstance(self._ck, AsyncCheckpointer):
            self._ck.save_async(epoch, bundle)
        else:
            self._ck.save(epoch, bundle)

    def wait(self) -> None:
        """Block until queued async writes are on disk (raises the first
        background write error, if any)."""
        if isinstance(self._ck, AsyncCheckpointer):
            self._ck.wait()

    def close(self) -> None:
        if isinstance(self._ck, AsyncCheckpointer):
            self._ck.close()

    # -- restore -------------------------------------------------------------
    def all_epochs(self) -> list[int]:
        return self._ck.all_steps()

    def latest_epoch(self) -> int | None:
        """Newest restorable epoch, or None when the directory is empty."""
        return self._ck.latest_step()

    def has_lane_map(self, epoch: int | None = None) -> bool:
        """True when the snapshot at ``epoch`` (default: latest) was
        written by an elastic-lifecycle run (``save(..., lane_map=...)``)
        — i.e. it must be restored with ``with_lane_map=True`` /
        ``fleet.lifecycle.restore_elastic``."""
        self.wait()
        epoch = self.latest_epoch() if epoch is None else epoch
        if epoch is None:
            return False
        manifest = json.loads(
            (self._ck.dir / f"step_{epoch:08d}" / "manifest.json").read_text())
        return any("lanes" in ent["name"] for ent in manifest["leaves"])

    def restore(self, agent_states, env_states, keys, epoch: int | None = None,
                mesh=None, with_lane_map: bool = False):
        """Load the carries saved at ``epoch`` (default: latest).

        ``agent_states`` / ``env_states`` / ``keys`` supply the target tree
        STRUCTURE (values are ignored — pass freshly-initialized carries).
        With ``mesh``, every lane array is re-placed against the current
        mesh's fleet shardings (leading axis over the data axes,
        replication fallback when the fleet no longer divides the device
        count) — the elastic path that lets a run resume after the device
        count changed.  Returns ``(epoch, agent_states, env_states,
        keys)``.

        ``with_lane_map=True`` reads a snapshot written by an
        elastic-lifecycle run (``save(..., lane_map=...)``): the structure
        templates then describe the COMPACTED (surviving) fleet, and the
        return grows a fifth element — the ``[fleet]`` original-lane index
        array."""
        self.wait()                       # flush our own pending writes
        epoch = self.latest_epoch() if epoch is None else epoch
        if epoch is None:
            raise FileNotFoundError(f"no fleet checkpoints in {self.directory}")
        like = self._bundle(agent_states, env_states, keys,
                            lane_map=(np.zeros(1, np.int32)
                                      if with_lane_map else None))
        shardings = None
        if mesh is not None:
            from repro.sharding.fleet import fleet_shardings
            shardings = fleet_shardings(mesh, like)
        out = self._ck.restore(like, step=epoch, shardings=shardings)
        if with_lane_map:
            return (epoch, out["agent"], out["env"], out["keys"],
                    np.asarray(out["lanes"]))
        return epoch, out["agent"], out["env"], out["keys"]
