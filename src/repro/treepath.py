"""Version-tolerant pytree key-path formatting.

``jax.tree_util.keystr(path, simple=True, separator=...)`` only exists in
newer jax releases; the pinned jax 0.4.37 accepts the path alone.  Every
module that needs a name-based path string (sharding policy rules,
checkpoint leaf ids) goes through :func:`keystr_path`, which produces the
"simple" form (bare attribute / key / index names joined by ``separator``)
on any jax version.
"""
from __future__ import annotations

import jax


def _key_token(key) -> str:
    # GetAttrKey(name=...), DictKey(key=...), SequenceKey(idx=...),
    # FlattenedIndexKey(key=...) — in the simple form each renders as its
    # bare payload, no brackets/dots.
    for attr in ("name", "key", "idx"):
        if hasattr(key, attr):
            return str(getattr(key, attr))
    return str(key)


def keystr_path(path, separator: str = "/") -> str:
    """Simple-form key-path string, e.g. ``layers/pos0/ffn/gate/w``."""
    try:
        return jax.tree_util.keystr(path, simple=True, separator=separator)
    except TypeError:  # jax <= 0.4.x: keystr(path) only
        return separator.join(_key_token(k) for k in path)
