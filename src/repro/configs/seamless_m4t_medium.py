"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206 — enc-dec, multimodal.  The speech frontend is a STUB:
input_specs() provides precomputed frame embeddings (per instructions).
[arXiv:2308.11596]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    frontend="frames",
    frontend_positions=0,     # frames arrive as the encoder input itself
)

SMOKE = ModelConfig(
    name="seamless-smoke",
    family="encdec",
    num_layers=2,
    encoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    frontend="frames",
)
