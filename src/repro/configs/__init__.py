"""Architecture registry: ``--arch <id>`` resolution + shape grid.

Every assigned (architecture × input shape) cell is enumerated here; the
dry-run, roofline, and smoke tests iterate this table."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    "command-r-plus-104b",
    "llama3-8b",
    "qwen1.5-110b",
    "yi-34b",
    "seamless-m4t-medium",
    "rwkv6-7b",
    "jamba-1.5-large-398b",
    "phi-3-vision-4.2b",
    "granite-moe-3b-a800m",
    "qwen2-moe-a2.7b",
]

_MODULES = {
    "command-r-plus-104b": "command_r_plus_104b",
    "llama3-8b": "llama3_8b",
    "qwen1.5-110b": "qwen15_110b",
    "yi-34b": "yi_34b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "rwkv6-7b": "rwkv6_7b",
    "jamba-1.5-large-398b": "jamba_15_large_398b",
    "phi-3-vision-4.2b": "phi3_vision_42b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.SMOKE if smoke else mod.CONFIG


def cell_enabled(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs; reason if skipped (DESIGN.md §7)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k requires sub-quadratic attention (skip: " \
                      "pure full-attention arch)"
    return True, ""


def all_cells(include_skipped: bool = False):
    """Yield (arch_id, shape_name, enabled, reason)."""
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = cell_enabled(cfg, s)
            if ok or include_skipped:
                yield a, s.name, ok, why
