"""rwkv6-7b [ssm] — 32L d_model=4096 (attention-free) d_ff=14336 vocab=65536
— Finch, data-dependent decay.  [arXiv:2404.05892]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,             # wkv heads = d_model / head_size
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv_head_size=64,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    rwkv_head_size=16,
)
