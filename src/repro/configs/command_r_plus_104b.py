"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias, Cohere parallel attn+FFN block.
[hf:CohereForAI/c4ai-command-r-v01]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    qkv_bias=False,
    parallel_block=True,      # Cohere: x + attn(ln x) + mlp(ln x)
    tie_embeddings=True,      # command-r ties input/output embeddings
    rope_theta=75e6,
)

SMOKE = ModelConfig(
    name="command-r-plus-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    parallel_block=True,
    tie_embeddings=True,
)
