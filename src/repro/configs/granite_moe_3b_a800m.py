"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40e top-8.  [hf:ibm-granite/granite-3.0-3b-a800m-base]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="moe",
    num_layers=2,
    d_model=48,
    num_heads=6,
    num_kv_heads=2,
    head_dim=8,
    d_ff=32,
    vocab_size=512,
    num_experts=5,            # preserves non-divisible expert count
    experts_per_token=2,
    tie_embeddings=True,
)
