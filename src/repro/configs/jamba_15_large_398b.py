"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE
every other layer.  [arXiv:2403.19887]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    moe_offset=1,             # MoE on odd positions within the period-8 block
    attn_every=8,             # 1 attention : 7 mamba
    attn_offset=4,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    num_layers=8,             # one full period-8 block
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    num_experts=4,
    experts_per_token=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    mamba_d_state=4,
    mamba_d_conv=4,
)
