"""yi-34b [dense] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 —
llama-arch GQA.  [arXiv:2403.04652]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5e6,
)

SMOKE = ModelConfig(
    name="yi-smoke",
    family="dense",
    num_layers=2,
    d_model=56,          # 7 heads * hd 8: preserves the non-16-divisible heads
    num_heads=7,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    head_dim=8,
)
