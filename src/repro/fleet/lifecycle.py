"""Elastic lane lifecycle: early-stop, compact, and search scenario fleets.

The fixed-grid fleet runner (``core/agent.run_online_fleet``) spends
identical compute on every lane, converged or not.  The paper's claim is
that model-free control *quickly* reaches a good schedule during online
learning — so for most scenario lanes most epochs of a fixed grid are
wasted.  This module converts fleet compute from fixed-grid to
budget-aware:

* **Per-lane early stopping** — :class:`StopRule` is a jit-compatible
  plateau test on the smoothed reward trace (:func:`plateau_converged`).
  The elastic runner reuses the ``checkpoint=`` chunking machinery: the
  epoch scan is cut every ``rule.check_every`` epochs (or the checkpoint
  cadence when one is attached) and the rule runs at each boundary.

* **Lane compaction** — lanes the rule marks done stop paying compute:
  between chunks :func:`compact_lanes` gathers the survivors into a
  smaller fleet (agent states, env states, PRNG keys, and the STACKED
  leaves of an EnvParams scenario fleet — broadcast-invariant leaves pass
  through single-copy) and, on a mesh, re-places them with
  ``sharding/fleet.py``.  ``shard_map`` partitions evenly, so meshed
  fleets compact to multiples of the data-axis device count
  (``sharding.fleet.compaction_size``); the gap rides as already-stopped
  "passenger" lanes whose extra epochs are discarded.  Compaction is
  loss-free: a surviving lane's trajectory bit-matches the uncompacted
  run on the host mesh (lanes are independent; pinned in
  tests/test_lifecycle.py).

* **Successive-halving scenario search** — :func:`search_scenarios`
  launches a wide fleet of perturbed scenarios
  (``dsdps/scenarios.build_for`` + ``sample_perturbed``), prunes the
  bottom half at each rung by eval reward, refills freed lanes with fresh
  perturbations, and returns a ranked :class:`Leaderboard`.  This is the
  Decima-style adaptively-curated workload set, and the Vaquero &
  Cuadrado online budget reallocation, on top of our fleet runner.

Entry points: ``run_online_fleet(..., lifecycle=StopRule(...))`` for the
drop-in path, :func:`run_online_fleet_elastic` for the full
:class:`ElasticResult` accounting, ``drl_control --scenario-search`` and
``fleet_bench --lifecycle`` from the command line.  The narrative
walkthrough lives in docs/elastic_fleets.md."""
from __future__ import annotations

import dataclasses
import json
import pathlib
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent import (History, chunk_schedule, prepare_fleet,
                              reset_fleet_states, run_fleet_chunk)
from repro.core.api import Agent
from repro.diagnostics import maybe_check_finite
from repro.dsdps.simulator import lane_params, params_in_axes, stack_env_params
from repro.sharding.fleet import (compaction_size, fleet_host,
                                  fleet_host_tree, is_spanning, shard_fleet)


class StopRule(NamedTuple):
    """Jit-compatible plateau test on the smoothed per-lane reward.

    A lane is converged when the mean reward of its last ``window`` epochs
    improves on the mean of the ``window`` before that by no more than
    ``rel_tol`` (relative to the reward magnitude) — window means ARE the
    smoother, so single noisy epochs cannot stop a lane.  ``min_epochs``
    lower-bounds how early any lane may stop; ``check_every`` is the chunk
    cadence at which the rule runs when no checkpoint cadence drives the
    chunking.  A NamedTuple of numbers → hashable → rides jit as a static
    argument."""

    window: int = 8
    rel_tol: float = 0.01
    min_epochs: int = 16
    check_every: int = 8

    @property
    def warmup(self) -> int:
        """Epochs of history the rule needs before it can fire."""
        return max(self.min_epochs, 2 * self.window)


@partial(jax.jit, static_argnames=("rule",))
def plateau_converged(recent: jnp.ndarray, rule: StopRule) -> jnp.ndarray:
    """Per-lane plateau verdict over the last ``2 * rule.window`` epochs.

    ``recent`` is ``[..., 2*window]`` reward history (the elastic runner
    slices it from the accumulating trace at each chunk boundary).  Fixed
    input shape → one compile per (shape, rule); usable INSIDE a jitted
    scan as well as between chunks."""
    W = rule.window
    prev = recent[..., :W].mean(axis=-1)
    last = recent[..., W:].mean(axis=-1)
    scale = jnp.maximum(jnp.maximum(jnp.abs(prev), jnp.abs(last)), 1e-9)
    return (last - prev) <= rule.rel_tol * scale


def compact_lanes(idx, keys, states, env_states, env_params, ref):
    """Gather lanes ``idx`` of the fleet carries into a smaller fleet.

    ``keys`` / ``states`` / ``env_states`` gather their leading fleet
    axis; ``env_params`` gathers only its STACKED leaves (one more leading
    axis than the single-scenario reference ``ref``) — broadcast-invariant
    leaves pass through as the single copy they are, so a
    ``stack_env_params(..., broadcast_invariant=True)`` fleet stays
    broadcast-invariant after compaction and the ``params_in_axes`` spec
    is unchanged.  Returns ``(keys, states, env_states, env_params)``."""
    idx = jnp.asarray(idx)
    take = lambda tree: jax.tree.map(lambda x: jnp.take(x, idx, axis=0), tree)
    keys = jnp.take(keys, idx, axis=0)
    states = take(states)
    env_states = take(env_states)
    if env_params is not None:
        flat, treedef = jax.tree_util.tree_flatten(env_params)
        ref_flat = jax.tree_util.tree_leaves(ref)
        picked = [jnp.take(p, idx, axis=0) if jnp.ndim(p) == jnp.ndim(r) + 1
                  else p for p, r in zip(flat, ref_flat)]
        env_params = jax.tree_util.tree_unflatten(treedef, picked)
    return keys, states, env_states, env_params


@dataclasses.dataclass
class ElasticResult:
    """Outcome of an elastic fleet run, in ORIGINAL lane order.

    ``history`` carries full ``[F, T]`` traces: a lane stopped at epoch e
    repeats its epoch-(e-1) reward/latency from e on (moved pads with 0),
    so downstream seed-band plotting keeps working; ``epochs_run[i]``
    says where lane i's real trace ends.  ``executed_lane_epochs`` counts
    every lane-epoch actually executed — passengers included — which is
    what ``fleet_bench --lifecycle`` compares against the fixed grid.
    ``lane_ids[i]`` names row i's lane in the RUN THAT STARTED the
    lifecycle — a fresh run numbers 0..F-1; a run resumed from a
    compacted snapshot (:func:`restore_elastic`) keeps the original
    numbering of the surviving lanes."""

    states: Any                     # [F] stacked agent states
    history: History                # [F, T] padded traces
    epochs_run: np.ndarray          # [F] epochs each lane really executed
    executed_lane_epochs: int
    fixed_grid_lane_epochs: int
    lane_ids: np.ndarray = None     # [F] original lane names

    @property
    def savings(self) -> float:
        """Fraction of the fixed grid's lane-epochs NOT executed."""
        return 1.0 - self.executed_lane_epochs / max(
            self.fixed_grid_lane_epochs, 1)


def run_online_fleet_elastic(
    keys: jax.Array,
    env,
    agent: Agent,
    states,
    T: int,
    rule: StopRule | None = None,
    updates_per_epoch: int = 1,
    explore: bool = True,
    env_states=None,
    env_params=None,
    mesh=None,
    checkpoint=None,
    start_epoch: int = 0,
    stop_fn: Callable[[np.ndarray, int], np.ndarray] | None = None,
    lane_ids: np.ndarray | None = None,
) -> ElasticResult:
    """``run_online_fleet`` with the elastic lane lifecycle.

    Identical call surface and per-epoch semantics as the fixed-grid
    runner (same chunked scan, same key discipline — a lane's trajectory
    up to its stop epoch bit-matches the fixed-grid run on the host mesh),
    plus: at every chunk boundary the :class:`StopRule` marks plateaued
    lanes done, their final carries are captured, and the surviving lanes
    are compacted into a smaller fleet (re-placed against ``mesh`` when
    sharded, padded with passenger lanes to keep the fleet divisible).

    ``checkpoint`` snapshots the COMPACTED carries with a ``lane_map``
    naming the original lanes (passenger rows are marked ``-1`` — their
    states continued past their stop epoch and are not authoritative);
    restore with ``FleetCheckpoint.restore(..., with_lane_map=True)``.

    ``stop_fn(rewards_so_far, t) -> done[n_live]`` overrides the plateau
    test (rows are the live lanes' full ``[n_live, t]`` reward history) —
    the hook custom convergence criteria and the bit-match tests use.

    ``lane_ids`` names the lanes in the ORIGINAL run's numbering — pass
    the ids :func:`restore_elastic` returns when resuming a compacted
    snapshot, so checkpoint lane maps and the result's lane accounting
    keep referring to the original lanes across kill/resume cycles."""
    from repro.core.agent import _require_agent
    agent = _require_agent(agent)
    rule = rule if rule is not None else StopRule()
    T = int(T)
    if T < 1:
        raise ValueError(f"T must be >= 1, got {T}")
    F = int(jnp.asarray(keys).shape[0])
    keys, states, env_states, env_params, ref, params_axes, params_specs = \
        prepare_fleet(keys, env, states, env_states, env_params, mesh)

    every = getattr(checkpoint, "every", None) if checkpoint is not None \
        else None
    every = every or rule.check_every

    # -- per-original-lane output slots -------------------------------------
    rewards_buf = np.zeros((F, T), np.float32)
    lats_buf = np.zeros((F, T), np.float32)
    moved_buf = np.zeros((F, T), np.float32)
    epochs_run = np.full(F, T, np.int64)
    final_states: list[Any] = [None] * F
    final_X: list[Any] = [None] * F

    # -- compact-fleet bookkeeping ------------------------------------------
    orig = np.arange(F)              # compact position -> row in this run
    ids = (np.arange(F) if lane_ids is None
           else np.asarray(lane_ids, np.int64))  # row -> ORIGINAL lane name
    if ids.shape != (F,):
        raise ValueError(f"lane_ids must be [{F}], got {ids.shape}")
    live = np.ones(F, bool)          # False = passenger (already captured)
    executed = 0
    t = 0

    def host_carries(states_now, env_states_now):
        # chunk-boundary bookkeeping crosses host<->device on purpose, so
        # the diagnostics transfer guard is lifted here (as in the
        # stop-test/compaction block below); the guarded steady state is
        # the chunk scan itself.  On a process-spanning mesh fleet_host is
        # a cross-process allgather every process runs identically, so the
        # host-side lane bookkeeping below stays deterministic and in
        # lockstep across processes.
        with jax.transfer_guard("allow"):
            return fleet_host_tree(states_now), fleet_host_tree(env_states_now)

    def capture(pos: int, host_states, host_env_states) -> None:
        o = int(orig[pos])
        final_states[o] = jax.tree.map(lambda x: np.asarray(x[pos]),
                                       host_states)
        final_X[o] = np.asarray(host_env_states.X[pos])

    for n in chunk_schedule(T, every):
        states, env_states, keys, rewards, lats, moved = run_fleet_chunk(
            keys, states, env_states, env_params, env=env, agent=agent,
            T=n, updates_per_epoch=updates_per_epoch, explore=explore,
            params_axes=params_axes, mesh=mesh, params_specs=params_specs)
        executed += len(orig) * n
        maybe_check_finite((states, rewards),
                           f"run_online_fleet_elastic epoch {start_epoch + t + n}")
        r, l, m = fleet_host(rewards), fleet_host(lats), fleet_host(moved)
        rows = orig[live]
        rewards_buf[rows, t:t + n] = r[live]
        lats_buf[rows, t:t + n] = l[live]
        moved_buf[rows, t:t + n] = m[live]
        t += n
        if checkpoint is not None:
            lane_map = np.where(live, ids[orig], -1).astype(np.int32)
            checkpoint.save(start_epoch + t, states, env_states, keys,
                            lane_map=lane_map)
        if t >= T:
            break

        # -- stop test at the chunk boundary (boundary work: guard lifted) --
        with jax.transfer_guard("allow"):
            if stop_fn is not None:
                done_rows = np.asarray(stop_fn(rewards_buf[rows, :t], t),
                                       bool)
            elif t >= rule.warmup:
                recent = jnp.asarray(rewards_buf[rows, t - 2 * rule.window:t])
                done_rows = np.asarray(plateau_converged(recent, rule))
            else:
                continue
            if not done_rows.any():
                continue
            h_states, h_env = host_carries(states, env_states)
            live_pos = np.flatnonzero(live)
            for pos in live_pos[done_rows]:
                capture(int(pos), h_states, h_env)
                o = int(orig[pos])
                epochs_run[o] = t
                rewards_buf[o, t:] = rewards_buf[o, t - 1]
                lats_buf[o, t:] = lats_buf[o, t - 1]
                moved_buf[o, t:] = 0.0
            live[live_pos[done_rows]] = False

            # -- compaction -------------------------------------------------
            n_live = int(live.sum())
            if n_live == 0:
                break
            target = compaction_size(n_live, mesh)
            if target < len(orig):
                keep = np.flatnonzero(live)
                if target > n_live:      # pad with most recent passengers
                    passengers = np.flatnonzero(~live)[::-1][:target - n_live]
                    keep = np.sort(np.concatenate([keep, passengers]))
                if mesh is not None and is_spanning(mesh):
                    # spanning arrays can't be gathered with plain
                    # jnp.take on-device (arbitrary cross-process
                    # gathers); bring the carries home — identically on
                    # every process — compact on host, and let
                    # shard_fleet below re-place against the global mesh
                    keys = fleet_host(keys)
                    states = fleet_host_tree(states)
                    env_states = fleet_host_tree(env_states)
                    if env_params is not None:
                        env_params = fleet_host_tree(env_params)
                keys, states, env_states, env_params = compact_lanes(
                    keep, keys, states, env_states, env_params, ref)
                orig, live = orig[keep], live[keep]
                if mesh is not None:
                    keys, states, env_states, env_params, params_specs = \
                        shard_fleet(mesh, keys, states, env_states,
                                    env_params, ref)

    # lanes still running at the horizon (or passengers never re-captured)
    if np.any(live):
        h_states, h_env = host_carries(states, env_states)
        for pos in np.flatnonzero(live):
            capture(int(pos), h_states, h_env)

    with jax.transfer_guard("allow"):
        states_out = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)),
                                  *final_states)
    history = History(rewards=rewards_buf, latencies=lats_buf,
                      moved=moved_buf, final_assignment=np.stack(final_X))
    return ElasticResult(states=states_out, history=history,
                         epochs_run=epochs_run,
                         executed_lane_epochs=executed,
                         fixed_grid_lane_epochs=F * T,
                         lane_ids=ids)


def restore_elastic(checkpoint, states_like, env_states_like, keys_like,
                    env_params=None, ref=None, epoch: int | None = None,
                    mesh=None):
    """Restore a COMPACTED elastic-lifecycle snapshot for resumption.

    Elastic runs checkpoint their compacted carries with a ``lane_map``
    naming each row's original lane (``-1`` = passenger: a lane that
    already stopped and whose row continued past its stop epoch as
    divisibility padding — its checkpointed state is NOT authoritative).
    This helper restores the snapshot via ``FleetCheckpoint.restore(...,
    with_lane_map=True)``, DROPS the passenger rows, and — given the
    original run's stacked ``env_params`` scenario fleet plus its
    single-scenario ``ref`` — gathers the surviving lanes' scenario rows
    (broadcast-invariant leaves pass through single-copy).

    The ``*_like`` templates only supply tree STRUCTURE (the generic
    checkpointer takes shapes from the manifest), so templates built for
    the original full-size fleet restore any compacted snapshot.

    Returns ``(epoch, keys, states, env_states, env_params, lane_ids)``;
    feed everything straight back into :func:`run_online_fleet_elastic`
    with ``start_epoch=epoch`` and ``lane_ids=lane_ids``."""
    # on a process-spanning target mesh restore to HOST arrays: the
    # passenger-dropping row gather below can't run on spanning shards,
    # and run_online_fleet_elastic's prepare_fleet re-places the compacted
    # carries against the mesh anyway
    restore_mesh = None if (mesh is not None and is_spanning(mesh)) else mesh
    epoch, states, env_states, keys, lane_map = checkpoint.restore(
        states_like, env_states_like, keys_like, epoch=epoch,
        mesh=restore_mesh, with_lane_map=True)
    lane_map = np.asarray(lane_map)
    rows = np.flatnonzero(lane_map >= 0)
    ids = lane_map[rows].astype(np.int64)
    with jax.transfer_guard("allow"):
        take = jnp.asarray(rows)
        gather = lambda t: jax.tree.map(
            lambda x: jnp.take(jnp.asarray(x), take, axis=0), t)
        states, env_states = gather(states), gather(env_states)
        keys = jnp.take(jnp.asarray(keys), take, axis=0)
        if env_params is not None:
            if ref is None:
                raise ValueError("restoring with env_params= needs ref= "
                                 "(the env's default_params()) to tell "
                                 "stacked leaves from invariant ones")
            flat, treedef = jax.tree_util.tree_flatten(env_params)
            ref_flat = jax.tree_util.tree_leaves(ref)
            pick = jnp.asarray(ids)
            picked = [jnp.take(p, pick, axis=0)
                      if jnp.ndim(p) == jnp.ndim(r) + 1 else p
                      for p, r in zip(flat, ref_flat)]
            env_params = jax.tree_util.tree_unflatten(treedef, picked)
    return epoch, keys, states, env_states, env_params, ids


# --------------------------------------------------------------------------
# Successive-halving scenario search
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ScenarioEntry:
    """One candidate scenario's search record."""

    cand: int            # candidate id (launch order)
    rung: int            # rungs completed (1-based)
    epochs: int          # cumulative training epochs this candidate got
    score: float         # eval reward: mean of its last eval_window epochs
    survived: bool       # still in the fleet after its last cut


@dataclasses.dataclass
class Leaderboard:
    """Ranked outcome of :func:`search_scenarios` (best score first).

    ``params[cand]`` holds each candidate's single-scenario EnvParams —
    re-stack the top entries with ``stack_env_params`` to train a full
    fleet on the curated set (the Decima discipline)."""

    entries: list[ScenarioEntry]
    rungs: tuple[int, ...]
    fleet: int
    total_lane_epochs: int
    params: dict[int, Any]

    def to_json(self) -> dict:
        return {
            "rungs": list(self.rungs),
            "fleet": self.fleet,
            "total_lane_epochs": self.total_lane_epochs,
            "leaderboard": [dataclasses.asdict(e) for e in self.entries],
        }

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=2))
        return path


def _tree_concat(a, b):
    return jax.tree.map(lambda x, y: jnp.concatenate([x, y], axis=0), a, b)


def search_scenarios(
    env,
    agent: Agent,
    scenario: str = "mixed",
    perturb: Callable[[jax.Array], Any] | None = None,
    fleet: int = 8,
    rungs: tuple[int, ...] = (16, 16, 32),
    eval_window: int = 8,
    updates_per_epoch: int = 1,
    explore: bool = True,
    refill: bool = True,
    seed: int = 0,
) -> Leaderboard:
    """Successive-halving search over perturbed scenarios.

    A ``fleet``-wide candidate set seeded from the named scenario builder
    (``dsdps/scenarios.build_for(env, scenario, fleet)``) trains through
    the rungs: after each rung every lane is scored by eval reward (mean
    training reward over its last ``eval_window`` epochs — higher is
    better, i.e. lower stabilized latency), the bottom half is pruned via
    :func:`compact_lanes`, and — with ``refill=True`` — the freed lanes
    are refilled with fresh perturbations (``perturb(key) -> params``,
    default ``dsdps.scenarios.perturb_sampler(env)``), so the fleet stays
    wide while compute concentrates on promising scenarios.  Survivors
    carry their agent state, env state, and PRNG key across rungs;
    refills start fresh (their ``epochs`` field says how long each
    candidate actually trained).

    Returns a :class:`Leaderboard` ranked by score, holding every
    candidate ever launched plus its EnvParams for curriculum reuse.
    Wired into ``drl_control --scenario-search`` and ``fleet_bench
    --lifecycle``."""
    from repro.core.agent import _require_agent
    from repro.dsdps import scenarios as scen
    agent = _require_agent(agent)
    if fleet < 2:
        raise ValueError(f"search needs fleet >= 2, got {fleet}")
    ref = env.default_params()
    if perturb is None:
        perturb = scen.perturb_sampler(env)
    key = jax.random.PRNGKey(seed)

    stacked = scen.build_for(env, scenario, fleet)
    cand_params = {i: lane_params(stacked, ref, i) for i in range(fleet)}
    current = list(range(fleet))
    next_id = fleet
    key, k_init, k_lane, k_env = jax.random.split(key, 4)
    states = agent.init_fleet(k_init, fleet, env_params=stacked, env=env)
    keys = jax.random.split(k_lane, fleet)
    env_states = reset_fleet_states(
        jax.random.split(k_env, fleet), env, stacked)

    entries: dict[int, ScenarioEntry] = {}
    epochs_done = {c: 0 for c in current}
    total = 0
    for r, n in enumerate(rungs):
        stacked = stack_env_params([cand_params[c] for c in current])
        states, env_states, keys, rewards, _, _ = run_fleet_chunk(
            keys, states, env_states, stacked, env=env, agent=agent,
            T=int(n), updates_per_epoch=updates_per_epoch, explore=explore,
            params_axes=params_in_axes(stacked, ref))
        total += len(current) * int(n)
        scores = np.asarray(rewards)[:, -min(eval_window, int(n)):].mean(
            axis=1)
        for i, c in enumerate(current):
            epochs_done[c] += int(n)
            entries[c] = ScenarioEntry(cand=c, rung=r + 1,
                                       epochs=epochs_done[c],
                                       score=float(scores[i]), survived=True)
        if r == len(rungs) - 1:
            break

        # -- the halving cut ------------------------------------------------
        n_keep = max(1, len(current) // 2)
        keep = np.sort(np.argsort(-scores)[:n_keep])
        for i, c in enumerate(current):
            if i not in set(keep.tolist()):
                entries[c] = dataclasses.replace(entries[c], survived=False)
        keys, states, env_states, _ = compact_lanes(
            keep, keys, states, env_states, stacked, ref)
        current = [current[i] for i in keep]

        if refill:
            new_ids = []
            for _ in range(fleet - len(current)):
                key, k_p = jax.random.split(key)
                cand_params[next_id] = perturb(k_p)
                new_ids.append(next_id)
                next_id += 1
            new_stacked = stack_env_params([cand_params[c] for c in new_ids])
            key, k_i, k_l, k_e = jax.random.split(key, 4)
            new_states = agent.init_fleet(k_i, len(new_ids),
                                          env_params=new_stacked, env=env)
            new_keys = jax.random.split(k_l, len(new_ids))
            new_env = reset_fleet_states(
                jax.random.split(k_e, len(new_ids)), env, new_stacked)
            states = _tree_concat(states, new_states)
            env_states = _tree_concat(env_states, new_env)
            keys = jnp.concatenate([keys, new_keys], axis=0)
            current += new_ids
            epochs_done.update({c: 0 for c in new_ids})

    ranked = sorted(entries.values(), key=lambda e: -e.score)
    return Leaderboard(entries=ranked, rungs=tuple(int(n) for n in rungs),
                       fleet=fleet, total_lane_epochs=total,
                       params=cand_params)
