# Elastic lane lifecycle for scenario fleets: per-lane early stopping,
# between-chunk lane compaction, and successive-halving scenario search.
from repro.fleet.lifecycle import (ElasticResult, Leaderboard, ScenarioEntry,
                                   StopRule, compact_lanes, plateau_converged,
                                   run_online_fleet_elastic, search_scenarios)

__all__ = [
    "ElasticResult", "Leaderboard", "ScenarioEntry", "StopRule",
    "compact_lanes", "plateau_converged", "run_online_fleet_elastic",
    "search_scenarios",
]
