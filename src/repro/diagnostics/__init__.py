"""Runtime tracing-discipline guards (transfer guard, jit-cache-miss
sentinel, chunk-boundary NaN sweeps).  Static counterpart:
``tools/jaxguard``; rule catalog and usage: docs/static_analysis.md."""
from repro.diagnostics.guards import (CompileCounter, GuardState,
                                      NonFiniteError, active, guards,
                                      maybe_check_finite)

__all__ = ["CompileCounter", "GuardState", "NonFiniteError", "active",
           "guards", "maybe_check_finite"]
