"""Runtime tracing-discipline guards — the dynamic counterpart of the
``tools/jaxguard`` static pass.

Three guards, bundled by :func:`guards`:

* ``jax.transfer_guard``: implicit host<->device transfers (the runtime
  face of JG004) raise instead of silently serialising the dispatch
  stream.  Explicit pulls (``np.asarray(x)``, ``jax.device_get``) stay
  legal — that is how History traces leave the device.
* :class:`CompileCounter`: a jit-cache-miss sentinel (runtime face of
  JG002/JG003).  It reads the tracked jitted callables' trace-cache
  sizes, so a steady-state loop that silently retraces every call shows
  up as a count, not as a mysteriously slow run.
* NaN/Inf sweeps: :func:`maybe_check_finite` is called by the fleet
  runners at chunk boundaries; inside an active ``guards(nan_check=True)``
  region it pulls each carry leaf to host and raises
  :class:`NonFiniteError` naming the offending leaves.

Typical use (what ``drl_control --guards`` wires up)::

    from repro.core import agent as agent_mod
    from repro.diagnostics import guards

    with guards(track=(agent_mod._fleet_program,)) as g:
        states, hist = agent_mod.run_online_fleet(keys, env, agent, states, T)
    assert g.counter.compiles <= 1

The counter works on anything with JAX's private-but-stable
``_cache_size()`` (every ``jax.jit`` wrapper in the pinned version);
callables without it are tracked as permanently-zero so ``guards`` never
hard-fails on an unexpected object.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Iterable, Sequence

import jax
import numpy as np


class NonFiniteError(RuntimeError):
    """A guarded fleet carry contained NaN/Inf at a chunk boundary."""


def _cache_size(fn) -> int:
    getter = getattr(fn, "_cache_size", None)
    return int(getter()) if callable(getter) else 0


class CompileCounter:
    """Counts fresh traces/compilations of tracked jitted callables.

    Reads each wrapper's trace-cache size on entry and on demand; the
    difference is the number of cache MISSES since the counter started —
    exactly the retraces a stable loop should not be paying.  Usable as a
    context manager or via explicit :meth:`start`.
    """

    def __init__(self, *targets, label: str = ""):
        self.targets = tuple(targets)
        self.label = label
        self._base: tuple[int, ...] | None = None

    def start(self) -> "CompileCounter":
        self._base = tuple(_cache_size(t) for t in self.targets)
        return self

    def __enter__(self) -> "CompileCounter":
        return self.start()

    def __exit__(self, *exc) -> None:
        return None

    @property
    def compiles(self) -> int:
        """Cache misses across all tracked callables since :meth:`start`."""
        if self._base is None:
            raise RuntimeError("CompileCounter not started")
        return sum(max(_cache_size(t) - b, 0)
                   for t, b in zip(self.targets, self._base))

    def per_target(self) -> dict[str, int]:
        if self._base is None:
            raise RuntimeError("CompileCounter not started")
        out: dict[str, int] = {}
        for i, (t, b) in enumerate(zip(self.targets, self._base)):
            name = getattr(t, "__name__", repr(t))
            if name in out:
                name = f"{name}#{i}"
            out[name] = max(_cache_size(t) - b, 0)
        return out

    def assert_compiles(self, expected: int, at_most: bool = False) -> None:
        got = self.compiles
        ok = got <= expected if at_most else got == expected
        if not ok:
            rel = "at most" if at_most else "exactly"
            raise AssertionError(
                f"jit-cache-miss sentinel{f' [{self.label}]' if self.label else ''}: "
                f"expected {rel} {expected} compilation(s), observed {got} "
                f"({self.per_target()}) — a changing static argument or a "
                f"re-constructed jit wrapper is defeating the trace cache")


@dataclasses.dataclass
class GuardState:
    """Live state of an active :func:`guards` region."""
    counter: CompileCounter
    nan_check: bool
    nonfinite: list[str] = dataclasses.field(default_factory=list)


_ACTIVE: contextvars.ContextVar[GuardState | None] = contextvars.ContextVar(
    "repro_diagnostics_guards", default=None)


def active() -> GuardState | None:
    """The innermost active guard region, or None."""
    return _ACTIVE.get()


@contextlib.contextmanager
def guards(transfer: str = "disallow",
           track: Sequence[Any] = (),
           nan_check: bool = True,
           label: str = ""):
    """Enable the runtime guard bundle for the enclosed region.

    ``transfer``  — ``jax.transfer_guard`` level ('allow', 'log',
                    'disallow').  'disallow' blocks IMPLICIT transfers
                    only; explicit ``np.asarray``/``device_get`` pulls
                    still work, so steady-state fleet loops run unchanged.
    ``track``     — jitted callables for the :class:`CompileCounter`.
    ``nan_check`` — arm :func:`maybe_check_finite` at chunk boundaries.

    Yields the :class:`GuardState`; its ``counter`` stays readable after
    the region exits.
    """
    state = GuardState(CompileCounter(*track, label=label).start(), nan_check)
    token = _ACTIVE.set(state)
    try:
        with jax.transfer_guard(transfer):
            yield state
    finally:
        _ACTIVE.reset(token)


def _leaf_name(path) -> str:
    return jax.tree_util.keystr(path)


def maybe_check_finite(tree, where: str = "") -> None:
    """Chunk-boundary NaN/Inf sweep — no-op unless a ``guards`` region
    with ``nan_check=True`` is active.

    Pulls floating leaves of ``tree`` to host (explicit d2h — legal under
    the transfer guard) and raises :class:`NonFiniteError` naming every
    non-finite leaf.  Fleet runners call this on the scan carries after
    each chunk, so a diverging lane is caught within ``checkpoint.every``
    epochs of the blow-up instead of surfacing as nonsense end-of-run
    traces.
    """
    state = _ACTIVE.get()
    if state is None or not state.nan_check:
        return
    bad: list[str] = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if isinstance(leaf, jax.Array) and not leaf.is_fully_addressable:
            # process-spanning shard (multi-host fleet): np.asarray would
            # need a collective; sweep only the rows this process owns
            arr = np.concatenate(
                [np.asarray(s.data).reshape(-1)
                 for s in leaf.addressable_shards])
        else:
            arr = np.asarray(leaf)
        if not np.issubdtype(arr.dtype, np.floating):
            continue
        if not np.isfinite(arr).all():
            n = int((~np.isfinite(arr)).sum())
            bad.append(f"{_leaf_name(path)} ({n}/{arr.size} non-finite)")
    if bad:
        state.nonfinite.extend(f"{where}: {b}" for b in bad)
        raise NonFiniteError(
            f"non-finite values in fleet carry at {where or 'chunk boundary'}: "
            + "; ".join(bad))
