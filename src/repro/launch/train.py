"""End-to-end training driver.

Binds: config → sharded init → deterministic data pipeline → jitted
microbatched train step → async checkpointing → heartbeat/straggler
monitoring.  On this container it runs real (small) models on the single
CPU device; on a cluster the same code path runs under the production
mesh (launch/mesh.py) with the sharding policy applied.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import AsyncCheckpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, PrefetchIterator, batch_at
from repro.fault.heartbeat import HeartbeatMonitor
from repro.fault.straggler import StragglerDetector
from repro.models import lm
from repro.train.trainer import TrainSetup, init_train_state, jitted_train_step


def run_training(cfg, setup: TrainSetup, steps: int, global_batch: int,
                 seq_len: int, ckpt_dir: str | None = None,
                 ckpt_every: int = 50, resume: bool = True,
                 log_every: int = 1, mesh=None, frames_fn=None) -> dict:
    key = jax.random.PRNGKey(0)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                          global_batch=global_batch)

    ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    start_step = 0
    state = init_train_state(cfg, setup, key)
    if ckpt and resume and ckpt.latest_step() is not None:
        state = ckpt.restore(state)
        start_step = int(state.step)
        print(f"resumed from step {start_step}")

    train_step = jitted_train_step(cfg, setup)
    monitor = HeartbeatMonitor(num_workers=jax.process_count())
    stragglers = StragglerDetector(num_workers=jax.process_count())

    it = PrefetchIterator(data_cfg, start_step=start_step)
    losses = []
    t_total0 = time.time()
    try:
        for step in range(start_step, steps):
            batch = next(it)
            if cfg.family == "vlm":
                P = cfg.frontend_positions
                B = batch["tokens"].shape[0]
                batch["frontend_embeds"] = jax.random.normal(
                    jax.random.fold_in(key, step), (B, P, cfg.d_model),
                    jnp.bfloat16) * 0.02
            if cfg.family == "encdec":
                B = batch["tokens"].shape[0]
                batch["frames"] = jax.random.normal(
                    jax.random.fold_in(key, step),
                    (B, seq_len, cfg.d_model), jnp.bfloat16) * 0.02
            t0 = time.time()
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            monitor.beat(jax.process_index())
            stragglers.observe(jax.process_index(), dt)
            losses.append(loss)
            if step % log_every == 0:
                print(f"step {step:5d}  loss {loss:8.4f}  "
                      f"gnorm {float(metrics['grad_norm']):7.3f}  {dt:6.2f}s",
                      flush=True)
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.save_async(step + 1, state)
    finally:
        it.close()
        if ckpt:
            ckpt.close()
    return {"losses": losses, "state": state,
            "total_s": time.time() - t_total0}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    setup = TrainSetup(micro_batches=args.micro, learning_rate=args.lr,
                       warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps)
    out = run_training(cfg, setup, args.steps, args.batch, args.seq,
                       ckpt_dir=args.ckpt_dir)
    print(f"final loss {out['losses'][-1]:.4f} "
          f"(first {out['losses'][0]:.4f}) in {out['total_s']:.1f}s")


if __name__ == "__main__":
    main()
