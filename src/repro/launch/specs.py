"""ShapeDtypeStruct stand-ins for every model input (dry-run §2) and the
per-cell training setup.

No device allocation happens here: batches, decode caches, and the full
train state (params + AdamW moments + EF residuals) are abstract shapes
that ``jax.jit(...).lower()`` consumes directly."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.models import lm
from repro.models.config import ModelConfig
from repro.train.trainer import TrainSetup, abstract_train_state

# cross-attention memory length used by enc-dec decode cells (the encoder
# side of seamless; independent of the 32k/500k self-cache stress length)
ENCDEC_MEMORY_LEN = 4096


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_setup(cfg: ModelConfig, shape: ShapeSpec) -> TrainSetup:
    """Per-cell training configuration (microbatching + optimizer dtypes)."""
    big = cfg.param_count() > 5e10
    return TrainSetup(
        micro_batches=8 if shape.global_batch >= 64 else 1,
        moment_dtype="bfloat16" if big else "float32",
    )


def input_specs(arch_id: str, shape_name: str, cfg: ModelConfig | None = None):
    """Returns (kind, abstract_args) for the cell's step function:

      train  -> {"batch": {tokens, targets[, frames | frontend_embeds]}}
      decode -> {"cache": <abstract cache>, "tokens": [B, 1]}
      prefill-> {"batch": like train (forward only)}
    """
    cfg = cfg or get_config(arch_id)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]

    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            batch = {
                "frames": _sds((B, S, cfg.d_model), dt),     # frontend STUB
                "tokens": _sds((B, S), jnp.int32),
                "targets": _sds((B, S), jnp.int32),
            }
        elif cfg.family == "vlm":
            P = cfg.frontend_positions
            batch = {
                "frontend_embeds": _sds((B, P, cfg.d_model), dt),  # CLIP STUB
                "tokens": _sds((B, S - P), jnp.int32),
                "targets": _sds((B, S - P), jnp.int32),
            }
        else:
            batch = {
                "tokens": _sds((B, S), jnp.int32),
                "targets": _sds((B, S), jnp.int32),
            }
        return shape.kind, {"batch": batch}

    # decode: one new token against a seq_len-deep cache
    enc_len = ENCDEC_MEMORY_LEN if cfg.family == "encdec" else 0
    cache = jax.eval_shape(
        lambda: lm.init_cache(cfg, batch=B, max_seq=S, enc_len=enc_len))
    tokens = _sds((B, 1), jnp.int32)
    return "decode", {"cache": cache, "tokens": tokens}


def abstract_state_for(cfg: ModelConfig, shape: ShapeSpec):
    return abstract_train_state(cfg, train_setup(cfg, shape))
