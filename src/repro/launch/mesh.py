"""Production mesh construction (multi-pod dry-run §1).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.  The single-pod mesh is 16×16 = 256 chips
(v5e pod); multi-pod adds a leading "pod" axis (2×16×16 = 512 chips)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1×1 mesh over the single real CPU device — used by smoke
    tests and examples so the same pjit code paths run un-sharded."""
    return jax.make_mesh((1, 1), ("data", "model"))
