"""Production mesh construction (multi-pod dry-run §1).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.  The single-pod mesh is 16×16 = 256 chips
(v5e pod); multi-pod adds a leading "pod" axis (2×16×16 = 512 chips)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1×1 mesh over the single real CPU device — used by smoke
    tests and examples so the same pjit code paths run un-sharded.  For
    the fleet runner this is the bit-comparability anchor: a
    ``run_online_fleet(..., mesh=make_host_mesh())`` run shards nothing,
    so its lanes match the plain vmap path."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_fleet_mesh(n_devices: int | None = None):
    """Data-only mesh over the host's visible devices for fleet sharding:
    shape ``(n, 1)`` over ``("data", "model")``, so the fleet axis of a
    ``run_online_fleet(..., mesh=...)`` call partitions over all ``n``
    devices while the "model" axis stays degenerate (control-policy nets
    are tiny; lanes, not layers, are what need the memory).  Defaults to
    every visible device — on a single-device host this degenerates to
    :func:`make_host_mesh`."""
    n = len(jax.devices()) if n_devices is None else n_devices
    return jax.make_mesh((n, 1), ("data", "model"))
