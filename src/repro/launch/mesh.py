"""Mesh construction + multi-process (multi-host) initialization.

Everything here is a FUNCTION, not a module-level constant: importing
this module never touches jax device state.

Three mesh families:

* :func:`make_production_mesh` — the accelerator training mesh, derived
  from ``jax.device_count()`` (documented pod shapes — 16×16 single pod,
  2×16×16 multi-pod — when enough chips are visible, the largest
  (data, model) grid that fits otherwise);
* :func:`make_host_mesh` — the degenerate 1×1 CPU mesh that anchors the
  bit-comparability tests;
* :func:`make_fleet_mesh` — the data-only ``(n, 1)`` mesh the fleet
  runner shards scenario lanes over.  With ``spanning=True`` the mesh
  spans EVERY process of a ``jax.distributed`` job — the multi-host
  mega-fleet axis (docs/sharded_fleets.md#multi-host-fleets).

:func:`init_distributed` is the process-spanning entry point: call it
first thing in every worker process (before any other jax API touches
the backend), then build spanning meshes.  Single-process calls are a
no-op, so the same launcher script runs unmodified on one host."""
from __future__ import annotations

import os

import jax
import numpy as np

# env vars the localhost driver (repro.launch.multihost) sets for its
# workers; real clusters can export the same three variables
COORDINATOR_ENV = "REPRO_COORDINATOR"
NUM_PROCESSES_ENV = "REPRO_NUM_PROCESSES"
PROCESS_ID_ENV = "REPRO_PROCESS_ID"

_DISTRIBUTED = {"initialized": False}


def init_distributed(coordinator_address: str | None = None,
                     num_processes: int | None = None,
                     process_id: int | None = None) -> tuple[int, int]:
    """Join (or skip) a multi-process jax job; returns (process_id, n).

    Arguments default to the ``REPRO_COORDINATOR`` /
    ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID`` environment variables
    (what ``repro.launch.multihost`` exports for its localhost workers).
    With no coordinator configured — or ``num_processes <= 1`` — this is
    a NO-OP returning ``(0, 1)``: the same worker script runs
    single-process without edits, which is also what keeps the
    CI-executed docs snippet runnable.

    Must be called BEFORE anything else initializes the jax backend.  On
    the CPU backend the cross-process collectives implementation is
    switched to gloo (the default, ``"none"``, refuses multi-process
    computations outright).  Idempotent: a second call returns the
    current (process_index, process_count) without re-initializing."""
    if _DISTRIBUTED["initialized"]:
        return jax.process_index(), jax.process_count()
    env = os.environ
    if coordinator_address is None:
        coordinator_address = env.get(COORDINATOR_ENV)
    if num_processes is None and env.get(NUM_PROCESSES_ENV):
        num_processes = int(env[NUM_PROCESSES_ENV])
    if process_id is None and env.get(PROCESS_ID_ENV):
        process_id = int(env[PROCESS_ID_ENV])
    if coordinator_address is None or (num_processes or 1) <= 1:
        return 0, 1
    # CPU cross-process computations need a real collectives backend
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=int(num_processes),
                               process_id=int(process_id))
    _DISTRIBUTED["initialized"] = True
    return jax.process_index(), jax.process_count()


def make_production_mesh(*, multi_pod: bool = False):
    """The accelerator training mesh, sized to the visible devices.

    With a full pod (256+ chips) this is the documented v5e shape —
    16×16 = 256 over ``("data", "model")``, or 2×16×16 = 512 with a
    leading "pod" axis when ``multi_pod`` — and on anything smaller it
    degrades to the largest (data, model) grid that fits
    (``fault.elastic.plan_mesh``: model-parallel width halves until it
    divides, data takes the rest), so a laptop or CI host gets a 1×1
    mesh instead of a crash."""
    from repro.fault.elastic import plan_mesh
    plan = plan_mesh(jax.device_count(), model_parallel=16,
                     multi_pod=multi_pod)
    return jax.make_mesh(plan.shape, plan.axes)


def make_host_mesh():
    """Degenerate 1×1 mesh over the single real CPU device — used by smoke
    tests and examples so the same pjit code paths run un-sharded.  For
    the fleet runner this is the bit-comparability anchor: a
    ``run_online_fleet(..., mesh=make_host_mesh())`` run shards nothing,
    so its lanes match the plain vmap path."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_fleet_mesh(n_devices: int | None = None, *, spanning: bool = False):
    """Data-only mesh for fleet sharding: shape ``(n, 1)`` over
    ``("data", "model")``, so the fleet axis of a ``run_online_fleet(...,
    mesh=...)`` call partitions over all ``n`` devices while the "model"
    axis stays degenerate (control-policy nets are tiny; lanes, not
    layers, are what need the memory).

    ``spanning=False`` (default) uses this PROCESS's devices — on a
    single-process job that is every visible device, identical to the
    pre-multi-host behavior.  ``spanning=True`` builds the mesh over the
    GLOBAL device list of a ``jax.distributed`` job
    (:func:`init_distributed`): an ``(n_hosts * devices_per_host, 1)``
    data mesh every process participates in — each process then feeds
    and reads only its addressable shard of the fleet carries
    (``sharding/fleet.py`` handles the global placement).  In a
    single-process job ``spanning=True`` degenerates to the local mesh,
    so the same code path runs everywhere."""
    devices = list(jax.devices()) if spanning else list(jax.local_devices())
    n = len(devices) if n_devices is None else int(n_devices)
    mesh_devices = np.asarray(devices[:n]).reshape(n, 1)
    return jax.sharding.Mesh(mesh_devices, ("data", "model"))
