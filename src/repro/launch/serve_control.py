"""Serving control-plane launcher: batched decisions for many clusters.

Builds a :class:`~repro.serve.control.ControlService` over the requested
decision kinds (``core/spaces.py`` action spaces — placement is served by
a fresh or supplied agent, rate_control / auto_tune by their registered
policy agents), registers ``--clusters`` perturbed live clusters
(``dsdps.scenarios.sample_perturbed``), drives a synthetic request load
through it, and reports per-kind p50/p99 decision latency and
decisions/sec.  ``--guards`` runs steady-state serving under the runtime
tracing-discipline guards with a CompileCounter assertion that NO
recompilation happens after warmup.

  PYTHONPATH=src python -m repro.launch.serve_control --app cq_small \\
      --clusters 6 --requests 48 --slots 8 --guards
  PYTHONPATH=src python -m repro.launch.serve_control \\
      --kinds placement,rate_control --clusters 3 --requests 24

``drl_control --serve N`` reuses :func:`build_service` /
:func:`synthetic_requests` to serve N decisions from the freshly TRAINED
policy, with each training lane's scenario registered as a cluster."""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import numpy as np

from repro.core import make_agent, spaces
from repro.dsdps import SchedulingEnv, apps, scenarios
from repro.dsdps.apps import default_workload
from repro.serve.control import ControlPlane, ControlService, DecisionRequest

DEFAULT_KINDS = ("placement", "rate_control", "auto_tune")


def build_service(env, kinds=DEFAULT_KINDS, n_slots: int = 8, seed: int = 0,
                  placement_agent=None, placement_state=None,
                  donate: bool | None = None) -> ControlService:
    """One plane per decision kind; each kind's registered default agent,
    except ``placement`` which may be served by a supplied (trained)
    agent + state."""
    planes = {}
    for kind in kinds:
        space = spaces.action_space(kind)
        if kind == "placement" and placement_agent is not None:
            ag, st = placement_agent, placement_state
        else:
            overrides = {"k_nn": 8} if space.default_agent == "ddpg" else {}
            ag = make_agent(space.default_agent, env, **overrides)
            st = ag.init(jax.random.PRNGKey(seed))
        planes[kind] = ControlPlane(env, ag, st, kind=kind, n_slots=n_slots,
                                    explore=False, donate=donate)
    return ControlService(planes)


def synthetic_requests(env, svc: ControlService, n_requests: int,
                       seed: int = 0) -> list[DecisionRequest]:
    """A request mix round-robining over the service's clusters and
    kinds: random feasible assignments + lognormal-jittered spout loads,
    encoded exactly as ``SchedulingEnv.state_vector`` would."""
    rng = np.random.default_rng(seed)
    kinds = svc.kinds
    names = svc.planes[kinds[0]].clusters
    reqs = []
    for rid in range(n_requests):
        X = np.eye(env.M, dtype=np.float32)[rng.integers(0, env.M, env.N)]
        w_norm = np.exp(rng.normal(0.0, 0.25, env.workload.num_spouts))
        s_vec = np.concatenate([X.reshape(-1),
                                w_norm.astype(np.float32)])
        reqs.append(DecisionRequest(rid=rid,
                                    cluster=names[rid % len(names)],
                                    s_vec=s_vec,
                                    kind=kinds[rid % len(kinds)]))
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="cq_small", choices=list(apps.ALL_APPS))
    ap.add_argument("--kinds", default=",".join(DEFAULT_KINDS),
                    help="comma-separated decision kinds "
                         f"(registered: {spaces.action_space_names()})")
    ap.add_argument("--clusters", type=int, default=4,
                    help="live clusters to register (perturbed scenarios)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4,
                    help="batch slots per decision plane")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--guards", action="store_true",
                    help="serve the steady state under the runtime "
                         "tracing-discipline guards + assert zero "
                         "post-warmup recompilation")
    args = ap.parse_args()
    kinds = tuple(k for k in args.kinds.split(",") if k)
    for k in kinds:
        if k not in spaces.action_space_names():
            ap.error(f"unknown decision kind {k!r}; "
                     f"registered: {spaces.action_space_names()}")
    if args.clusters < 1 or args.requests < 1:
        ap.error("--clusters and --requests must be >= 1")

    topo = apps.ALL_APPS[args.app]()
    env = SchedulingEnv(topo, default_workload(topo))
    svc = build_service(env, kinds, n_slots=args.slots, seed=args.seed)
    key = jax.random.PRNGKey(args.seed)
    for c in range(args.clusters):
        key, k = jax.random.split(key)
        svc.register_cluster(f"cluster-{c}",
                             scenarios.sample_perturbed(env, k))
    print(f"serving {len(kinds)} decision kind(s) {list(kinds)} for "
          f"{args.clusters} clusters, {args.slots} slots/plane ...")

    reqs = synthetic_requests(env, svc, args.requests, seed=args.seed)
    for r in reqs:
        svc.submit(r)
    key, k_warm = jax.random.split(key)
    warm = svc.step(k_warm)              # warmup: one compile per plane
    if args.guards:
        from repro.diagnostics import guards
        region = guards(track=svc.programs(), label="serve_control")
    else:
        region = contextlib.nullcontext(None)
    t0 = time.perf_counter()
    with region as g:
        served = svc.run(key)
    wall = time.perf_counter() - t0
    if g is not None:
        g.counter.assert_compiles(0)
        print("guards: clean — steady-state serving recompiled nothing, "
              "no implicit transfers")

    steady = len(served) - len(warm)
    print(f"served {len(served)}/{args.requests} decisions "
          f"({steady} post-warmup in {wall * 1e3:.1f} ms = "
          f"{steady / wall:.0f} decisions/sec)")
    for kind, stats in svc.decision_stats().items():
        print(f"  {kind:13s} n={stats['n']:4d}  "
              f"p50 {stats['p50_ms']:8.3f} ms  "
              f"p99 {stats['p99_ms']:8.3f} ms  "
              f"mean {stats['mean_ms']:8.3f} ms")


if __name__ == "__main__":
    main()
