import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first init, and the production meshes need 512 placeholder
# devices (dry-run ONLY — smoke tests and benches see the 1 real device).

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) cell:
  jit(step, in_shardings, out_shardings).lower(<abstract args>).compile()
must succeed; we record memory_analysis() (fits-in-HBM proof),
cost_analysis() (FLOPs/bytes for the roofline), and the collective
schedule parsed from the optimized HLO (bytes per collective kind).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all --mesh single
  python -m repro.launch.dryrun --list

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json (incremental:
existing artifacts are skipped unless --force)."""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# -- collective parsing -------------------------------------------------------
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# wire-bytes-per-device conventions (ring algorithms, n→large):
#   all-reduce of shard s      -> 2s        all-gather to size g -> g
#   reduce-scatter of input s  -> s         all-to-all of s      -> s
#   collective-permute of s    -> s
_WIRE_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}


def parse_collectives(hlo_text: str) -> dict:
    by_kind: dict[str, dict] = {}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        d = by_kind.setdefault(kind, {"count": 0, "result_bytes": 0,
                                      "wire_bytes": 0.0})
        d["count"] += 1
        d["result_bytes"] += b
        d["wire_bytes"] += b * _WIRE_FACTOR[kind]
    return by_kind


# -- per-cell dry run ---------------------------------------------------------
def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             overrides: dict | None = None) -> dict:
    from repro.configs import SHAPES, cell_enabled, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import abstract_state_for, input_specs, train_setup
    from repro.models import lm
    from repro.sharding.policy import ShardingPolicy
    from repro.train.trainer import make_train_step

    cfg = get_config(arch_id)
    if overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **{k: v for k, v in overrides.items()
                                  if hasattr(cfg, k)})
    shape = SHAPES[shape_name]
    ok, why = cell_enabled(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    fsdp = True
    if overrides and "fsdp" in overrides:
        fsdp = bool(overrides["fsdp"])
    policy = ShardingPolicy(mesh, cfg, fsdp=fsdp)
    kind, args = input_specs(arch_id, shape_name, cfg)

    from repro.sharding import ctx
    t0 = time.time()
    with mesh, ctx.use_mesh(mesh):
        if kind == "prefill":
            # inference prefill: forward-only, emits the prompt KV cache
            from jax.sharding import NamedSharding, PartitionSpec as P
            step_fn = lm.prefill_forward(cfg)
            params = lm.abstract_params(cfg)
            params_sh = policy.params_sharding(params)
            batch_sh = policy.batch_sharding(args["batch"])
            # AOT lowering: the wrapper exists only to .lower().compile()
            # once per dry-run cell — per-call construction is the point
            jitted = jax.jit(  # jaxguard: disable=JG002
                step_fn, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params, args["batch"])
        elif kind == "train":
            setup = train_setup(cfg, shape)
            if overrides and "micro_batches" in overrides:
                import dataclasses as _dc
                setup = _dc.replace(setup, micro_batches=overrides["micro_batches"])
            if overrides and "compress_grads" in overrides:
                import dataclasses as _dc
                setup = _dc.replace(setup, compress_grads=overrides["compress_grads"])
            from repro.train.trainer import abstract_train_state
            state = abstract_train_state(cfg, setup)
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.train import optimizer as opt_lib
            from repro.train.trainer import TrainState

            # the full train state mirrors the parameter specs: AdamW
            # moments and EF residuals shard exactly like their parameters
            params_sh = policy.params_sharding(state.params)
            ef_sh = jax.tree.map(
                lambda s, l: NamedSharding(mesh, P()) if l.ndim == 0 else s,
                params_sh, state.ef_residual)
            state_sharding = TrainState(
                step=NamedSharding(mesh, P()),
                params=params_sh,
                opt=opt_lib.AdamState(step=NamedSharding(mesh, P()),
                                      mu=params_sh, nu=params_sh),
                ef_residual=ef_sh,
            )
            batch_sh = policy.batch_sharding(args["batch"])
            step_fn = make_train_step(cfg, setup)
            jitted = jax.jit(step_fn,  # jaxguard: disable=JG002 (AOT lowering)
                             in_shardings=(state_sharding, batch_sh),
                             out_shardings=(state_sharding, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, args["batch"])
        else:  # decode
            step_fn = lm.serve_step(cfg)
            params = lm.abstract_params(cfg)
            params_sh = policy.params_sharding(params)
            cache_sh = policy.cache_sharding(args["cache"])
            from jax.sharding import NamedSharding, PartitionSpec as P
            tok_sh = NamedSharding(
                mesh, P(policy.axes.dp
                        if args["tokens"].shape[0] % policy.dp_size == 0
                        else None, None))
            logits_sh = None
            jitted = jax.jit(step_fn,  # jaxguard: disable=JG002 (AOT lowering)
                             in_shardings=(params_sh, cache_sh, tok_sh),
                             out_shardings=(logits_sh, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params, args["cache"], args["tokens"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    # trip-count-corrected analysis (XLA counts while bodies once; scans
    # would otherwise undercount by microbatch × layer trip counts)
    try:
        from benchmarks.hlo_analysis import analyze as hlo_analyze
        corrected = hlo_analyze(hlo)
    except Exception as e:  # pragma: no cover
        corrected = {"error": f"{type(e).__name__}: {e}"}

    # keep the compressed HLO for offline re-analysis (§Perf iterations)
    try:
        import zstandard as zstd
        hlo_path = ART_DIR / "hlo"
        hlo_path.mkdir(parents=True, exist_ok=True)
        name = f"{arch_id}__{shape_name}__{mesh_kind}"
        if overrides:
            name += "__" + "-".join(f"{k}={v}" for k, v in sorted(overrides.items()))
        (hlo_path / f"{name}.hlo.zst").write_bytes(
            zstd.ZstdCompressor(level=3).compress(hlo.encode()))
    except Exception:
        pass

    result = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_kind,
        "kind": kind,
        "status": "ok",
        "devices": int(jax.device_count()) if mesh_kind == "multi" else 256,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "overrides": overrides or {},
        # per-device numbers (XLA reports per-participant in SPMD)
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_est": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "collectives": coll,
        "collective_wire_bytes_per_device": sum(
            d["wire_bytes"] for d in coll.values()),
        # trip-count-corrected (benchmarks/hlo_analysis.py) — use THESE for
        # the roofline; raw cost_analysis counts while bodies once
        "corrected": corrected,
        "param_count": cfg.param_count(),
        "param_count_active": cfg.param_count(active_only=True),
    }
    return result


def cell_path(arch_id: str, shape_name: str, mesh_kind: str,
              tag: str = "") -> pathlib.Path:
    suffix = f"__{tag}" if tag else ""
    return ART_DIR / f"{arch_id}__{shape_name}__{mesh_kind}{suffix}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix for perf expts")
    ap.add_argument("--override", default="",
                    help="k=v,... ModelConfig/TrainSetup overrides (perf expts)")
    args = ap.parse_args()

    from repro.configs import all_cells

    if args.list:
        for a, s, ok, why in all_cells(include_skipped=True):
            print(f"{a:26s} {s:12s} {'RUN' if ok else 'SKIP  ' + why}")
        return

    overrides = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        overrides[k] = (v == "True" if v in ("True", "False")
                        else int(v) if v.isdigit() else v)

    cells = []
    if args.all:
        cells = [(a, s) for a, s, ok, _ in all_cells() if ok]
    else:
        cells = [(args.arch, args.shape)]

    ART_DIR.mkdir(parents=True, exist_ok=True)
    for arch_id, shape_name in cells:
        out = cell_path(arch_id, shape_name, args.mesh, args.tag)
        if out.exists() and not args.force:
            print(f"SKIP (cached) {out.name}")
            continue
        print(f"=== {arch_id} × {shape_name} × {args.mesh} ===", flush=True)
        try:
            res = run_cell(arch_id, shape_name, args.mesh, overrides or None)
        except Exception as e:  # record failures — they are bugs to fix
            res = {"arch": arch_id, "shape": shape_name, "mesh": args.mesh,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        out.write_text(json.dumps(res, indent=2))
        status = res["status"]
        if status == "ok":
            gb = res["memory"]["peak_bytes_est"] / 2**30
            print(f"  ok: {res['flops_per_device']:.3e} flops/dev, "
                  f"peak {gb:.2f} GiB/dev, "
                  f"coll {res['collective_wire_bytes_per_device']:.3e} B/dev, "
                  f"compile {res['compile_s']}s", flush=True)
        else:
            print(f"  {status}: {res.get('error', res.get('reason'))}", flush=True)


if __name__ == "__main__":
    main()
