"""The paper's control loop as a launcher: train the DRL scheduler on a
DSDPS topology (or the TPU expert-placement env) and report the schedule.

  PYTHONPATH=src python -m repro.launch.drl_control --app cq_small \
      --offline 2000 --epochs 300
  PYTHONPATH=src python -m repro.launch.drl_control --app placement
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import (DDPGConfig, ddpg_init, run_online_ddpg,
                        jamba_placement_env, round_robin)
from repro.core.ddpg import offline_pretrain
from repro.dsdps import SchedulingEnv, apps
from repro.dsdps.apps import default_workload


def build_env(app: str):
    if app == "placement":
        return jamba_placement_env()
    topo = apps.ALL_APPS[app]()
    return SchedulingEnv(topo, default_workload(topo))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="cq_small",
                    choices=list(apps.ALL_APPS) + ["placement"])
    ap.add_argument("--offline", type=int, default=2000,
                    help="offline random-action samples (paper: 10,000)")
    ap.add_argument("--offline-updates", type=int, default=500)
    ap.add_argument("--epochs", type=int, default=300)
    ap.add_argument("--k", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    env = build_env(args.app)
    cfg = DDPGConfig(n_executors=env.N, n_machines=env.M,
                     state_dim=env.state_dim, k_nn=args.k)
    key = jax.random.PRNGKey(args.seed)
    state = ddpg_init(key, cfg)

    print(f"offline pretraining on {args.offline} random transitions ...")
    state = offline_pretrain(jax.random.fold_in(key, 1), state, cfg, env,
                             n_samples=args.offline,
                             n_updates=args.offline_updates)

    print(f"online learning for {args.epochs} decision epochs ...")
    state, hist = run_online_ddpg(jax.random.fold_in(key, 2), env, cfg,
                                  state, T=args.epochs)

    w = (env.workload.init() if hasattr(env, "workload")
         else env._base_load)
    final = float(env.evaluate(jnp.asarray(hist.final_assignment), w))
    rr = float(env.evaluate(env.round_robin_assignment(), w))
    print(f"\nfinal latency {final:.3f} ms   round-robin {rr:.3f} ms   "
          f"improvement {1 - final / rr:.1%}")
    print("assignment (executor -> machine):",
          hist.final_assignment.argmax(-1).tolist())


if __name__ == "__main__":
    main()
