"""The paper's control loop as a launcher: train the DRL scheduler on a
DSDPS topology (or the TPU expert-placement env) and report the schedule.

Online learning runs as a FLEET: ``--fleet N`` independent seeds execute
in one jitted, vmapped scan (core/agent.run_online_fleet) and the final
latency is reported as mean ± std across seeds, with the best lane's
assignment printed.

  PYTHONPATH=src python -m repro.launch.drl_control --app cq_small \
      --offline 2000 --epochs 300 --fleet 8
  PYTHONPATH=src python -m repro.launch.drl_control --app placement
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DDPGConfig, jamba_placement_env, run_online_fleet
from repro.core import ddpg as ddpg_lib
from repro.dsdps import SchedulingEnv, apps
from repro.dsdps.apps import default_workload


def build_env(app: str):
    if app == "placement":
        return jamba_placement_env()
    topo = apps.ALL_APPS[app]()
    return SchedulingEnv(topo, default_workload(topo))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="cq_small",
                    choices=list(apps.ALL_APPS) + ["placement"])
    ap.add_argument("--offline", type=int, default=2000,
                    help="offline random-action samples (paper: 10,000)")
    ap.add_argument("--offline-updates", type=int, default=500)
    ap.add_argument("--epochs", type=int, default=300)
    ap.add_argument("--fleet", type=int, default=4,
                    help="independent online-learning seeds, batched in one "
                         "XLA program")
    ap.add_argument("--k", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.fleet < 1:
        ap.error("--fleet must be >= 1")

    env = build_env(args.app)
    cfg = DDPGConfig(n_executors=env.N, n_machines=env.M,
                     state_dim=env.state_dim, k_nn=args.k)
    key = jax.random.PRNGKey(args.seed)
    states = ddpg_lib.init_fleet(key, cfg, args.fleet)

    print(f"offline pretraining {args.fleet} lanes on {args.offline} "
          f"random transitions each ...")
    states = ddpg_lib.offline_pretrain_fleet(
        jax.random.split(jax.random.fold_in(key, 1), args.fleet),
        states, cfg, env,
        n_samples=args.offline, n_updates=args.offline_updates)

    print(f"online learning: fleet of {args.fleet} x {args.epochs} decision "
          f"epochs in one batched scan ...")
    states, hist = run_online_fleet(
        jax.random.split(jax.random.fold_in(key, 2), args.fleet),
        env, cfg, states, T=args.epochs)

    w = (env.workload.init() if hasattr(env, "workload")
         else env._base_load)
    finals = np.asarray([
        float(env.evaluate(jnp.asarray(hist.final_assignment[f]), w))
        for f in range(args.fleet)])
    rr = float(env.evaluate(env.round_robin_assignment(), w))
    best = int(finals.argmin())
    print(f"\nfinal latency {finals.mean():.3f} ± {finals.std():.3f} ms "
          f"over {args.fleet} seeds (best {finals.min():.3f} ms)   "
          f"round-robin {rr:.3f} ms   "
          f"improvement {1 - finals.mean() / rr:.1%} mean / "
          f"{1 - finals.min() / rr:.1%} best")
    print("best assignment (executor -> machine):",
          hist.final_assignment[best].argmax(-1).tolist())


if __name__ == "__main__":
    main()
