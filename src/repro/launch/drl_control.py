"""The paper's control loop as a launcher: train a registry agent on a
DSDPS topology (or the TPU expert-placement env) and report the schedule.

Online learning runs as a FLEET: ``--fleet N`` independent lanes execute
in one jitted, vmapped scan (core/agent.run_online_fleet) and the final
latency is reported as mean ± std across lanes, with the best lane's
assignment printed.  ``--agent`` picks any registered control policy
(core.api.make_agent) and ``--scenario`` swaps the pure seed sweep for a
named heterogeneous params fleet — per-lane workload rates / stragglers /
noise in the same single program.  All scenario construction routes
through ``repro.dsdps.scenarios.build_for``, which also dispatches the
TPU expert-placement env's PlacementParams scenarios
(``--app placement --scenario one_slow_device``).  Agents initialize
under their lane's scenario (the model-based baseline profiles and fits
the lane's cluster — lane-correct speeds/services/noise, not the nominal
profile), and ``--broadcast-invariant`` keeps scenario-invariant params
leaves single-copy (per-leaf in_axes=None broadcasting).

Production scale-out: ``--sharded`` partitions the fleet axis over every
visible device (``launch.mesh.make_fleet_mesh``, shard_map under the
hood), and ``--checkpoint-dir DIR`` snapshots the fleet carries
asynchronously + atomically every ``--checkpoint-every`` epochs; a killed
run restarted with ``--resume`` picks up from the newest checkpoint,
re-placed against the current mesh (device counts may differ between
save and restore).  See docs/sharded_fleets.md.

Budget-aware fleets (docs/elastic_fleets.md): ``--early-stop`` attaches
the elastic lane lifecycle — lanes whose smoothed reward plateaus stop
early and the fleet compacts so converged scenarios stop paying compute —
and ``--scenario-search`` swaps training for a successive-halving search
over perturbed scenarios (wide fleet, bottom half pruned at each rung,
freed lanes refilled), writing the ranked leaderboard to
``--search-json``.

  PYTHONPATH=src python -m repro.launch.drl_control --app cq_small \
      --offline 2000 --epochs 300 --fleet 8
  PYTHONPATH=src python -m repro.launch.drl_control --app cq_small \
      --agent model_based --scenario one_slow_machine --fleet 4
  PYTHONPATH=src python -m repro.launch.drl_control --app placement \
      --scenario one_slow_device
  PYTHONPATH=src python -m repro.launch.drl_control --app cq_small \
      --fleet 8 --sharded --checkpoint-dir /tmp/fleet_ck --resume
  PYTHONPATH=src python -m repro.launch.drl_control --app cq_small \
      --scenario-search --fleet 8 --search-rungs 16,16,32
  PYTHONPATH=src python -m repro.launch.drl_control --app structural \
      --agent graph_policy --scenario dag_shapes --fleet 6
"""
from __future__ import annotations

import argparse
import contextlib
import sys

from repro.launch.mesh import init_distributed, make_fleet_mesh

if "--distributed" in sys.argv:
    # jax.distributed.initialize must run before ANY jax computation, and
    # some agent modules build jnp defaults at import time — so the
    # coordinator handshake happens here, ahead of the heavy imports
    # below (launch.mesh itself never touches device state on import)
    init_distributed()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (agent_names, jamba_placement_env, make_agent,
                        reset_fleet_states, run_online_fleet)
from repro.core import ddpg as ddpg_lib
from repro.core.placement import PLACEMENT_SCENARIOS
from repro.checkpoint.fleet import FleetCheckpoint
from repro.dsdps import (SchedulingEnv, StructuralSchedulingEnv, apps,
                         lane_params, scenarios)
from repro.dsdps.apps import default_workload
from repro.sharding.fleet import fleet_size


def build_env(app: str):
    if app == "placement":
        return jamba_placement_env()
    if app == "structural":
        # chain / diamond / wide-fanout padded into one envelope: the
        # DAG-shape fleet (--scenario dag_shapes varies topology per lane)
        return StructuralSchedulingEnv(apps.structural_topologies())
    topo = apps.ALL_APPS[app]()
    return SchedulingEnv(topo, default_workload(topo))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="cq_small",
                    choices=list(apps.ALL_APPS) + ["placement", "structural"],
                    help="one Storm topology, the TPU expert-placement env, "
                         "or 'structural' — the envelope-padded DAG-shape "
                         "env over apps.STRUCTURAL_APPS (pairs with "
                         "--agent graph_policy / --scenario dag_shapes)")
    ap.add_argument("--agent", default="ddpg", choices=list(agent_names()),
                    help="registered control policy (core.api.make_agent)")
    ap.add_argument("--scenario", default=None,
                    choices=sorted(set(scenarios.SCENARIOS)
                                   | set(scenarios.STRUCTURAL_SCENARIOS)
                                   | set(PLACEMENT_SCENARIOS)),
                    help="heterogeneous params fleet instead of a pure "
                         "seed sweep (EnvParams for DSDPS apps, "
                         "PlacementParams for --app placement; the "
                         "structure-varying dag_shapes needs "
                         "--app structural)")
    ap.add_argument("--broadcast-invariant", action="store_true",
                    help="keep scenario-invariant params leaves single-copy "
                         "(per-leaf in_axes=None broadcast in the vmap)")
    ap.add_argument("--offline", type=int, default=2000,
                    help="offline random-action samples (paper: 10,000; "
                         "ddpg only)")
    ap.add_argument("--offline-updates", type=int, default=500)
    ap.add_argument("--epochs", type=int, default=300)
    ap.add_argument("--fleet", type=int, default=4,
                    help="independent online-learning lanes, batched in one "
                         "XLA program")
    ap.add_argument("--k", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sharded", action="store_true",
                    help="partition the fleet axis over every visible "
                         "device (launch.mesh.make_fleet_mesh + shard_map); "
                         "--fleet must be a multiple of the device count")
    ap.add_argument("--distributed", action="store_true",
                    help="multi-host fleet: join a jax.distributed job "
                         "(coordinator/rank from REPRO_COORDINATOR / "
                         "REPRO_NUM_PROCESSES / REPRO_PROCESS_ID, see "
                         "launch.mesh.init_distributed) and shard the "
                         "fleet over a PROCESS-SPANNING mesh; every "
                         "process runs this same command "
                         "(repro.launch.multihost spawns localhost jobs)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for async atomic fleet checkpoints "
                         "(FleetCheckpoint); enables crash recovery")
    ap.add_argument("--checkpoint-every", type=int, default=50,
                    help="checkpoint cadence in decision epochs")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest checkpoint in "
                         "--checkpoint-dir (re-placed against the current "
                         "mesh) instead of starting fresh")
    ap.add_argument("--early-stop", action="store_true",
                    help="elastic lane lifecycle: stop lanes whose smoothed "
                         "reward plateaus and compact the fleet so "
                         "converged scenarios stop paying compute "
                         "(repro.fleet.lifecycle, docs/elastic_fleets.md)")
    ap.add_argument("--scenario-search", action="store_true",
                    help="successive-halving search over perturbed "
                         "scenarios instead of training: --fleet "
                         "candidates seeded from --scenario (default "
                         "mixed), bottom half pruned at each rung, freed "
                         "lanes refilled; prints and saves the ranked "
                         "leaderboard")
    ap.add_argument("--search-rungs", default="16,16,32",
                    help="comma-separated epochs per successive-halving "
                         "rung")
    ap.add_argument("--search-json", default="artifacts/scenario_search.json",
                    help="leaderboard artifact path for --scenario-search")
    ap.add_argument("--serve", type=int, default=0, metavar="N",
                    help="after training, serve N synthetic decision "
                         "requests from the best lane's trained policy "
                         "through the batched serving control plane — "
                         "every training lane's scenario becomes a "
                         "registered cluster (repro.serve.control, "
                         "docs/serving.md)")
    ap.add_argument("--guards", action="store_true",
                    help="run the online-learning phase under the runtime "
                         "tracing-discipline guards (repro.diagnostics): "
                         "implicit-transfer guard, jit-cache-miss sentinel, "
                         "chunk-boundary NaN/Inf sweeps "
                         "(docs/static_analysis.md)")
    args = ap.parse_args()
    if args.fleet < 1:
        ap.error("--fleet must be >= 1")
    if args.distributed:
        if args.serve:
            ap.error("--serve drives a single-process control plane; run "
                     "it without --distributed")
        if args.scenario_search:
            ap.error("--scenario-search runs its own single-process rung "
                     "fleets; drop --distributed")
        # already joined at import time (see module top); a no-op
        # single-process run (no coordinator configured) degrades to
        # --sharded over the local devices.  Idempotent re-call keeps
        # programmatic main() invocations honest too.
        init_distributed()
        if jax.process_index() != 0:
            # one report per job: non-zero ranks run the same program but
            # stay quiet (their results are identical by construction)
            import os
            import sys
            sys.stdout = open(os.devnull, "w")
    if args.agent == "model_based" and args.app == "placement":
        ap.error("model_based profiles a DSDPS cluster; use it with the "
                 "Storm apps")
    if args.agent == "graph_policy" and args.app == "placement":
        ap.error("graph_policy message-passes over a topology DAG; use it "
                 "with the Storm apps or --app structural")
    if args.agent in ("rate_control", "auto_tune"):
        ap.error(f"{args.agent} is a serving-side decision policy (its "
                 f"actions are not placements) — it runs behind the "
                 f"serving control plane: repro.launch.serve_control, or "
                 f"--serve N after training (docs/serving.md)")
    if args.serve and args.app == "placement":
        ap.error("--serve drives the DSDPS control plane; use it with the "
                 "Storm apps")
    if args.serve and args.agent not in ("ddpg", "round_robin"):
        ap.error(f"--serve needs an agent that decides from (s_vec, "
                 f"cluster params) alone; {args.agent}'s select reads the "
                 f"live EnvState (see docs/serving.md)")
    if args.scenario_search:
        for flag, on in (("--sharded", args.sharded),
                         ("--checkpoint-dir", args.checkpoint_dir),
                         ("--resume", args.resume),
                         ("--early-stop", args.early_stop)):
            if on:
                ap.error(f"--scenario-search does not support {flag}: the "
                         f"search runs its own un-sharded, un-checkpointed "
                         f"rung fleets (--offline/--epochs are ignored too "
                         f"— rung lengths come from --search-rungs)")

    env = build_env(args.app)
    if args.scenario and args.scenario not in scenarios.scenario_names(env):
        ap.error(f"scenario {args.scenario!r} is not defined for "
                 f"--app {args.app}; "
                 f"known: {scenarios.scenario_names(env)}")
    overrides = {"k_nn": args.k} if args.agent == "ddpg" else {}
    agent = make_agent(args.agent, env, **overrides)
    key = jax.random.PRNGKey(args.seed)

    if args.scenario_search:
        from repro.fleet.lifecycle import search_scenarios
        rungs = tuple(int(x) for x in args.search_rungs.split(",") if x)
        if args.fleet < 2:
            ap.error("--scenario-search needs --fleet >= 2")
        print(f"successive-halving scenario search: {args.fleet} candidates "
              f"seeded from {args.scenario or 'mixed'!r}, rungs {rungs} ...")
        lb = search_scenarios(env, agent,
                              scenario=args.scenario or "mixed",
                              fleet=args.fleet, rungs=rungs, seed=args.seed)
        print(f"\nrank  cand  rung  epochs  eval_reward  survived")
        for rank, e in enumerate(lb.entries):
            print(f"{rank:4d}  {e.cand:4d}  {e.rung:4d}  {e.epochs:6d}  "
                  f"{e.score:11.4f}  {e.survived}")
        print(f"\ntotal lane-epochs executed: {lb.total_lane_epochs} "
              f"(fixed grid over every candidate would be "
              f"{len(lb.entries) * sum(rungs)})")
        path = lb.save(args.search_json)
        print(f"wrote {path}")
        return
    env_params = (scenarios.build_for(
        env, args.scenario, args.fleet,
        broadcast_invariant=args.broadcast_invariant)
        if args.scenario else None)
    # lanes initialize under their own scenario: the model-based baseline
    # profiles and fits the lane's cluster, not the nominal one
    states = agent.init_fleet(key, args.fleet, env_params=env_params,
                              env=env)

    if args.distributed:
        mesh = make_fleet_mesh(spanning=True)
    elif args.sharded:
        mesh = make_fleet_mesh()
    else:
        mesh = None
    if mesh is not None and args.fleet % fleet_size(mesh) != 0:
        # elastic degradation: a checkpoint may be resumed on a machine
        # whose device count no longer divides the fleet — run un-sharded
        # rather than dying in shard_fleet's divisibility check
        print(f"--fleet {args.fleet} does not divide the "
              f"{fleet_size(mesh)} data-axis devices; falling back to the "
              f"un-sharded vmap runner")
        mesh = None
    ck = (FleetCheckpoint(args.checkpoint_dir, every=args.checkpoint_every)
          if args.checkpoint_dir else None)
    keys = jax.random.split(jax.random.fold_in(key, 2), args.fleet)
    env_states, start_epoch, restored, lane_ids = None, 0, False, None
    if args.resume:
        if ck is None:
            ap.error("--resume needs --checkpoint-dir")
        if ck.latest_epoch() is not None:
            like_env = reset_fleet_states(keys, env, env_params)
            if ck.has_lane_map():
                # elastic-lifecycle snapshot: the saved fleet is COMPACTED
                # (possibly padded with passenger lanes) — restore through
                # the lane map, drop passengers, and subset the scenario
                # fleet to the surviving original lanes
                if not args.early_stop:
                    ap.error(f"{ck.directory} holds elastic-lifecycle "
                             f"(compacted) snapshots; resume with "
                             f"--early-stop")
                from repro.fleet.lifecycle import restore_elastic
                (start_epoch, keys, states, env_states, env_params,
                 lane_ids) = restore_elastic(
                    ck, states, like_env, keys, env_params=env_params,
                    ref=(env.default_params() if env_params is not None
                         else None))
                restored = True
                if mesh is not None and \
                        int(keys.shape[0]) % fleet_size(mesh) != 0:
                    print(f"{int(keys.shape[0])} surviving lane(s) do not "
                          f"divide the {fleet_size(mesh)} data-axis "
                          f"devices; falling back to the un-sharded vmap "
                          f"runner")
                    mesh = None
                print(f"resuming compacted elastic fleet from epoch "
                      f"{start_epoch}: {len(lane_ids)} surviving lane(s) "
                      f"{lane_ids.tolist()} ({ck.directory})")
            else:
                start_epoch, states, env_states, keys = ck.restore(
                    states, like_env, keys, mesh=mesh)
                restored = True
                print(f"resuming from checkpoint epoch {start_epoch} "
                      f"({ck.directory})")
        if start_epoch >= args.epochs:
            print(f"checkpoint already at epoch {start_epoch} >= "
                  f"--epochs {args.epochs}; nothing left to run")
            return

    # offline pretraining only seeds a FRESH run: restored lanes already
    # carry their replay buffers and trained networks
    if not restored and args.agent == "ddpg" and args.offline > 0:
        print(f"offline pretraining {args.fleet} lanes on {args.offline} "
              f"random transitions each ...")
        states = ddpg_lib.offline_pretrain_fleet(
            jax.random.split(jax.random.fold_in(key, 1), args.fleet),
            states, agent.cfg, env,
            n_samples=args.offline, n_updates=args.offline_updates,
            env_params=env_params)

    fleet_now = int(jnp.asarray(keys).shape[0])
    scen = f" ({args.scenario} scenario fleet)" if args.scenario else ""
    where = (f" sharded over {mesh.devices.size} devices" if mesh is not None
             else "")
    stop = " with per-lane early stopping" if args.early_stop else ""
    print(f"online learning: {args.agent} fleet of {fleet_now} x "
          f"{args.epochs - start_epoch} decision epochs in one batched "
          f"scan{scen}{where}{stop} ...")
    if args.guards:
        from repro.core import agent as agent_mod
        from repro.diagnostics import guards
        region = guards(track=(agent_mod._fleet_program,
                               agent_mod._fleet_program_sharded,
                               agent_mod._fleet_program_sharded_donated),
                        label="drl_control")
    else:
        region = contextlib.nullcontext(None)
    with region as g:
        if args.early_stop:
            from repro.fleet.lifecycle import StopRule, run_online_fleet_elastic
            result = run_online_fleet_elastic(
                keys, env, agent, states, T=args.epochs - start_epoch,
                rule=StopRule(), env_params=env_params, env_states=env_states,
                mesh=mesh, checkpoint=ck, start_epoch=start_epoch,
                lane_ids=lane_ids)
            states, hist = result.states, result.history
            lanes = (f" (original lanes {result.lane_ids.tolist()})"
                     if lane_ids is not None else "")
            print(f"early stopping: per-lane epochs "
                  f"{result.epochs_run.tolist()}{lanes} "
                  f"— {result.executed_lane_epochs} lane-epochs executed vs "
                  f"{result.fixed_grid_lane_epochs} fixed-grid "
                  f"({result.savings:.0%} saved)")
        else:
            states, hist = run_online_fleet(
                keys, env, agent, states, T=args.epochs - start_epoch,
                env_params=env_params, env_states=env_states, mesh=mesh,
                checkpoint=ck, start_epoch=start_epoch)
    if g is not None:
        print(f"guards: clean — {g.counter.compiles} fleet-program "
              f"compilation(s) {g.counter.per_target()}, no implicit "
              f"transfers, no non-finite carries")
    if ck is not None:
        ck.close()

    # score every lane under the scenario it actually ran (round-robin too,
    # so the improvement column compares like with like per lane)
    finals, rrs = [], []
    X_rr = env.round_robin_assignment()
    n_lanes = int(np.asarray(hist.final_assignment).shape[0])
    for f in range(n_lanes):
        if env_params is not None:
            lane_p = lane_params(env_params, env.default_params(), f)
            w_f = (lane_p.base_rates if hasattr(lane_p, "base_rates")
                   else lane_p.base_load)
        else:
            lane_p = None
            w_f = (env.workload.init() if hasattr(env, "workload")
                   else env._base_load)
        X_f = jnp.asarray(hist.final_assignment[f])
        finals.append(float(env.evaluate(X_f, w_f, params=lane_p)
                            if lane_p is not None
                            else env.evaluate(X_f, w_f)))
        rrs.append(float(env.evaluate(X_rr, w_f, params=lane_p)
                         if lane_p is not None
                         else env.evaluate(X_rr, w_f)))
    finals, rrs = np.asarray(finals), np.asarray(rrs)
    # "best" is the lane with the largest improvement over ITS round-robin
    # score, so the printed latency, improvement, and assignment agree even
    # when lanes run heterogeneous scenarios
    best = int((finals / rrs).argmin())
    print(f"\nfinal latency {finals.mean():.3f} ± {finals.std():.3f} ms "
          f"over {n_lanes} lanes "
          f"(best lane {best}: {finals[best]:.3f} ms)   "
          f"round-robin {rrs.mean():.3f} ms   "
          f"improvement {1 - finals.mean() / rrs.mean():.1%} mean / "
          f"{1 - finals[best] / rrs[best]:.1%} best")
    print("best assignment (executor -> machine):",
          hist.final_assignment[best].argmax(-1).tolist())

    if args.serve:
        # serve the TRAINED policy through the batched control plane: the
        # best lane's agent state answers placement requests, each
        # training lane's scenario is a registered live cluster, and the
        # rate_control / auto_tune planes ride along (docs/serving.md)
        from repro.launch.serve_control import (build_service,
                                                synthetic_requests)
        best_state = jax.tree.map(lambda x: x[best], states)
        svc = build_service(env, seed=args.seed, n_slots=min(8, args.serve),
                            placement_agent=agent,
                            placement_state=best_state)
        for f in range(n_lanes):
            svc.register_cluster(
                f"lane-{f}",
                lane_params(env_params, env.default_params(), f)
                if env_params is not None else None)
        for r in synthetic_requests(env, svc, args.serve, seed=args.seed):
            svc.submit(r)
        print(f"\nserving {args.serve} decision requests from the trained "
              f"policy across {n_lanes} cluster(s) ...")
        served = svc.run(jax.random.fold_in(key, 3))
        for kind, stats in svc.decision_stats().items():
            print(f"  {kind:13s} n={stats['n']:4d}  "
                  f"p50 {stats['p50_ms']:8.3f} ms  "
                  f"p99 {stats['p99_ms']:8.3f} ms")
        assert len(served) == args.serve


if __name__ == "__main__":
    main()
