"""Localhost multi-process fleet driver: spawn, supervise, heal.

This is the orchestration layer of the multi-host mega-fleet story
(docs/sharded_fleets.md#multi-host-fleets).  It launches ``--procs``
worker processes of the SAME training command — by default
``repro.launch.drl_control --distributed`` — wired together as one
``jax.distributed`` job over localhost:

* each worker gets ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` /
  ``REPRO_PROCESS_ID`` (what ``launch.mesh.init_distributed`` reads),
  plus ``JAX_PLATFORMS=cpu`` and ``--xla_force_host_platform_device_count``
  so a single machine emulates N hosts × D devices;
* the driver is the Storm-style master: a
  :class:`repro.fault.heartbeat.HeartbeatMonitor` tracks worker liveness
  (a running process IS its heartbeat), and when a worker dies the
  surviving job is torn down, the reduced mesh is sized with
  :func:`repro.fault.elastic.plan_mesh` (``model_parallel=1`` — fleets
  are data-only), and the job is relaunched on the survivors with
  ``--resume`` so it continues from the newest published multi-host
  checkpoint;
* ``--kill-proc P --kill-at-epoch E`` injects the failure
  deterministically: once the shared checkpoint directory publishes a
  step at epoch >= E, worker P is SIGKILLed — the recovery drill the CI
  ``multihost-smoke`` job runs.

Everything after ``--`` is passed to the worker module verbatim
(``--distributed`` and the driver's ``--checkpoint-dir`` are appended
automatically)::

  PYTHONPATH=src python -m repro.launch.multihost \\
      --procs 2 --devices-per-proc 2 --checkpoint-dir /tmp/mh_ck \\
      --kill-proc 1 --kill-at-epoch 8 -- \\
      --app cq_small --fleet 8 --epochs 24 --offline 64 \\
      --checkpoint-every 4
"""
from __future__ import annotations

import argparse
import os
import pathlib
import signal
import socket
import subprocess
import sys
import time

from repro.fault.elastic import plan_mesh
from repro.fault.heartbeat import HeartbeatMonitor
from repro.launch.mesh import (COORDINATOR_ENV, NUM_PROCESSES_ENV,
                               PROCESS_ID_ENV)


def free_port() -> int:
    """An OS-assigned free TCP port on localhost (racy in principle,
    fine for a driver that binds it again immediately)."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def published_epochs(checkpoint_dir: str | os.PathLike) -> list[int]:
    """Epochs of the PUBLISHED checkpoints in ``checkpoint_dir`` —
    single-process steps (``manifest.json``) and complete multi-host
    steps (``meta.json``) — without constructing a FleetCheckpoint
    (which would spin up its async writer thread just to peek)."""
    d = pathlib.Path(checkpoint_dir)
    out = []
    for p in d.glob("step_*"):
        if (p / "manifest.json").exists() or (p / "meta.json").exists():
            out.append(int(p.name.split("_")[1]))
    return sorted(out)


def worker_env(base: dict, coordinator: str, num_processes: int,
               process_id: int, devices_per_proc: int) -> dict:
    """Environment for one localhost worker: jax.distributed wiring plus
    the CPU device-count emulation flags."""
    env = dict(base)
    env[COORDINATOR_ENV] = coordinator
    env[NUM_PROCESSES_ENV] = str(num_processes)
    env[PROCESS_ID_ENV] = str(process_id)
    env["JAX_PLATFORMS"] = "cpu"
    flag = f"--xla_force_host_platform_device_count={devices_per_proc}"
    prior = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = f"{prior} {flag}".strip()
    return env


def launch_workers(module: str, worker_args: list[str], n_procs: int,
                   devices_per_proc: int, log_dir: pathlib.Path,
                   attempt: int) -> list[subprocess.Popen]:
    coordinator = f"127.0.0.1:{free_port()}"
    procs = []
    for pid in range(n_procs):
        log = log_dir / f"attempt{attempt}_proc{pid}.log"
        f = open(log, "w")
        p = subprocess.Popen(
            [sys.executable, "-m", module, *worker_args],
            env=worker_env(os.environ, coordinator, n_procs, pid,
                           devices_per_proc),
            stdout=f, stderr=subprocess.STDOUT)
        p._repro_log = log          # type: ignore[attr-defined]
        p._repro_logfile = f        # type: ignore[attr-defined]
        procs.append(p)
    return procs


def _close_logs(procs: list[subprocess.Popen]) -> None:
    for p in procs:
        p._repro_logfile.close()    # type: ignore[attr-defined]


def _terminate(procs: list[subprocess.Popen], grace_s: float = 10.0) -> None:
    for p in procs:
        if p.poll() is None:
            p.terminate()
    deadline = time.monotonic() + grace_s
    for p in procs:
        if p.poll() is None:
            try:
                p.wait(timeout=max(deadline - time.monotonic(), 0.1))
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()


def supervise(procs: list[subprocess.Popen], *,
              checkpoint_dir: str | None,
              kill_proc: int | None, kill_at_epoch: int,
              poll_s: float = 0.25,
              timeout_s: float = 1800.0) -> tuple[bool, set[int]]:
    """Run the job to completion under heartbeat supervision.

    A worker process that is still running beats its heartbeat every
    poll; exiting (for any reason) makes it miss beats and surface in
    ``newly_dead`` — nonzero exits are failures immediately, zero exits
    only count everyone out when ALL workers finished (a collective job
    cannot half-succeed).  Returns ``(ok, dead)``: ``ok`` means every
    worker exited 0; ``dead`` is the set of failed worker ids."""
    monitor = HeartbeatMonitor(num_workers=len(procs),
                               timeout_s=3 * poll_s)
    killed: set[int] = set()
    deadline = time.monotonic() + timeout_s
    while True:
        running = [i for i, p in enumerate(procs) if p.poll() is None]
        for i in running:
            monitor.beat(i)
        if (kill_proc is not None and kill_proc not in killed
                and checkpoint_dir is not None
                and procs[kill_proc].poll() is None):
            steps = published_epochs(checkpoint_dir)
            if steps and steps[-1] >= kill_at_epoch:
                print(f"[multihost] checkpoint at epoch {steps[-1]} "
                      f"published; killing worker {kill_proc} (drill)")
                procs[kill_proc].send_signal(signal.SIGKILL)
                killed.add(kill_proc)
        # a worker that exited nonzero is dead immediately; one that only
        # stopped beating joins it via the heartbeat timeout — but a clean
        # exit-0 slightly ahead of the stragglers (workers leave the final
        # barrier in any order) is not a failure
        dead = ({i for i, p in enumerate(procs) if p.poll() not in (None, 0)}
                | {i for i in monitor.newly_dead() if procs[i].poll() != 0})
        if dead:
            _terminate(procs)
            _close_logs(procs)
            return False, dead
        if not running:
            _close_logs(procs)
            return all(p.returncode == 0 for p in procs), set()
        if time.monotonic() > deadline:
            print(f"[multihost] supervision timeout after {timeout_s:.0f}s; "
                  f"tearing the job down")
            _terminate(procs)
            _close_logs(procs)
            return False, set(range(len(procs)))
        time.sleep(poll_s)


def _print_log(path: pathlib.Path, header: str, tail: int | None = None)\
        -> None:
    print(f"----- {header} ({path}) -----")
    lines = path.read_text().splitlines()
    for line in (lines[-tail:] if tail else lines):
        print(f"  {line}")


def run(module: str, worker_args: list[str], *, procs: int,
        devices_per_proc: int, checkpoint_dir: str | None,
        kill_proc: int | None = None, kill_at_epoch: int = 0,
        max_restarts: int = 1, log_dir: str | None = None,
        timeout_s: float = 1800.0) -> int:
    """Drive the multi-process job, healing through up to
    ``max_restarts`` failures.  Returns a process exit code."""
    base_args = list(worker_args)
    if checkpoint_dir is not None:
        base_args += ["--checkpoint-dir", checkpoint_dir]
    logs = pathlib.Path(log_dir or checkpoint_dir or ".")
    logs.mkdir(parents=True, exist_ok=True)

    n, attempt = int(procs), 0
    while True:
        resumed = attempt > 0
        args = base_args + (["--resume"] if resumed else [])
        print(f"[multihost] attempt {attempt}: launching {n} worker "
              f"process(es) x {devices_per_proc} device(s) "
              f"({'resuming' if resumed else 'fresh'})")
        workers = launch_workers(module, args, n, devices_per_proc, logs,
                                 attempt)
        ok, dead = supervise(
            workers, checkpoint_dir=checkpoint_dir,
            kill_proc=kill_proc if attempt == 0 else None,
            kill_at_epoch=kill_at_epoch, timeout_s=timeout_s)
        if ok:
            _print_log(workers[0]._repro_log,  # type: ignore[attr-defined]
                       f"worker 0 attempt {attempt}")
            print(f"[multihost] job complete on {n} process(es)")
            return 0
        print(f"[multihost] worker(s) {sorted(dead)} died")
        if attempt >= max_restarts or checkpoint_dir is None:
            for w in workers:
                _print_log(w._repro_log,  # type: ignore[attr-defined]
                           "failed worker", tail=30)
            print("[multihost] out of restarts (or no --checkpoint-dir "
                  "to resume from); giving up")
            return 1
        # Storm-style recovery: size the reduced mesh over the surviving
        # devices and relaunch the whole collective job on them — the
        # workers restore from the newest published multi-host checkpoint
        survivors = n - len(dead)
        if survivors < 1:
            survivors = 1                   # relaunch degenerates to local
        plan = plan_mesh(survivors * devices_per_proc, model_parallel=1)
        n = max(plan.shape[0] // devices_per_proc, 1)
        print(f"[multihost] re-planned mesh {plan.shape} over "
              f"{survivors * devices_per_proc} surviving device(s) -> "
              f"relaunching on {n} process(es) with --resume")
        attempt += 1


def main() -> None:
    ap = argparse.ArgumentParser(
        description="localhost multi-process fleet driver "
                    "(spawn, supervise, heal)")
    ap.add_argument("--procs", type=int, default=2,
                    help="worker processes (emulated hosts)")
    ap.add_argument("--devices-per-proc", type=int, default=2,
                    help="CPU devices each worker exposes "
                         "(--xla_force_host_platform_device_count)")
    ap.add_argument("--module", default="repro.launch.drl_control",
                    help="worker module run as `python -m MODULE`")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="shared fleet checkpoint directory (appended to "
                         "the worker args; required for healing restarts "
                         "and for --kill-at-epoch's trigger)")
    ap.add_argument("--kill-proc", type=int, default=None,
                    help="failure drill: SIGKILL this worker id once the "
                         "checkpoint dir publishes --kill-at-epoch")
    ap.add_argument("--kill-at-epoch", type=int, default=1,
                    help="epoch threshold arming --kill-proc")
    ap.add_argument("--max-restarts", type=int, default=1,
                    help="healing relaunches before giving up")
    ap.add_argument("--timeout", type=float, default=1800.0,
                    help="per-attempt supervision timeout in seconds")
    ap.add_argument("worker_args", nargs="*",
                    help="arguments after `--` go to the worker module "
                         "(--distributed is appended automatically for "
                         "the default drl_control module)")
    args = ap.parse_args()
    if args.procs < 1:
        ap.error("--procs must be >= 1")
    if args.kill_proc is not None and args.kill_proc >= args.procs:
        ap.error(f"--kill-proc {args.kill_proc} out of range for "
                 f"--procs {args.procs}")
    if args.kill_proc is not None and not args.checkpoint_dir:
        ap.error("--kill-proc needs --checkpoint-dir (the kill triggers "
                 "on a published checkpoint, and recovery resumes from it)")
    worker_args = list(args.worker_args)
    if args.module == "repro.launch.drl_control" \
            and "--distributed" not in worker_args:
        worker_args.append("--distributed")
    raise SystemExit(run(
        args.module, worker_args, procs=args.procs,
        devices_per_proc=args.devices_per_proc,
        checkpoint_dir=args.checkpoint_dir, kill_proc=args.kill_proc,
        kill_at_epoch=args.kill_at_epoch, max_restarts=args.max_restarts,
        timeout_s=args.timeout))


if __name__ == "__main__":
    main()
