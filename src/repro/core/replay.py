"""Experience replay buffer (paper: |B| = 1000, minibatch H = 32).

Fixed-capacity ring buffer held as device arrays so sampling and the DDPG
update jit together; oldest samples are overwritten when full (paper §3.2.1)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Replay(NamedTuple):
    states: jnp.ndarray        # [cap, state_dim]
    actions: jnp.ndarray       # [cap, action_dim]
    rewards: jnp.ndarray       # [cap]
    next_states: jnp.ndarray   # [cap, state_dim]
    ptr: jnp.ndarray           # scalar int32 — next write slot
    size: jnp.ndarray          # scalar int32


def replay_init(capacity: int, state_dim: int, action_dim: int) -> Replay:
    return Replay(
        states=jnp.zeros((capacity, state_dim), jnp.float32),
        actions=jnp.zeros((capacity, action_dim), jnp.float32),
        rewards=jnp.zeros((capacity,), jnp.float32),
        next_states=jnp.zeros((capacity, state_dim), jnp.float32),
        ptr=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def replay_add(buf: Replay, s, a, r, s_next) -> Replay:
    cap = buf.states.shape[0]
    i = buf.ptr
    return Replay(
        states=buf.states.at[i].set(s),
        actions=buf.actions.at[i].set(a),
        rewards=buf.rewards.at[i].set(r),
        next_states=buf.next_states.at[i].set(s_next),
        ptr=(i + 1) % cap,
        size=jnp.minimum(buf.size + 1, cap),
    )


def replay_sample(key: jax.Array, buf: Replay, batch: int):
    """Uniform sample with replacement over the filled prefix."""
    idx = jax.random.randint(key, (batch,), 0, jnp.maximum(buf.size, 1))
    return (
        buf.states[idx],
        buf.actions[idx],
        buf.rewards[idx],
        buf.next_states[idx],
    )
