"""Storm's default scheduler: round-robin executor→slot→machine assignment.

Results in near-even workload spread with no communication awareness —
the paper's "Default" baseline."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def round_robin(n_executors: int, n_machines: int,
                alive: np.ndarray | None = None) -> jnp.ndarray:
    """One-hot [N, M]; skips dead machines (used by fault-tolerance tests)."""
    machines = np.arange(n_machines)
    if alive is not None:
        machines = machines[np.asarray(alive, dtype=bool)]
    idx = machines[np.arange(n_executors) % len(machines)]
    X = np.zeros((n_executors, n_machines), dtype=np.float32)
    X[np.arange(n_executors), idx] = 1.0
    return jnp.asarray(X)
