"""Storm's default scheduler: round-robin executor→slot→machine assignment.

Results in near-even workload spread with no communication awareness —
the paper's "Default" baseline.  Also exposed as a trivial non-learning
:class:`~repro.core.api.Agent` (``make_agent("round_robin", env)``) so the
baseline runs through the same fleet runner as the DRL methods."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api


def round_robin(n_executors: int, n_machines: int,
                alive: np.ndarray | None = None) -> jnp.ndarray:
    """One-hot [N, M]; skips dead machines (used by fault-tolerance tests)."""
    machines = np.arange(n_machines)
    if alive is not None:
        machines = machines[np.asarray(alive, dtype=bool)]
    idx = machines[np.arange(n_executors) % len(machines)]
    X = np.zeros((n_executors, n_machines), dtype=np.float32)
    X[np.arange(n_executors), idx] = 1.0
    return jnp.asarray(X)


# --------------------------------------------------------------------------
# Agent-interface adapter: a stateless policy whose "state" is just an
# epoch counter; observe/update are identity.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RoundRobinConfig:
    n_executors: int
    n_machines: int


def _agent_init(key, cfg: RoundRobinConfig, env_params=None):
    return jnp.zeros((), jnp.int32)


def _agent_select(key, cfg: RoundRobinConfig, state, s_vec, env_state,
                  env_params, explore):
    idx = jnp.arange(cfg.n_executors) % cfg.n_machines
    X = jax.nn.one_hot(idx, cfg.n_machines, dtype=jnp.float32)
    return X, jnp.zeros(())


def _agent_observe(cfg, state, s_vec, aux, reward, s_next):
    return state


def _agent_update(key, cfg, state):
    return state


def _agent_tick(cfg, state):
    return state + 1


def as_agent(cfg: RoundRobinConfig) -> api.Agent:
    return api.Agent(name="round_robin", cfg=cfg, init_fn=_agent_init,
                     select_fn=_agent_select, observe_fn=_agent_observe,
                     update_fn=_agent_update, tick_fn=_agent_tick)


def agent_factory(env, **overrides) -> api.Agent:
    cfg = overrides.pop("cfg", None)
    if cfg is None:
        cfg = RoundRobinConfig(n_executors=env.N, n_machines=env.M,
                               **overrides)
    return as_agent(cfg)


api.register_agent("round_robin", agent_factory)
