"""State/action-space algebra for the scheduling problem (paper §3.2).

Action a ∈ {0,1}^{N×M} with row-simplex constraints Σ_j a_ij = 1;
state s = (X, w).  Helpers here are shared by agents, tests, and the
property-based invariants.

The module also carries the ACTION-SPACE REGISTRY: the serving control
plane (serve/control.py) dispatches decision kinds by name, and each kind
is an :class:`ActionSpace` — its per-env action shape, its feasibility
predicate, and the registered default agent that serves it.  Builtins:
``placement`` (the paper's [N, M] assignment), ``rate_control`` (per-spout
admission throttles) and ``auto_tune`` (config-knob operating points),
whose simulator semantics live in ``repro.dsdps.actions``."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


def is_feasible(action: jnp.ndarray, atol: float = 1e-6) -> jnp.ndarray:
    """Checks the MIQP-NN constraint set: binary rows summing to one."""
    binary = jnp.all(jnp.abs(action * (1.0 - action)) < atol)
    rows = jnp.all(jnp.abs(action.sum(-1) - 1.0) < atol)
    return jnp.logical_and(binary, rows)


def assignment_to_machines(action: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(action, axis=-1)


def machines_to_assignment(machines: jnp.ndarray, n_machines: int) -> jnp.ndarray:
    return jax.nn.one_hot(machines, n_machines, dtype=jnp.float32)


def hamming_moves(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Number of executors whose machine differs between two assignments —
    the deployment cost of the minimal-delta re-assignment (paper §3.1)."""
    return (assignment_to_machines(a) != assignment_to_machines(b)).sum()


def action_space_size(n_executors: int, n_machines: int) -> int:
    return n_machines ** n_executors


# --------------------------------------------------------------------------
# Action-space registry — the decision surface the serving control plane
# dispatches over.  Every space's actions are one-hot rows, so the single
# MIQP-NN predicate above validates all of them (a 1-D action is one row).
# --------------------------------------------------------------------------
class ActionSpace(NamedTuple):
    """One decision kind: name, per-env action shape, feasibility test,
    and the registry name of the agent that serves it by default."""

    name: str
    shape_fn: Callable[[Any], tuple[int, ...]]
    feasible_fn: Callable[[jnp.ndarray], jnp.ndarray]
    default_agent: str


_ACTION_SPACES: dict[str, ActionSpace] = {}


def register_action_space(space: ActionSpace) -> None:
    """Register a decision kind for ``action_space(name)`` lookup (and
    therefore for ``serve.control.ControlPlane(kind=name)``)."""
    _ACTION_SPACES[space.name] = space


def action_space(name: str) -> ActionSpace:
    try:
        return _ACTION_SPACES[name]
    except KeyError:
        raise KeyError(f"unknown action space {name!r}; "
                       f"known: {sorted(_ACTION_SPACES)}") from None


def action_space_names() -> tuple[str, ...]:
    return tuple(sorted(_ACTION_SPACES))


def _placement_shape(env) -> tuple[int, ...]:
    return (env.N, env.M)


def _rate_shape(env) -> tuple[int, ...]:
    # lazy import: spaces is a core leaf module; the rate grid lives with
    # its simulator semantics in dsdps
    from repro.dsdps.actions import RATE_LEVELS
    return (env.workload.num_spouts, len(RATE_LEVELS))


def _tune_shape(env) -> tuple[int, ...]:
    from repro.dsdps.actions import TUNE_GRID
    return (len(TUNE_GRID),)


register_action_space(ActionSpace("placement", _placement_shape,
                                  is_feasible, "ddpg"))
register_action_space(ActionSpace("rate_control", _rate_shape,
                                  is_feasible, "rate_control"))
register_action_space(ActionSpace("auto_tune", _tune_shape,
                                  is_feasible, "auto_tune"))
