"""State/action-space algebra for the scheduling problem (paper §3.2).

Action a ∈ {0,1}^{N×M} with row-simplex constraints Σ_j a_ij = 1;
state s = (X, w).  Helpers here are shared by agents, tests, and the
property-based invariants."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def is_feasible(action: jnp.ndarray, atol: float = 1e-6) -> jnp.ndarray:
    """Checks the MIQP-NN constraint set: binary rows summing to one."""
    binary = jnp.all(jnp.abs(action * (1.0 - action)) < atol)
    rows = jnp.all(jnp.abs(action.sum(-1) - 1.0) < atol)
    return jnp.logical_and(binary, rows)


def assignment_to_machines(action: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(action, axis=-1)


def machines_to_assignment(machines: jnp.ndarray, n_machines: int) -> jnp.ndarray:
    return jax.nn.one_hot(machines, n_machines, dtype=jnp.float32)


def hamming_moves(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Number of executors whose machine differs between two assignments —
    the deployment cost of the minimal-delta re-assignment (paper §3.1)."""
    return (assignment_to_machines(a) != assignment_to_machines(b)).sum()


def action_space_size(n_executors: int, n_machines: int) -> int:
    return n_machines ** n_executors
