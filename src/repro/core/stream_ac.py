"""Stream AC(λ) — replay-free actor-critic online control (arXiv 2410.14606).

The actor-critic counterpart of :mod:`stream_q`, and the streaming
replacement for the DDPG lane.  Instead of DDPG's proto-action + K-NN
projection over a continuous relaxation, the actor is a *factorized
discrete* policy: logits [N, M], one categorical per executor row, so an
action is always a valid one-hot assignment by construction — no
projection step, no critic argmax over candidates.  The critic learns
V(s) (not Q(s, a)), which single-transition TD(λ) bootstraps directly.

Per-lane carry: actor + critic params, one eligibility-trace pytree per
net, the shared Welford observation normalizer, and one pending TD error.
No replay, no target nets, no Adam moments — both updates are ObGD steps,
with the actor trace accumulating ∇ log π(a|s) (summed over executor
rows) and the critic trace accumulating ∇V(s)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core import networks as nets
from repro.core.streaming import (ObsNorm, norm_apply, norm_init,
                                  norm_update, obgd_step, reward_norm_update,
                                  trace_decay_add, trace_zeros_like)


@dataclasses.dataclass(frozen=True)
class StreamACConfig:
    n_executors: int
    n_machines: int
    state_dim: int
    gamma: float = 0.99
    lam: float = 0.9             # eligibility-trace decay λ (both nets)
    lr_actor: float = 1.0        # ObGD base stepsizes (self-throttling)
    lr_critic: float = 1.0
    kappa: float = 2.0           # ObGD overshoot margin
    # lean nets, same story as StreamQConfig: reward parity with DDPG
    # holds at (8, 8) (pinned in tests/test_streaming.py) and the per-lane
    # carry drops ~74× vs the DDPG lane.  Unlike stream_q the full 0.9
    # zero fraction stays the stronger setting here — softmax sampling
    # keeps gradients flowing through all rows from epoch 0
    sparsity: float = 0.9
    hidden: tuple = (8, 8)
    reward_scale: float = 0.25
    # sampling-temperature schedule: softmax sampling is the exploration
    # mechanism, so anneal it the way the replay agents anneal ε — early
    # epochs sample near-uniformly, late epochs act near-greedily (the
    # log π gradient uses the SAME tempered logits, so updates stay
    # on-policy)
    temp_start: float = 1.0
    temp_end: float = 0.02
    temp_decay_epochs: int = 300

    def temperature(self, epoch: jnp.ndarray) -> jnp.ndarray:
        frac = jnp.clip(epoch.astype(jnp.float32) / self.temp_decay_epochs,
                        0.0, 1.0)
        return self.temp_start + frac * (self.temp_end - self.temp_start)

    @property
    def action_dim(self) -> int:
        return self.n_executors * self.n_machines


class StreamACState(NamedTuple):
    actor: nets.MLPParams        # logits head [N·M]
    critic: nets.MLPParams       # V(s) head [1]
    z_actor: nets.MLPParams
    z_critic: nets.MLPParams
    norm: ObsNorm
    delta: jnp.ndarray           # pending TD error (consumed by update)
    epoch: jnp.ndarray
    r_mean: jnp.ndarray = jnp.zeros(())
    r_var: jnp.ndarray = jnp.ones(())
    r_count: jnp.ndarray = jnp.zeros((), jnp.int32)


def init_state(key: jax.Array, cfg: StreamACConfig) -> StreamACState:
    ka, kc = jax.random.split(key)
    actor = nets.sparse_init(
        ka, (cfg.state_dim, *cfg.hidden, cfg.action_dim),
        sparsity=cfg.sparsity)
    critic = nets.sparse_init(
        kc, (cfg.state_dim, *cfg.hidden, 1), sparsity=cfg.sparsity)
    return StreamACState(
        actor=actor,
        critic=critic,
        z_actor=trace_zeros_like(actor),
        z_critic=trace_zeros_like(critic),
        norm=norm_init(cfg.state_dim),
        delta=jnp.zeros(()),
        epoch=jnp.zeros((), jnp.int32),
    )


def _logits(actor: nets.MLPParams, cfg: StreamACConfig, x,
            temp) -> jnp.ndarray:
    raw = nets.apply_mlp(actor, x).reshape(cfg.n_executors, cfg.n_machines)
    return raw / temp


def select_assignment(key, state: StreamACState, cfg: StreamACConfig, s_vec,
                      explore: bool = True):
    """Sample (or argmax) one machine per executor row.

    Softmax sampling IS the exploration mechanism: sparse init starts the
    logits near zero, i.e. near-uniform assignment — the streaming
    counterpart of the replay agents' ε-schedules."""
    x = norm_apply(state.norm, s_vec)
    logits = _logits(state.actor, cfg, x, cfg.temperature(state.epoch))
    if explore:
        machines = jax.random.categorical(key, logits, axis=-1)
    else:
        machines = jnp.argmax(logits, axis=-1)
    action = jax.nn.one_hot(machines, cfg.n_machines, dtype=jnp.float32)
    return action, machines


def observe(cfg: StreamACConfig, state: StreamACState, s_vec, aux, reward,
            s_next) -> StreamACState:
    """Fold one transition into both trace pytrees; stash the TD error."""
    machines = aux
    r_std, r_mean, r_var, r_count = reward_norm_update(
        reward, state.r_mean, state.r_var, state.r_count,
        scale=cfg.reward_scale)
    x = norm_apply(state.norm, s_vec)
    x_next = norm_apply(state.norm, s_next)
    v, grad_v = jax.value_and_grad(
        lambda p: nets.apply_mlp(p, x)[0])(state.critic)
    v_next = nets.apply_mlp(state.critic, x_next)[0]
    delta = r_std + cfg.gamma * v_next - v

    def logp(p):
        lp = jax.nn.log_softmax(
            _logits(p, cfg, x, cfg.temperature(state.epoch)), axis=-1)
        rows = jnp.arange(cfg.n_executors)
        return lp[rows, machines].sum()

    grad_pi = jax.grad(logp)(state.actor)
    decay = cfg.gamma * cfg.lam
    return state._replace(
        z_actor=trace_decay_add(state.z_actor, grad_pi, decay),
        z_critic=trace_decay_add(state.z_critic, grad_v, decay),
        delta=delta,
        norm=norm_update(state.norm, s_vec),
        r_mean=r_mean, r_var=r_var, r_count=r_count)


def update(state: StreamACState, cfg: StreamACConfig) -> StreamACState:
    """Apply both pending ObGD TD steps, then consume the error (δ = 0
    makes repeat calls exact no-ops — one TD step per transition)."""
    critic = obgd_step(state.critic, state.z_critic, state.delta,
                       cfg.lr_critic, cfg.kappa)
    actor = obgd_step(state.actor, state.z_actor, state.delta,
                      cfg.lr_actor, cfg.kappa)
    return state._replace(actor=actor, critic=critic, delta=jnp.zeros(()))


def tick(state: StreamACState) -> StreamACState:
    return state._replace(epoch=state.epoch + 1)


# --------------------------------------------------------------------------
# Agent-interface adapter — hooks for the generic api.make_epoch_step.
# --------------------------------------------------------------------------
def _agent_init(key, cfg: StreamACConfig, env_params=None):
    return init_state(key, cfg)


def _agent_select(key, cfg: StreamACConfig, state, s_vec, env_state,
                  env_params, explore):
    return select_assignment(key, state, cfg, s_vec, explore=explore)


def _agent_observe(cfg: StreamACConfig, state, s_vec, aux, reward, s_next):
    return observe(cfg, state, s_vec, aux, reward, s_next)


def _agent_update(key, cfg: StreamACConfig, state):
    return update(state, cfg)


def _agent_tick(cfg: StreamACConfig, state):
    return tick(state)


def as_agent(cfg: StreamACConfig) -> api.Agent:
    """Stream AC(λ) as a pluggable Agent bundle."""
    return api.Agent(name="stream_ac", cfg=cfg, init_fn=_agent_init,
                     select_fn=_agent_select, observe_fn=_agent_observe,
                     update_fn=_agent_update, tick_fn=_agent_tick)


def agent_factory(env, **overrides) -> api.Agent:
    """Registry hook: size a StreamACConfig for ``env`` (or pass ``cfg=``)."""
    cfg = overrides.pop("cfg", None)
    if cfg is None:
        cfg = StreamACConfig(n_executors=env.N, n_machines=env.M,
                             state_dim=env.state_dim, **overrides)
    return as_agent(cfg)


api.register_agent("stream_ac", agent_factory)


def init_fleet(key: jax.Array, cfg: StreamACConfig,
               fleet: int) -> StreamACState:
    """Independently-initialized per-lane states stacked on [fleet]."""
    return jax.vmap(lambda k: init_state(k, cfg))(jax.random.split(key, fleet))
