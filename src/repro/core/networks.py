"""Actor / critic networks — exactly the paper's §3.2.1 shapes.

Both are 2-layer fully-connected feedforward nets with 64 and 32 neurons
and tanh activations.  The actor maps a state to a proto-action in
[0, 1]^{N·M}; the critic maps (state, action) to a scalar Q value."""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

HIDDEN = (64, 32)   # paper §3.2.1


class MLPParams(NamedTuple):
    weights: tuple
    biases: tuple


def init_mlp(key: jax.Array, sizes: Sequence[int]) -> MLPParams:
    """Glorot-uniform init for a chain of Linear layers."""
    ws, bs = [], []
    for k, (din, dout) in zip(
        jax.random.split(key, len(sizes) - 1), zip(sizes[:-1], sizes[1:])
    ):
        lim = jnp.sqrt(6.0 / (din + dout))
        ws.append(jax.random.uniform(k, (din, dout), jnp.float32, -lim, lim))
        bs.append(jnp.zeros((dout,), jnp.float32))
    return MLPParams(weights=tuple(ws), biases=tuple(bs))


def apply_mlp(params: MLPParams, x: jnp.ndarray) -> jnp.ndarray:
    """tanh hidden activations (paper's empirically-best choice), linear out."""
    h = x
    n = len(params.weights)
    for li, (w, b) in enumerate(zip(params.weights, params.biases)):
        h = h @ w + b
        if li < n - 1:
            h = jnp.tanh(h)
    return h


def init_actor(key: jax.Array, state_dim: int, action_dim: int) -> MLPParams:
    return init_mlp(key, (state_dim, *HIDDEN, action_dim))


def apply_actor(params: MLPParams, state: jnp.ndarray) -> jnp.ndarray:
    """proto-action in [0, 1]^{action_dim} (row-simplex-ish via sigmoid)."""
    return jax.nn.sigmoid(apply_mlp(params, state))


def init_critic(key: jax.Array, state_dim: int, action_dim: int) -> MLPParams:
    return init_mlp(key, (state_dim + action_dim, *HIDDEN, 1))


def apply_critic(params: MLPParams, state: jnp.ndarray, action: jnp.ndarray) -> jnp.ndarray:
    x = jnp.concatenate([state, action], axis=-1)
    return apply_mlp(params, x)[..., 0]


def init_qnet(key: jax.Array, state_dim: int, num_actions: int) -> MLPParams:
    """DQN baseline: Q(s, ·) head over the restricted N×M move space."""
    return init_mlp(key, (state_dim, *HIDDEN, num_actions))


def apply_qnet(params: MLPParams, state: jnp.ndarray) -> jnp.ndarray:
    return apply_mlp(params, state)


def soft_update(target: MLPParams, online: MLPParams, tau: float) -> MLPParams:
    """θ' ← τθ + (1−τ)θ'  (paper: τ = 0.01)."""
    return jax.tree.map(lambda t, o: (1.0 - tau) * t + tau * o, target, online)
