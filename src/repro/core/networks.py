"""Actor / critic networks — exactly the paper's §3.2.1 shapes.

Both are 2-layer fully-connected feedforward nets with 64 and 32 neurons
and tanh activations.  The actor maps a state to a proto-action in
[0, 1]^{N·M}; the critic maps (state, action) to a scalar Q value."""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

HIDDEN = (64, 32)   # paper §3.2.1


class MLPParams(NamedTuple):
    weights: tuple
    biases: tuple


def init_mlp(key: jax.Array, sizes: Sequence[int]) -> MLPParams:
    """Glorot-uniform init for a chain of Linear layers."""
    ws, bs = [], []
    for k, (din, dout) in zip(
        jax.random.split(key, len(sizes) - 1), zip(sizes[:-1], sizes[1:])
    ):
        lim = jnp.sqrt(6.0 / (din + dout))
        ws.append(jax.random.uniform(k, (din, dout), jnp.float32, -lim, lim))
        bs.append(jnp.zeros((dout,), jnp.float32))
    return MLPParams(weights=tuple(ws), biases=tuple(bs))


def apply_mlp(params: MLPParams, x: jnp.ndarray) -> jnp.ndarray:
    """tanh hidden activations (paper's empirically-best choice), linear out."""
    h = x
    n = len(params.weights)
    for li, (w, b) in enumerate(zip(params.weights, params.biases)):
        h = h @ w + b
        if li < n - 1:
            h = jnp.tanh(h)
    return h


def sparse_init(key: jax.Array, sizes: Sequence[int],
                sparsity: float = 0.9) -> MLPParams:
    """Sparse LeCun-uniform init for the streaming agents (arXiv 2410.14606).

    Each layer draws U(−1/√fan_in, 1/√fan_in) and zeroes a fixed
    ``sparsity`` fraction of the incoming weights of every output unit —
    the remaining active weights start proportionally larger relative to
    the dead ones, which the streaming paper shows protects single-sample
    TD(λ) updates from early interference.  Returns the same
    :class:`MLPParams` structure as :func:`init_mlp`, so traces, ObGD, and
    every pytree-shaped fleet operation apply unchanged."""
    if not 0.0 <= sparsity < 1.0:
        raise ValueError(f"sparsity must be in [0, 1); got {sparsity}")
    ws, bs = [], []
    for k, (din, dout) in zip(
        jax.random.split(key, len(sizes) - 1), zip(sizes[:-1], sizes[1:])
    ):
        kw, km = jax.random.split(k)
        lim = 1.0 / jnp.sqrt(jnp.asarray(din, jnp.float32))
        w = jax.random.uniform(kw, (din, dout), jnp.float32, -lim, lim)
        n_zero = int(round(sparsity * din))
        # exactly n_zero zeros per output unit: rank a uniform draw along
        # fan_in and kill the lowest-ranked entries of each column
        u = jax.random.uniform(km, (din, dout))
        ranks = jnp.argsort(jnp.argsort(u, axis=0), axis=0)
        ws.append(jnp.where(ranks < n_zero, 0.0, w))
        bs.append(jnp.zeros((dout,), jnp.float32))
    return MLPParams(weights=tuple(ws), biases=tuple(bs))


def init_actor(key: jax.Array, state_dim: int, action_dim: int) -> MLPParams:
    return init_mlp(key, (state_dim, *HIDDEN, action_dim))


def apply_actor(params: MLPParams, state: jnp.ndarray) -> jnp.ndarray:
    """proto-action in [0, 1]^{action_dim} (row-simplex-ish via sigmoid)."""
    return jax.nn.sigmoid(apply_mlp(params, state))


def init_critic(key: jax.Array, state_dim: int, action_dim: int) -> MLPParams:
    return init_mlp(key, (state_dim + action_dim, *HIDDEN, 1))


def apply_critic(params: MLPParams, state: jnp.ndarray, action: jnp.ndarray) -> jnp.ndarray:
    x = jnp.concatenate([state, action], axis=-1)
    return apply_mlp(params, x)[..., 0]


def init_qnet(key: jax.Array, state_dim: int, num_actions: int) -> MLPParams:
    """DQN baseline: Q(s, ·) head over the restricted N×M move space."""
    return init_mlp(key, (state_dim, *HIDDEN, num_actions))


def apply_qnet(params: MLPParams, state: jnp.ndarray) -> jnp.ndarray:
    return apply_mlp(params, state)


def soft_update(target: MLPParams, online: MLPParams, tau: float) -> MLPParams:
    """θ' ← τθ + (1−τ)θ'  (paper: τ = 0.01)."""
    return jax.tree.map(lambda t, o: (1.0 - tau) * t + tau * o, target, online)
