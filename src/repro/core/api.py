"""Functional core API v1 — the pluggable `Agent` interface + registry.

The paper's framework is explicitly pluggable: one DRL control loop driven
against arbitrary applications and control policies.  An :class:`Agent` is
an optax-style bundle of pure functions over a hashable config:

    init     (key, cfg, env_params)                       -> agent_state
    select   (key, cfg, state, s_vec, env_state,
              env_params, explore)                        -> (action, aux)
    observe  (cfg, state, s_vec, aux, reward, s_next)     -> agent_state
    update   (key, cfg, state)                            -> agent_state
    tick     (cfg, state)                                 -> agent_state

``aux`` is whatever the agent wants replayed (DDPG: the flat action; DQN:
the move index; non-learning baselines: a dummy scalar).  ``env_params``
is the scenario the agent is actually controlling (an EnvParams /
PlacementParams pytree, or None for the env's defaults): learning agents
may ignore it, but model-grounded baselines MUST consult it — a
model-based lane in a heterogeneous straggler fleet has to profile and
search ITS cluster, not the nominal one.  Because the bundle holds
module-level functions plus a hashable config, two agents built from
equal configs compare equal — an Agent is a valid jit STATIC argument,
and jit's own cache (keyed on the static env spec + agent) replaces the
old id(env)-keyed runner cache.

:func:`make_epoch_step` fuses select → env.step → observe → update×U →
tick into one scan body for ANY agent, against the functional env surface
``reset(key, params) / step(key, state, action, params) /
state_vector(state, params)``.  The fleet runner (core/agent.py) vmaps
that scan over stacked agent states AND stacked EnvParams, so baselines
and learners run through the same one-XLA-program fleet path.

:func:`make_agent` is the registry entry point:

    agent = make_agent("ddpg", env, k_nn=16)
    states = agent.init_fleet(key, fleet=8)
    states, hist = run_online_fleet(keys, env, agent, states, T=300)

Built-in names: ``ddpg``, ``dqn``, ``stream_q``, ``stream_ac``,
``graph_policy``, ``round_robin``, ``model_based`` (plus the
serving-only ``rate_control`` and ``auto_tune`` action-space policies).
The runners take Agent bundles ONLY — the PR-2 window during which bare
DDPG/DQN configs were coerced has closed; wrap a ready config with
``make_agent(name, env, cfg=cfg)``.  The full interface contract is
documented in docs/core_api.md.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Agent(NamedTuple):
    """Optax-style bundle of pure control-policy functions.

    Fields hold module-level functions taking the config explicitly (so
    equality/hashing works for jit static args); the ``init/select/...``
    methods are the ergonomic curried surface.  Signatures (the PR-3
    params-aware contract):

        init_fn(key, cfg, env_params)                       -> agent_state
        select_fn(key, cfg, state, s_vec, env_state,
                  env_params, explore)                      -> (action, aux)
        observe_fn(cfg, state, s_vec, aux, reward, s_next)  -> agent_state
        update_fn(key, cfg, state)                          -> agent_state
        tick_fn(cfg, state)                                 -> agent_state
    """

    name: str
    cfg: Any
    init_fn: Callable[[jax.Array, Any, Any], Any]
    select_fn: Callable[..., tuple[jnp.ndarray, Any]]
    observe_fn: Callable[..., Any]
    update_fn: Callable[[jax.Array, Any, Any], Any]
    tick_fn: Callable[[Any, Any], Any]

    # -- curried convenience surface ---------------------------------------
    def init(self, key: jax.Array, env_params=None):
        return self.init_fn(key, self.cfg, env_params)

    def init_fleet(self, key: jax.Array, fleet: int, env_params=None,
                   env=None):
        """Independently-initialized per-lane states stacked on [fleet].

        ``env_params`` may be None, a single scenario shared by every lane,
        or a STACKED scenario fleet ([F] leading axis, possibly with
        broadcast-invariant leaves) — each lane then initializes under its
        own scenario (e.g. the model-based baseline profiles and fits ITS
        cluster, so a straggler lane learns a straggler model).  ``env`` is
        required alongside ``env_params``: its ``default_params()``
        supplies the single-scenario leaf ranks, without which a stacked
        fleet is indistinguishable from a single scenario (and would be
        fed whole to every lane)."""
        keys = jax.random.split(key, fleet)
        if env_params is not None:
            if env is None:
                raise ValueError(
                    "init_fleet(env_params=...) needs env= as well — the "
                    "env's default_params() is the reference that tells a "
                    "stacked scenario fleet apart from a single scenario")
            from repro.dsdps.simulator import params_in_axes
            axes = params_in_axes(env_params, env.default_params())
            if axes is not None:
                return jax.vmap(
                    lambda k, p: self.init_fn(k, self.cfg, p),
                    in_axes=(0, axes))(keys, env_params)
        return jax.vmap(lambda k: self.init_fn(k, self.cfg, env_params))(keys)

    def select(self, key, state, s_vec, env_state, env_params=None,
               explore: bool = True):
        return self.select_fn(key, self.cfg, state, s_vec, env_state,
                              env_params, explore)

    def observe(self, state, s_vec, aux, reward, s_next):
        return self.observe_fn(self.cfg, state, s_vec, aux, reward, s_next)

    def update(self, key, state):
        return self.update_fn(key, self.cfg, state)

    def tick(self, state):
        return self.tick_fn(self.cfg, state)

    def make_epoch_step(self, env, env_params=None, updates_per_epoch: int = 1,
                        explore: bool = True):
        return make_epoch_step(env, self, env_params=env_params,
                               updates_per_epoch=updates_per_epoch,
                               explore=explore)


def make_epoch_step(env, agent: Agent, env_params=None,
                    updates_per_epoch: int = 1, explore: bool = True):
    """Fused online decision epoch as a scan body, for any Agent.

    carry = (agent_state, env_state, key); per-epoch output is
    (reward, latency_ms, moved).  The key-splitting discipline matches the
    legacy per-agent Python loops (core.agent.run_online_*_python) exactly,
    so scan runners reproduce their traces.  ``env_params`` may be a traced
    pytree (the fleet runner passes one lane of a stacked scenario fleet);
    None freezes the env's defaults into the program as constants."""
    params = env.default_params() if env_params is None else env_params

    def epoch_step(carry, _):
        state, env_state, key = carry
        key, k_act, k_step, k_upd = jax.random.split(key, 4)
        s_vec = env.state_vector(env_state, params)
        action, aux = agent.select_fn(k_act, agent.cfg, state, s_vec,
                                      env_state, params, explore)
        out = env.step(k_step, env_state, action, params)
        s_next = env.state_vector(out.state, params)
        state = agent.observe_fn(agent.cfg, state, s_vec, aux, out.reward,
                                 s_next)

        def upd(st, k):
            return agent.update_fn(k, agent.cfg, st), None

        state, _ = jax.lax.scan(
            upd, state, jax.random.split(k_upd, updates_per_epoch))
        state = agent.tick_fn(agent.cfg, state)
        return (state, out.state, key), (out.reward, out.latency_ms, out.moved)

    return epoch_step


def params_are_stacked(env, env_params) -> bool:
    """True when ``env_params`` carries a leading fleet axis (one more
    dimension than the env's single-scenario defaults)."""
    from repro.dsdps.simulator import params_stacked
    return params_stacked(env_params, env.default_params())


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[..., Agent]] = {}
_FAMILIES: dict[str, tuple[str, ...]] = {}

# the two env families sharing the functional surface (reset/step/
# state_vector/default_params + N/M/state_dim): the DSDPS SchedulingEnv
# and the TPU ExpertPlacementEnv instantiation
ENV_FAMILIES = ("scheduling", "placement")


def register_agent(name: str, factory: Callable[..., Agent],
                   families: tuple[str, ...] = ENV_FAMILIES) -> None:
    """Register ``factory(env, **overrides) -> Agent`` under ``name``.

    ``families`` declares which env families the agent's actions are valid
    for (subset of :data:`ENV_FAMILIES`; empty for serving-only policies
    whose action spaces never reach ``env.step``) — the registry
    completeness test drives every registered agent through one fused
    epoch step on each family it declares."""
    unknown = set(families) - set(ENV_FAMILIES)
    if unknown:
        raise ValueError(f"unknown env families {sorted(unknown)}; "
                         f"known: {ENV_FAMILIES}")
    _REGISTRY[name] = factory
    _FAMILIES[name] = tuple(families)


def _load_builtins() -> None:
    # Built-in agents self-register at import time; imported lazily to keep
    # this module dependency-free (ddpg/dqn/... all import it).
    import repro.core.control_policies  # noqa: F401
    import repro.core.ddpg        # noqa: F401
    import repro.core.dqn         # noqa: F401
    import repro.core.graph_policy  # noqa: F401
    import repro.core.model_based  # noqa: F401
    import repro.core.round_robin  # noqa: F401
    import repro.core.stream_ac   # noqa: F401
    import repro.core.stream_q    # noqa: F401


def agent_names() -> tuple[str, ...]:
    """Registered agent names (builtin + user-registered)."""
    _load_builtins()
    return tuple(sorted(_REGISTRY))


def agent_families(name: str) -> tuple[str, ...]:
    """Env families ``name`` declared at registration (see
    :func:`register_agent`); empty tuple = serving-only."""
    _load_builtins()
    try:
        return _FAMILIES[name]
    except KeyError:
        raise KeyError(f"unknown agent {name!r}; "
                       f"known: {sorted(_REGISTRY)}") from None


def make_agent(name: str, env, **overrides) -> Agent:
    """Construct a registered agent sized for ``env``.

    ``overrides`` are forwarded to the agent's config constructor (e.g.
    ``make_agent("ddpg", env, k_nn=16, eps=EpsilonSchedule(...))``), or
    pass a ready config as ``cfg=``."""
    _load_builtins()
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown agent {name!r}; "
                       f"known: {sorted(_REGISTRY)}") from None
    return factory(env, **overrides)
