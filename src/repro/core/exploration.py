"""Exploration policy R(â) = â + εI  (paper §3.2.1, line 9).

ε is the probability of perturbing the proto-action with uniform noise
I ~ U[0,1]^{N·M}; it decays with the decision epoch so later epochs act
greedily.  The DQN baseline uses the standard ε-greedy over its move space."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EpsilonSchedule:
    eps_start: float = 1.0
    eps_end: float = 0.02
    decay_epochs: int = 800

    def __call__(self, epoch: jnp.ndarray) -> jnp.ndarray:
        frac = jnp.clip(epoch.astype(jnp.float32) / self.decay_epochs, 0.0, 1.0)
        return self.eps_start + frac * (self.eps_end - self.eps_start)


def perturb_proto(key: jax.Array, proto: jnp.ndarray, eps: jnp.ndarray) -> jnp.ndarray:
    """With probability eps add uniform noise I in [0, 1] to the proto-action."""
    k_bern, k_noise = jax.random.split(key)
    add = jax.random.bernoulli(k_bern, eps)
    noise = jax.random.uniform(k_noise, proto.shape)
    return jnp.where(add, proto + noise, proto)


def epsilon_greedy(key: jax.Array, q_values: jnp.ndarray, eps: jnp.ndarray) -> jnp.ndarray:
    """DQN move selection over flat action logits."""
    k_bern, k_rand = jax.random.split(key)
    explore = jax.random.bernoulli(k_bern, eps)
    rand_a = jax.random.randint(k_rand, (), 0, q_values.shape[-1])
    return jnp.where(explore, rand_a, jnp.argmax(q_values))
