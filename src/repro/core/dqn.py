"""DQN-based DRL baseline (paper §3.2, shown to underperform at scale).

The action space is restricted to single-executor moves: action (i, j)
re-assigns executor i to machine j, giving |A| = N·M.  Q(s, ·) is a single
MLP head over all moves; ε-greedy exploration; replay + target network as
in Mnih et al."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import networks as nets
from repro.core.exploration import EpsilonSchedule, epsilon_greedy
from repro.core.replay import Replay, replay_add, replay_init, replay_sample
from repro.train.optimizer import adam, apply_updates


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    n_executors: int
    n_machines: int
    state_dim: int
    gamma: float = 0.99
    tau: float = 0.01
    batch: int = 32
    buffer: int = 1000
    lr: float = 1e-3
    reward_scale: float = 0.25
    eps: EpsilonSchedule = EpsilonSchedule()

    @property
    def num_actions(self) -> int:
        return self.n_executors * self.n_machines


class DQNState(NamedTuple):
    qnet: nets.MLPParams
    target: nets.MLPParams
    opt: object
    replay: Replay
    epoch: jnp.ndarray
    r_mean: jnp.ndarray = jnp.zeros(())
    r_var: jnp.ndarray = jnp.ones(())
    r_count: jnp.ndarray = jnp.zeros((), jnp.int32)


def init_state(key: jax.Array, cfg: DQNConfig) -> DQNState:
    q = nets.init_qnet(key, cfg.state_dim, cfg.num_actions)
    return DQNState(
        qnet=q,
        target=q,
        opt=adam(cfg.lr).init(q),
        replay=replay_init(cfg.buffer, cfg.state_dim, 1),
        epoch=jnp.zeros((), jnp.int32),
    )


def apply_move(X: jnp.ndarray, move: jnp.ndarray, n_machines: int) -> jnp.ndarray:
    """Move `move // M`-th executor to machine `move % M`."""
    i = move // n_machines
    j = move % n_machines
    return X.at[i].set(jax.nn.one_hot(j, n_machines, dtype=X.dtype))


@partial(jax.jit, static_argnames=("cfg", "explore"))
def select_move(key, state: DQNState, cfg: DQNConfig, s_vec, explore: bool = True):
    q = nets.apply_qnet(state.qnet, s_vec)
    eps = cfg.eps(state.epoch) if explore else jnp.zeros(())
    return epsilon_greedy(key, q, eps)


@partial(jax.jit, static_argnames=("cfg",))
def update_step(key, state: DQNState, cfg: DQNConfig):
    s, a, r, s_next = replay_sample(key, state.replay, cfg.batch)
    a = a[:, 0].astype(jnp.int32)
    q_next = jax.vmap(lambda sv: nets.apply_qnet(state.target, sv))(s_next)
    y = r + cfg.gamma * q_next.max(-1)

    def loss(qp):
        q = jax.vmap(lambda sv: nets.apply_qnet(qp, sv))(s)
        q_sa = jnp.take_along_axis(q, a[:, None], axis=-1)[:, 0]
        return jnp.mean(jnp.square(y - q_sa))

    l, grads = jax.value_and_grad(loss)(state.qnet)
    opt = adam(cfg.lr)
    upd, opt_state = opt.update(grads, state.opt, state.qnet)
    qnet = apply_updates(state.qnet, upd)
    return state._replace(
        qnet=qnet,
        target=nets.soft_update(state.target, qnet, cfg.tau),
        opt=opt_state,
    ), {"loss": l}


def store(state: DQNState, s, move, r, s_next,
          reward_scale: float = 1.0) -> DQNState:
    r = r * reward_scale
    cnt = state.r_count + 1
    alpha = jnp.maximum(0.02, 1.0 / cnt.astype(jnp.float32))
    mean = state.r_mean + alpha * (r - state.r_mean)
    var = (1 - alpha) * state.r_var + alpha * jnp.square(r - mean)
    r_std = jnp.clip((r - mean) / jnp.maximum(jnp.sqrt(var), 1e-4), -10, 10)
    return state._replace(
        replay=replay_add(state.replay, s,
                          jnp.asarray([move], jnp.float32),
                          r_std, s_next),
        r_mean=mean, r_var=var, r_count=cnt)


def tick(state: DQNState) -> DQNState:
    return state._replace(epoch=state.epoch + 1)


# --------------------------------------------------------------------------
# Fused online epoch as a scan body (mirrors ddpg.make_epoch_step) — the
# DQN lane program of the fleet runner in core/agent.py.
# --------------------------------------------------------------------------
def make_epoch_step(env, cfg: DQNConfig, updates_per_epoch: int = 1,
                    explore: bool = True):
    """carry = (DQNState, EnvState, key); emits (reward, latency_ms, moved).
    Key-splitting matches agent.run_online_dqn_python exactly."""
    def epoch_step(carry, _):
        state, env_state, key = carry
        key, k_act, k_step, k_upd = jax.random.split(key, 4)
        s_vec = env.state_vector(env_state)
        move = select_move(k_act, state, cfg, s_vec, explore=explore)
        action = apply_move(env_state.X, move, cfg.n_machines)
        out = env.step(k_step, env_state, action)
        s_next = env.state_vector(out.state)
        state = store(state, s_vec, move, out.reward, s_next,
                      reward_scale=cfg.reward_scale)

        def upd(st, k):
            st, _ = update_step(k, st, cfg)
            return st, None

        state, _ = jax.lax.scan(
            upd, state, jax.random.split(k_upd, updates_per_epoch))
        state = tick(state)
        return (state, out.state, key), (out.reward, out.latency_ms, out.moved)

    return epoch_step


def init_fleet(key: jax.Array, cfg: DQNConfig, fleet: int) -> DQNState:
    """Independently-initialized per-lane states stacked on [fleet]."""
    return jax.vmap(lambda k: init_state(k, cfg))(jax.random.split(key, fleet))
