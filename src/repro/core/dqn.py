"""DQN-based DRL baseline (paper §3.2, shown to underperform at scale).

The action space is restricted to single-executor moves: action (i, j)
re-assigns executor i to machine j, giving |A| = N·M.  Q(s, ·) is a single
MLP head over all moves; ε-greedy exploration; replay + target network as
in Mnih et al."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core import networks as nets
from repro.core.exploration import EpsilonSchedule, epsilon_greedy
from repro.core.replay import Replay, replay_add, replay_init, replay_sample
from repro.train.optimizer import adam, apply_updates


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    n_executors: int
    n_machines: int
    state_dim: int
    gamma: float = 0.99
    tau: float = 0.01
    batch: int = 32
    buffer: int = 1000
    lr: float = 1e-3
    reward_scale: float = 0.25
    eps: EpsilonSchedule = EpsilonSchedule()

    @property
    def num_actions(self) -> int:
        return self.n_executors * self.n_machines


class DQNState(NamedTuple):
    qnet: nets.MLPParams
    target: nets.MLPParams
    opt: object
    replay: Replay
    epoch: jnp.ndarray
    r_mean: jnp.ndarray = jnp.zeros(())
    r_var: jnp.ndarray = jnp.ones(())
    r_count: jnp.ndarray = jnp.zeros((), jnp.int32)


def init_state(key: jax.Array, cfg: DQNConfig) -> DQNState:
    q = nets.init_qnet(key, cfg.state_dim, cfg.num_actions)
    return DQNState(
        qnet=q,
        target=q,
        opt=adam(cfg.lr).init(q),
        replay=replay_init(cfg.buffer, cfg.state_dim, 1),
        epoch=jnp.zeros((), jnp.int32),
    )


def apply_move(X: jnp.ndarray, move: jnp.ndarray, n_machines: int) -> jnp.ndarray:
    """Move `move // M`-th executor to machine `move % M`."""
    i = move // n_machines
    j = move % n_machines
    return X.at[i].set(jax.nn.one_hot(j, n_machines, dtype=X.dtype))


@partial(jax.jit, static_argnames=("cfg", "explore"))
def select_move(key, state: DQNState, cfg: DQNConfig, s_vec, explore: bool = True):
    q = nets.apply_qnet(state.qnet, s_vec)
    eps = cfg.eps(state.epoch) if explore else jnp.zeros(())
    return epsilon_greedy(key, q, eps)


@partial(jax.jit, static_argnames=("cfg",))
def update_step(key, state: DQNState, cfg: DQNConfig):
    s, a, r, s_next = replay_sample(key, state.replay, cfg.batch)
    a = a[:, 0].astype(jnp.int32)
    q_next = jax.vmap(lambda sv: nets.apply_qnet(state.target, sv))(s_next)
    y = r + cfg.gamma * q_next.max(-1)

    def loss(qp):
        q = jax.vmap(lambda sv: nets.apply_qnet(qp, sv))(s)
        q_sa = jnp.take_along_axis(q, a[:, None], axis=-1)[:, 0]
        return jnp.mean(jnp.square(y - q_sa))

    l, grads = jax.value_and_grad(loss)(state.qnet)
    opt = adam(cfg.lr)
    upd, opt_state = opt.update(grads, state.opt, state.qnet)
    qnet = apply_updates(state.qnet, upd)
    return state._replace(
        qnet=qnet,
        target=nets.soft_update(state.target, qnet, cfg.tau),
        opt=opt_state,
    ), {"loss": l}


def store(state: DQNState, s, move, r, s_next,
          reward_scale: float = 1.0) -> DQNState:
    r = r * reward_scale
    cnt = state.r_count + 1
    alpha = jnp.maximum(0.02, 1.0 / cnt.astype(jnp.float32))
    mean = state.r_mean + alpha * (r - state.r_mean)
    var = (1 - alpha) * state.r_var + alpha * jnp.square(r - mean)
    r_std = jnp.clip((r - mean) / jnp.maximum(jnp.sqrt(var), 1e-4), -10, 10)
    return state._replace(
        replay=replay_add(state.replay, s,
                          jnp.asarray([move], jnp.float32),
                          r_std, s_next),
        r_mean=mean, r_var=var, r_count=cnt)


def tick(state: DQNState) -> DQNState:
    return state._replace(epoch=state.epoch + 1)


# --------------------------------------------------------------------------
# Agent-interface adapter (mirrors ddpg's) — the DQN hooks of the generic
# fused epoch body in api.make_epoch_step.
# --------------------------------------------------------------------------
def _agent_init(key, cfg: DQNConfig, env_params=None):
    return init_state(key, cfg)


def _agent_select(key, cfg: DQNConfig, state, s_vec, env_state, env_params,
                  explore):
    move = select_move(key, state, cfg, s_vec, explore=explore)
    return apply_move(env_state.X, move, cfg.n_machines), move


def _agent_observe(cfg: DQNConfig, state, s_vec, aux, reward, s_next):
    return store(state, s_vec, aux, reward, s_next,
                 reward_scale=cfg.reward_scale)


def _agent_update(key, cfg: DQNConfig, state):
    state, _ = update_step(key, state, cfg)
    return state


def _agent_tick(cfg: DQNConfig, state):
    return tick(state)


def as_agent(cfg: DQNConfig) -> api.Agent:
    """The DQN baseline as a pluggable Agent bundle."""
    return api.Agent(name="dqn", cfg=cfg, init_fn=_agent_init,
                     select_fn=_agent_select, observe_fn=_agent_observe,
                     update_fn=_agent_update, tick_fn=_agent_tick)


def agent_factory(env, **overrides) -> api.Agent:
    """Registry hook: size a DQNConfig for ``env`` (or pass ``cfg=``)."""
    cfg = overrides.pop("cfg", None)
    if cfg is None:
        cfg = DQNConfig(n_executors=env.N, n_machines=env.M,
                        state_dim=env.state_dim, **overrides)
    return as_agent(cfg)


api.register_agent("dqn", agent_factory)


def make_epoch_step(env, cfg: DQNConfig, updates_per_epoch: int = 1,
                    explore: bool = True, env_params=None):
    """carry = (DQNState, EnvState, key); emits (reward, latency_ms, moved).
    Compat wrapper over api.make_epoch_step — key-splitting matches
    agent.run_online_dqn_python exactly."""
    return api.make_epoch_step(env, as_agent(cfg), env_params=env_params,
                               updates_per_epoch=updates_per_epoch,
                               explore=explore)


def init_fleet(key: jax.Array, cfg: DQNConfig, fleet: int) -> DQNState:
    """Independently-initialized per-lane states stacked on [fleet]."""
    return jax.vmap(lambda k: init_state(k, cfg))(jax.random.split(key, fleet))
