"""Unified online-learning control loops (the paper's decision-epoch loop).

These drive any environment exposing the SchedulingEnv surface
(reset / step / state_vector / random_assignment) — the DSDPS simulator or
the TPU expert-placement environment — with either the actor-critic method
(Algorithm 1) or the DQN baseline, producing the reward traces of
Figs 7/9/11."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddpg, dqn
from repro.core.ddpg import DDPGConfig, DDPGState
from repro.core.dqn import DQNConfig, DQNState


@dataclasses.dataclass
class History:
    rewards: np.ndarray
    latencies: np.ndarray
    moved: np.ndarray
    final_assignment: np.ndarray

    def normalized_rewards(self) -> np.ndarray:
        """(r - r_min)/(r_max - r_min), the paper's normalization."""
        r = self.rewards
        lo, hi = r.min(), r.max()
        return (r - lo) / max(hi - lo, 1e-12)

    def smoothed_rewards(self, cutoff: float = 0.05) -> np.ndarray:
        """Forward-backward (zero-phase) low-pass filter, as in the paper
        ([20] Gustafsson filtfilt)."""
        from scipy.signal import butter, filtfilt
        b, a = butter(2, cutoff)
        r = self.normalized_rewards()
        if len(r) < 15:
            return r
        return filtfilt(b, a, r)


def run_online_ddpg(
    key: jax.Array,
    env,
    cfg: DDPGConfig,
    state: DDPGState,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
) -> tuple[DDPGState, History]:
    k_env, key = jax.random.split(key)
    env_state = env.reset(k_env)
    rewards, lats, moved = [], [], []

    for t in range(T):
        key, k_act, k_step, k_upd = jax.random.split(key, 4)
        s_vec = env.state_vector(env_state)
        action = ddpg.select_action_jit(k_act, state, cfg, s_vec, explore=explore)
        out = env.step(k_step, env_state, action)
        s_next = env.state_vector(out.state)
        state = ddpg.store(state, s_vec, action.reshape(-1), out.reward, s_next,
                           reward_scale=cfg.reward_scale)
        for k in jax.random.split(k_upd, updates_per_epoch):
            state, _ = ddpg.update_step(k, state, cfg)
        state = ddpg.tick(state)
        env_state = out.state
        rewards.append(float(out.reward))
        lats.append(float(out.latency_ms))
        moved.append(int(out.moved))

    return state, History(
        rewards=np.asarray(rewards),
        latencies=np.asarray(lats),
        moved=np.asarray(moved),
        final_assignment=np.asarray(env_state.X),
    )


def run_online_dqn(
    key: jax.Array,
    env,
    cfg: DQNConfig,
    state: DQNState,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
) -> tuple[DQNState, History]:
    k_env, key = jax.random.split(key)
    env_state = env.reset(k_env)
    rewards, lats, moved = [], [], []

    for t in range(T):
        key, k_act, k_step, k_upd = jax.random.split(key, 4)
        s_vec = env.state_vector(env_state)
        move = dqn.select_move(k_act, state, cfg, s_vec, explore=explore)
        action = dqn.apply_move(env_state.X, move, cfg.n_machines)
        out = env.step(k_step, env_state, action)
        s_next = env.state_vector(out.state)
        state = dqn.store(state, s_vec, move, out.reward, s_next,
                          reward_scale=cfg.reward_scale)
        for k in jax.random.split(k_upd, updates_per_epoch):
            state, _ = dqn.update_step(k, state, cfg)
        state = dqn.tick(state)
        env_state = out.state
        rewards.append(float(out.reward))
        lats.append(float(out.latency_ms))
        moved.append(int(out.moved))

    return state, History(
        rewards=np.asarray(rewards),
        latencies=np.asarray(lats),
        moved=np.asarray(moved),
        final_assignment=np.asarray(env_state.X),
    )


def greedy_assignment_ddpg(key, env, cfg: DDPGConfig, state: DDPGState,
                           env_state) -> jnp.ndarray:
    """Deploy-time action of a trained agent (no exploration)."""
    s_vec = env.state_vector(env_state)
    return ddpg.select_action(key, state, cfg, s_vec, explore=False,
                              exact_host_knn=True)
