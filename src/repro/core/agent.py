"""Unified online-learning control loops (the paper's decision-epoch loop).

These drive any environment exposing the functional core surface
(``reset(key, params)`` / ``step(key, state, action, params)`` /
``state_vector(state, params)`` / ``default_params()``) — the DSDPS
simulator or the TPU expert-placement environment — with any
:class:`repro.core.api.Agent` (actor-critic Algorithm 1, the DQN baseline,
or the non-learning round-robin / model-based baselines), producing the
reward traces of Figs 7/9/11.

Three execution paths:

  * ``run_online_ddpg`` / ``run_online_dqn`` — ONE online run, executed as
    a single jitted ``jax.lax.scan`` over decision epochs (thin
    compatibility wrappers over the Agent path);

  * ``run_online_fleet`` — MANY independent runs executed as one XLA
    program: ``jax.vmap`` over a fleet axis of the same scan.  Lanes may
    differ by seed, by initial EnvState, AND by scenario: pass stacked
    :class:`~repro.dsdps.simulator.EnvParams` (repro.dsdps.scenarios) and
    heterogeneous workload rates × service-time jitter × noise levels ×
    stragglers train in ONE program.  This is what makes Decima-style
    train-over-a-distribution-of-workloads affordable here.

Executable caching is jit's own: the env spec and the Agent bundle are
hashable static arguments of module-level jitted programs, and EnvParams
are traced, so re-running with new scenario parameters never recompiles.
(The pre-v1 ``id(env)``-keyed ``_RUNNER_CACHE`` is gone.)

The legacy per-epoch Python loops are kept as ``run_online_*_python`` —
they are the bit-exactness reference for the scan runners
(tests/test_fleet_runner.py) and the baseline of benchmarks/fleet_bench.py."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddpg, dqn
from repro.core.api import Agent, make_epoch_step
from repro.core.ddpg import DDPGConfig, DDPGState
from repro.core.dqn import DQNConfig, DQNState


@dataclasses.dataclass
class History:
    """Reward / latency / movement traces of one run ([T]) or of a fleet of
    runs ([fleet, T]); final_assignment is [N, M] or [fleet, N, M]."""

    rewards: np.ndarray
    latencies: np.ndarray
    moved: np.ndarray
    final_assignment: np.ndarray

    @property
    def fleet(self) -> int | None:
        """Fleet size, or None for a single-run history."""
        return self.rewards.shape[0] if self.rewards.ndim == 2 else None

    def lane(self, i: int) -> "History":
        """The i-th run of a fleet history as a single-run History."""
        if self.fleet is None:
            raise ValueError("lane() on a single-run History")
        return History(rewards=self.rewards[i], latencies=self.latencies[i],
                       moved=self.moved[i],
                       final_assignment=self.final_assignment[i])

    def normalized_rewards(self) -> np.ndarray:
        """(r - r_min)/(r_max - r_min), the paper's normalization —
        per-lane (along the epoch axis) for fleet histories."""
        r = self.rewards
        lo = r.min(axis=-1, keepdims=True)
        hi = r.max(axis=-1, keepdims=True)
        return (r - lo) / np.maximum(hi - lo, 1e-12)

    def smoothed_rewards(self, cutoff: float = 0.05) -> np.ndarray:
        """Forward-backward (zero-phase) low-pass filter, as in the paper
        ([20] Gustafsson filtfilt).  Falls back to a numpy forward-backward
        moving average when scipy is unavailable."""
        r = self.normalized_rewards()
        if r.shape[-1] < 15:
            return r
        try:
            from scipy.signal import butter, filtfilt
        except ImportError:
            return _smooth_moving_average(r, cutoff)
        b, a = butter(2, cutoff)
        return filtfilt(b, a, r, axis=-1)

    def seed_band(self, cutoff: float = 0.05) -> tuple[np.ndarray, np.ndarray]:
        """(mean, std) across the fleet axis of the smoothed normalized
        reward curves — the seed-averaged curve + variance band plotted by
        the paper_fig benchmarks."""
        r = np.atleast_2d(self.smoothed_rewards(cutoff))
        return r.mean(axis=0), r.std(axis=0)


def _smooth_moving_average(r: np.ndarray, cutoff: float) -> np.ndarray:
    """Scipy-free zero-phase smoother: an edge-padded moving average of
    width ~1/cutoff applied forward then backward (symmetric kernel, so the
    result is zero-phase like filtfilt; slightly softer roll-off)."""
    win = max(3, int(round(1.0 / max(cutoff, 1e-3))))
    win = min(win, r.shape[-1])
    kernel = np.ones(win) / win
    pad = (win // 2, win - 1 - win // 2)

    def one_pass(x: np.ndarray) -> np.ndarray:
        return np.convolve(np.pad(x, pad, mode="edge"), kernel, mode="valid")

    sm = np.apply_along_axis(one_pass, -1, r)
    sm = np.apply_along_axis(lambda x: one_pass(x[::-1])[::-1], -1, sm)
    return sm


def as_agent(agent_or_cfg, name: str | None = None) -> Agent:
    """Coerce a bare DDPGConfig / DQNConfig into its Agent bundle (the
    deprecation shim behind the pre-v1 ``run_online_*(..., cfg, ...)``
    call style); Agent instances pass through."""
    if isinstance(agent_or_cfg, Agent):
        return agent_or_cfg
    if isinstance(agent_or_cfg, DDPGConfig):
        return ddpg.as_agent(agent_or_cfg)
    if isinstance(agent_or_cfg, DQNConfig):
        return dqn.as_agent(agent_or_cfg)
    raise TypeError(f"expected an Agent or a DDPG/DQN config, got "
                    f"{type(agent_or_cfg).__name__}")


# --------------------------------------------------------------------------
# The two jitted programs.  env + agent are hashable static arguments —
# jit's cache replaces the old id(env)-keyed runner cache — and EnvParams
# ride as traced pytrees, so scenario changes never recompile.  Executables
# (and the env specs they key on) live for the process: far fewer entries
# than the old per-env-instance cache since params changes reuse programs,
# but a sweep over many (env, agent, T) combos can call jax.clear_caches()
# between apps if memory matters.
# --------------------------------------------------------------------------
@partial(jax.jit,
         static_argnames=("env", "agent", "T", "updates_per_epoch", "explore"))
def _single_program(key, state, env_state, env_params, *, env, agent: Agent,
                    T: int, updates_per_epoch: int, explore: bool):
    epoch = make_epoch_step(env, agent, env_params=env_params,
                            updates_per_epoch=updates_per_epoch,
                            explore=explore)
    (state, env_state, _), (rewards, lats, moved) = jax.lax.scan(
        epoch, (state, env_state, key), None, length=T)
    return state, rewards, lats, moved, env_state.X


@partial(jax.jit,
         static_argnames=("env", "agent", "T", "updates_per_epoch", "explore",
                          "params_axes"))
def _fleet_program(keys, states, env_states, env_params, *, env, agent: Agent,
                   T: int, updates_per_epoch: int, explore: bool,
                   params_axes):
    """``params_axes`` is the per-leaf vmap axis spec for ``env_params``
    (simulator.params_in_axes): an EnvParams-shaped pytree of 0/None —
    scenario-invariant leaves broadcast with None instead of being stacked
    F× — or plain None when every lane shares one scenario.  It is a
    hashable NamedTuple of ints/None, so it rides jit as a static arg."""
    def lane(key, state, env_state, lane_params):
        epoch = make_epoch_step(env, agent, env_params=lane_params,
                                updates_per_epoch=updates_per_epoch,
                                explore=explore)
        (state, env_state, _), (rewards, lats, moved) = jax.lax.scan(
            epoch, (state, env_state, key), None, length=T)
        return state, rewards, lats, moved, env_state.X

    in_axes = (0, 0, 0, params_axes)
    return jax.vmap(lane, in_axes=in_axes)(keys, states, env_states,
                                           env_params)


def _run_single(key, env, agent_or_cfg, state, T, updates_per_epoch, explore,
                env_params=None):
    agent = as_agent(agent_or_cfg)
    params = env.default_params() if env_params is None else env_params
    k_env, key = jax.random.split(key)
    env_state = env.reset(k_env, params)
    state, rewards, lats, moved, X = _single_program(
        key, state, env_state, params, env=env, agent=agent, T=int(T),
        updates_per_epoch=int(updates_per_epoch), explore=bool(explore))
    return state, History(rewards=np.asarray(rewards),
                          latencies=np.asarray(lats),
                          moved=np.asarray(moved),
                          final_assignment=np.asarray(X))


def run_online_ddpg(
    key: jax.Array,
    env,
    cfg: DDPGConfig,
    state: DDPGState,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
    env_params=None,
) -> tuple[DDPGState, History]:
    """One online actor-critic run as a single jitted scan over epochs
    (compat wrapper over the Agent path)."""
    return _run_single(key, env, cfg, state, T, updates_per_epoch, explore,
                       env_params=env_params)


def run_online_dqn(
    key: jax.Array,
    env,
    cfg: DQNConfig,
    state: DQNState,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
    env_params=None,
) -> tuple[DQNState, History]:
    """One online DQN run as a single jitted scan over epochs (compat
    wrapper over the Agent path)."""
    return _run_single(key, env, cfg, state, T, updates_per_epoch, explore,
                       env_params=env_params)


def run_online_agent(
    key: jax.Array,
    env,
    agent: Agent,
    state,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
    env_params=None,
):
    """One online run of any registry agent (the v1-native single-run
    entry point)."""
    return _run_single(key, env, agent, state, T, updates_per_epoch, explore,
                       env_params=env_params)


def run_online_fleet(
    keys: jax.Array,
    env,
    agent,
    states,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
    env_states=None,
    env_params=None,
):
    """Fleet-batched online learning: one XLA program for [fleet] runs.

    ``keys``   — stacked per-lane PRNG keys ([fleet] key array);
    ``agent``  — an api.Agent (make_agent(...)) or, for compatibility, a
                 bare DDPGConfig / DQNConfig;
    ``states`` — per-lane agent states stacked on a leading [fleet] axis
                 (agent.init_fleet / ddpg.init_fleet / dqn.init_fleet,
                 optionally pretrained with ddpg.offline_pretrain_fleet);
    ``env_params`` — a single EnvParams (shared by every lane) or a STACKED
                 EnvParams scenario fleet ([F] leading axis, e.g. from
                 repro.dsdps.scenarios): heterogeneous workload rates,
                 service-time jitter, noise levels, and stragglers then run
                 as one vmapped program.  Defaults to env.default_params().
                 Stacks built with ``stack_env_params(...,
                 broadcast_invariant=True)`` keep scenario-invariant leaves
                 (routing / flow_solve / tuple_bytes) as ONE copy; those
                 leaves ride the vmap with per-leaf ``in_axes=None`` —
                 numerically identical to the fully-stacked run, minus the
                 duplicated memory and batched-matmul FLOPs.
    ``env_states`` — optional stacked EnvState (SchedulingEnv.reset_fleet)
                 for heterogeneous *initial state* lanes: per-lane straggler
                 speed factors, initial assignments, warm workload states.
                 When omitted, every lane resets the env exactly as the
                 single-run API does (so fleet lane i bit-matches a
                 run_online_* call with the same key, initial state, and
                 params lane).

    Returns (stacked agent states, History with [fleet, T] traces)."""
    agent = as_agent(agent)
    keys = jnp.asarray(keys)
    if env_params is None:
        env_params = env.default_params()
        params_axes = None
    else:
        from repro.dsdps.simulator import params_in_axes
        params_axes = params_in_axes(env_params, env.default_params())
    if env_states is None:
        pairs = jax.vmap(jax.random.split)(keys)          # [F, 2] keys
        k_env, keys = pairs[:, 0], pairs[:, 1]
        if params_axes is not None:
            env_states = jax.vmap(env.reset, in_axes=(0, params_axes))(
                k_env, env_params)
        else:
            env_states = jax.vmap(lambda k: env.reset(k, env_params))(k_env)
    states, rewards, lats, moved, X = _fleet_program(
        keys, states, env_states, env_params, env=env, agent=agent, T=int(T),
        updates_per_epoch=int(updates_per_epoch), explore=bool(explore),
        params_axes=params_axes)
    return states, History(rewards=np.asarray(rewards),
                           latencies=np.asarray(lats),
                           moved=np.asarray(moved),
                           final_assignment=np.asarray(X))


# --------------------------------------------------------------------------
# Legacy per-epoch Python loops — the reference semantics.  Kept unchanged
# as (a) the regression oracle for the scan runners and (b) the sequential
# baseline the fleet microbenchmark measures its speedup against.
# --------------------------------------------------------------------------
def run_online_ddpg_python(
    key: jax.Array,
    env,
    cfg: DDPGConfig,
    state: DDPGState,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
) -> tuple[DDPGState, History]:
    k_env, key = jax.random.split(key)
    env_state = env.reset(k_env)
    rewards, lats, moved = [], [], []

    for t in range(T):
        key, k_act, k_step, k_upd = jax.random.split(key, 4)
        s_vec = env.state_vector(env_state)
        action = ddpg.select_action_jit(k_act, state, cfg, s_vec, explore=explore)
        out = env.step(k_step, env_state, action)
        s_next = env.state_vector(out.state)
        state = ddpg.store(state, s_vec, action.reshape(-1), out.reward, s_next,
                           reward_scale=cfg.reward_scale)
        for k in jax.random.split(k_upd, updates_per_epoch):
            state, _ = ddpg.update_step(k, state, cfg)
        state = ddpg.tick(state)
        env_state = out.state
        rewards.append(float(out.reward))
        lats.append(float(out.latency_ms))
        moved.append(int(out.moved))

    return state, History(
        rewards=np.asarray(rewards),
        latencies=np.asarray(lats),
        moved=np.asarray(moved),
        final_assignment=np.asarray(env_state.X),
    )


def run_online_dqn_python(
    key: jax.Array,
    env,
    cfg: DQNConfig,
    state: DQNState,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
) -> tuple[DQNState, History]:
    k_env, key = jax.random.split(key)
    env_state = env.reset(k_env)
    rewards, lats, moved = [], [], []

    for t in range(T):
        key, k_act, k_step, k_upd = jax.random.split(key, 4)
        s_vec = env.state_vector(env_state)
        move = dqn.select_move(k_act, state, cfg, s_vec, explore=explore)
        action = dqn.apply_move(env_state.X, move, cfg.n_machines)
        out = env.step(k_step, env_state, action)
        s_next = env.state_vector(out.state)
        state = dqn.store(state, s_vec, move, out.reward, s_next,
                          reward_scale=cfg.reward_scale)
        for k in jax.random.split(k_upd, updates_per_epoch):
            state, _ = dqn.update_step(k, state, cfg)
        state = dqn.tick(state)
        env_state = out.state
        rewards.append(float(out.reward))
        lats.append(float(out.latency_ms))
        moved.append(int(out.moved))

    return state, History(
        rewards=np.asarray(rewards),
        latencies=np.asarray(lats),
        moved=np.asarray(moved),
        final_assignment=np.asarray(env_state.X),
    )


def greedy_assignment_ddpg(key, env, cfg: DDPGConfig, state: DDPGState,
                           env_state) -> jnp.ndarray:
    """Deploy-time action of a trained agent (no exploration)."""
    s_vec = env.state_vector(env_state)
    return ddpg.select_action(key, state, cfg, s_vec, explore=False,
                              exact_host_knn=True)
