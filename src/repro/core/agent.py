"""Unified online-learning control loops (the paper's decision-epoch loop).

These drive any environment exposing the functional core surface
(``reset(key, params)`` / ``step(key, state, action, params)`` /
``state_vector(state, params)`` / ``default_params()``) — the DSDPS
simulator or the TPU expert-placement environment — with any
:class:`repro.core.api.Agent` (actor-critic Algorithm 1, the DQN baseline,
or the non-learning round-robin / model-based baselines), producing the
reward traces of Figs 7/9/11.

Three execution paths:

  * ``run_online_agent`` — ONE online run of any registry agent, executed
    as a single jitted ``jax.lax.scan`` over decision epochs;

  * ``run_online_fleet`` — MANY independent runs executed as one XLA
    program: ``jax.vmap`` over a fleet axis of the same scan.  Lanes may
    differ by seed, by initial EnvState, AND by scenario: pass stacked
    :class:`~repro.dsdps.simulator.EnvParams` (repro.dsdps.scenarios) and
    heterogeneous workload rates × service-time jitter × noise levels ×
    stragglers train in ONE program.  This is what makes Decima-style
    train-over-a-distribution-of-workloads affordable here.

  * ``run_online_fleet(..., mesh=...)`` — the same fleet partitioned over
    a device mesh: the fleet axis of every carry (keys, agent states, env
    states, stacked EnvParams leaves) shards over the mesh's data axes
    via ``shard_map`` (repro/sharding/fleet.py), so fleet capacity is the
    whole mesh's memory, not one accelerator's.  On real accelerators the
    carries are donated (the epoch scan runs in-place); on the 1-device
    host mesh the path is bit-comparable to the plain vmap runner.
    Passing ``checkpoint=`` (a
    :class:`repro.checkpoint.fleet.FleetCheckpoint`) chunks the epoch
    scan every ``checkpoint.every`` epochs and atomically snapshots the
    carries in the background — the device→host transfer itself runs off
    the caller thread, so the mesh keeps scanning while the previous
    chunk serializes — and long heterogeneous-scenario runs survive
    restarts and device-count changes (docs/sharded_fleets.md).  Passing
    ``lifecycle=`` (a :class:`repro.fleet.lifecycle.StopRule`) makes the
    fleet ELASTIC: lanes whose smoothed reward plateaus stop early and
    the surviving lanes are compacted into a smaller fleet between
    chunks, so converged scenarios stop paying compute
    (docs/elastic_fleets.md).

Executable caching is jit's own: the env spec and the Agent bundle are
hashable static arguments of module-level jitted programs, and EnvParams
are traced, so re-running with new scenario parameters never recompiles.
(The pre-v1 ``id(env)``-keyed ``_RUNNER_CACHE`` is gone, and the PR-2
``run_online_ddpg`` / ``run_online_dqn`` bare-config wrappers were
removed when their deprecation window closed — build an Agent with
``make_agent(name, env, cfg=...)`` instead.)

The legacy per-epoch Python loops are kept as ``run_online_*_python`` —
they are the bit-exactness reference for the scan runners
(tests/test_fleet_runner.py) and the baseline of benchmarks/fleet_bench.py."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map

from repro.core import ddpg, dqn
from repro.core.api import Agent, make_epoch_step
from repro.diagnostics import maybe_check_finite
from repro.core.ddpg import DDPGConfig, DDPGState
from repro.core.dqn import DQNConfig, DQNState
from repro.sharding.fleet import fleet_host, fleet_spec, shard_fleet


@dataclasses.dataclass
class History:
    """Reward / latency / movement traces of one run ([T]) or of a fleet of
    runs ([fleet, T]); final_assignment is [N, M] or [fleet, N, M]."""

    rewards: np.ndarray
    latencies: np.ndarray
    moved: np.ndarray
    final_assignment: np.ndarray

    @property
    def fleet(self) -> int | None:
        """Fleet size, or None for a single-run history."""
        return self.rewards.shape[0] if self.rewards.ndim == 2 else None

    def lane(self, i: int) -> "History":
        """The i-th run of a fleet history as a single-run History."""
        if self.fleet is None:
            raise ValueError("lane() on a single-run History")
        return History(rewards=self.rewards[i], latencies=self.latencies[i],
                       moved=self.moved[i],
                       final_assignment=self.final_assignment[i])

    def normalized_rewards(self) -> np.ndarray:
        """(r - r_min)/(r_max - r_min), the paper's normalization —
        per-lane (along the epoch axis) for fleet histories."""
        r = self.rewards
        lo = r.min(axis=-1, keepdims=True)
        hi = r.max(axis=-1, keepdims=True)
        return (r - lo) / np.maximum(hi - lo, 1e-12)

    def smoothed_rewards(self, cutoff: float = 0.05) -> np.ndarray:
        """Forward-backward (zero-phase) low-pass filter, as in the paper
        ([20] Gustafsson filtfilt).  Falls back to a numpy forward-backward
        moving average when scipy is unavailable."""
        r = self.normalized_rewards()
        if r.shape[-1] < 15:
            return r
        try:
            from scipy.signal import butter, filtfilt
        except ImportError:
            return _smooth_moving_average(r, cutoff)
        b, a = butter(2, cutoff)
        return filtfilt(b, a, r, axis=-1)

    def seed_band(self, cutoff: float = 0.05) -> tuple[np.ndarray, np.ndarray]:
        """(mean, std) across the fleet axis of the smoothed normalized
        reward curves — the seed-averaged curve + variance band plotted by
        the paper_fig benchmarks."""
        r = np.atleast_2d(self.smoothed_rewards(cutoff))
        return r.mean(axis=0), r.std(axis=0)


def _smooth_moving_average(r: np.ndarray, cutoff: float) -> np.ndarray:
    """Scipy-free zero-phase smoother: an edge-padded moving average of
    width ~1/cutoff applied forward then backward (symmetric kernel, so the
    result is zero-phase like filtfilt; slightly softer roll-off)."""
    win = max(3, int(round(1.0 / max(cutoff, 1e-3))))
    win = min(win, r.shape[-1])
    kernel = np.ones(win) / win
    pad = (win // 2, win - 1 - win // 2)

    def one_pass(x: np.ndarray) -> np.ndarray:
        return np.convolve(np.pad(x, pad, mode="edge"), kernel, mode="valid")

    sm = np.apply_along_axis(one_pass, -1, r)
    sm = np.apply_along_axis(lambda x: one_pass(x[::-1])[::-1], -1, sm)
    return sm


def _require_agent(agent) -> Agent:
    """The runners take api.Agent bundles only.  (The PR-2 deprecation
    window during which bare DDPG/DQN configs were coerced has closed.)"""
    if not isinstance(agent, Agent):
        raise TypeError(
            f"expected an api.Agent, got {type(agent).__name__}; build one "
            f"with make_agent(name, env, cfg=...) or ddpg/dqn.as_agent(cfg) "
            f"(the pre-v1 bare-config call style was removed)")
    return agent


# --------------------------------------------------------------------------
# The jitted programs.  env + agent are hashable static arguments — jit's
# cache replaces the old id(env)-keyed runner cache — and EnvParams ride
# as traced pytrees, so scenario changes never recompile.  Executables
# (and the env specs they key on) live for the process: far fewer entries
# than the old per-env-instance cache since params changes reuse programs,
# but a sweep over many (env, agent, T) combos can call jax.clear_caches()
# between apps if memory matters.
# --------------------------------------------------------------------------
@partial(jax.jit,
         static_argnames=("env", "agent", "T", "updates_per_epoch", "explore"))
def _single_program(key, state, env_state, env_params, *, env, agent: Agent,
                    T: int, updates_per_epoch: int, explore: bool):
    epoch = make_epoch_step(env, agent, env_params=env_params,
                            updates_per_epoch=updates_per_epoch,
                            explore=explore)
    (state, env_state, _), (rewards, lats, moved) = jax.lax.scan(
        epoch, (state, env_state, key), None, length=T)
    return state, rewards, lats, moved, env_state.X


def _fleet_fn(keys, states, env_states, env_params, *, env, agent: Agent,
              T: int, updates_per_epoch: int, explore: bool, params_axes):
    """The fleet body: vmap of the fused epoch scan over the lane axis.

    ``params_axes`` is the per-leaf vmap axis spec for ``env_params``
    (simulator.params_in_axes): an EnvParams-shaped pytree of 0/None —
    scenario-invariant leaves broadcast with None instead of being stacked
    F× — or plain None when every lane shares one scenario.  It is a
    hashable NamedTuple of ints/None, so it rides jit as a static arg.

    Returns the FULL evolved carries ``(states, env_states, keys)`` plus
    the ``(rewards, lats, moved)`` traces — the carries are what fleet
    checkpointing snapshots and what chunked runs thread from one scan
    call into the next."""
    def lane(key, state, env_state, lane_params):
        epoch = make_epoch_step(env, agent, env_params=lane_params,
                                updates_per_epoch=updates_per_epoch,
                                explore=explore)
        (state, env_state, key), (rewards, lats, moved) = jax.lax.scan(
            epoch, (state, env_state, key), None, length=T)
        return state, env_state, key, rewards, lats, moved

    in_axes = (0, 0, 0, params_axes)
    return jax.vmap(lane, in_axes=in_axes)(keys, states, env_states,
                                           env_params)


_FLEET_STATICS = ("env", "agent", "T", "updates_per_epoch", "explore",
                  "params_axes")
_fleet_program = jax.jit(_fleet_fn, static_argnames=_FLEET_STATICS)


def _sharded_fleet_fn(keys, states, env_states, env_params, *, env,
                      agent: Agent, T: int, updates_per_epoch: int,
                      explore: bool, params_axes, mesh, params_specs):
    """The fleet body wrapped in ``shard_map``: every carry partitions its
    leading fleet axis over the mesh's data axes; ``params_specs``
    (sharding.fleet.params_partition_specs) replicates broadcast-invariant
    EnvParams leaves instead of sharding them.  Lanes are independent, so
    the body needs no collectives — each device runs the vmapped scan over
    its local lanes (check_rep stays off: no replicated outputs to
    certify, and the scan body trips no replication rules)."""
    spec = fleet_spec(mesh)
    body = partial(_fleet_fn, env=env, agent=agent, T=T,
                   updates_per_epoch=updates_per_epoch, explore=explore,
                   params_axes=params_axes)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(spec, spec, spec, params_specs),
                   out_specs=(spec, spec, spec, spec, spec, spec),
                   check_rep=False)
    return fn(keys, states, env_states, env_params)


_SHARDED_STATICS = _FLEET_STATICS + ("mesh", "params_specs")
_fleet_program_sharded = jax.jit(_sharded_fleet_fn,
                                 static_argnames=_SHARDED_STATICS)
# Donated variant for real accelerator meshes: the carries (keys, agent
# states, env states) are consumed in place, halving fleet memory across
# chunked checkpointed runs.  CPU meshes use the non-donated program (jax
# cannot donate on cpu and would warn on every call).
_fleet_program_sharded_donated = jax.jit(_sharded_fleet_fn,
                                         static_argnames=_SHARDED_STATICS,
                                         donate_argnums=(0, 1, 2))


def run_fleet_chunk(keys, states, env_states, env_params, *, env,
                    agent: Agent, T: int, updates_per_epoch: int,
                    explore: bool, params_axes, mesh=None, params_specs=None):
    """One chunk of the fleet epoch scan: the shared execution primitive
    behind ``run_online_fleet``'s checkpoint chunking and the elastic lane
    lifecycle's stop-check boundaries (repro/fleet/lifecycle.py).

    The inputs must already be placed (``sharding.fleet.shard_fleet``) when
    ``mesh`` is given; ``params_specs`` is the hashable PartitionSpec tree
    that placement returned.  On accelerator meshes the carries are DONATED
    — slice anything you still need out of them (e.g. a stopped lane's
    final state) before calling again.  Returns the evolved carries plus
    the ``[fleet, T]`` traces: ``(states, env_states, keys, rewards,
    latencies, moved)``."""
    common = dict(env=env, agent=agent, T=int(T),
                  updates_per_epoch=int(updates_per_epoch),
                  explore=bool(explore), params_axes=params_axes)
    if mesh is not None:
        donate = mesh.devices.flat[0].platform != "cpu"
        program = (_fleet_program_sharded_donated if donate
                   else _fleet_program_sharded)
        common.update(mesh=mesh, params_specs=params_specs)
    else:
        program = _fleet_program
    return program(keys, states, env_states, env_params, **common)


def chunk_schedule(T: int, every: int | None) -> list[int]:
    """Chunk lengths for a ``T``-epoch scan cut every ``every`` epochs
    (trailing partial chunk included); ``[T]`` when ``every`` is falsy."""
    if not every:
        return [T]
    chunks = [every] * (T // every)
    if T % every:
        chunks.append(T % every)
    return chunks


def prepare_fleet(keys, env, states, env_states, env_params, mesh):
    """The fleet runners' shared setup preamble: default-params /
    ``params_axes`` resolution, the env-reset key split, and mesh
    placement.  The elastic runner's loss-free bit-match contract depends
    on this staying IDENTICAL between the fixed-grid and elastic entry
    points, which is why it is one function.

    Returns ``(keys, states, env_states, env_params, ref, params_axes,
    params_specs)``."""
    # setup preamble exemption: placing hosts arrays on devices is this
    # function's JOB, so the diagnostics transfer guard (which polices the
    # steady-state chunk loop) is lifted for its dynamic extent
    with jax.transfer_guard("allow"):
        keys = jnp.asarray(keys)
        ref = env.default_params()
        if env_params is None:
            env_params = ref
            params_axes = None
        else:
            from repro.dsdps.simulator import params_in_axes
            params_axes = params_in_axes(env_params, ref)
        if env_states is None:
            pairs = jax.vmap(jax.random.split)(keys)      # [F, 2] keys
            k_env, keys = pairs[:, 0], pairs[:, 1]
            env_states = reset_fleet_states(k_env, env, env_params)
        params_specs = None
        if mesh is not None:
            keys, states, env_states, env_params, params_specs = shard_fleet(
                mesh, keys, states, env_states, env_params, ref)
        return keys, states, env_states, env_params, ref, params_axes, \
            params_specs


def _run_single(key, env, agent, state, T, updates_per_epoch, explore,
                env_params=None):
    agent = _require_agent(agent)
    params = env.default_params() if env_params is None else env_params
    k_env, key = jax.random.split(key)
    env_state = env.reset(k_env, params)
    state, rewards, lats, moved, X = _single_program(
        key, state, env_state, params, env=env, agent=agent, T=int(T),
        updates_per_epoch=int(updates_per_epoch), explore=bool(explore))
    return state, History(rewards=np.asarray(rewards),
                          latencies=np.asarray(lats),
                          moved=np.asarray(moved),
                          final_assignment=np.asarray(X))


def run_online_agent(
    key: jax.Array,
    env,
    agent: Agent,
    state,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
    env_params=None,
):
    """One online run of any registry agent as a single jitted scan over
    ``T`` decision epochs.

    ``key`` is split once for the env reset, then carried through the
    fused epoch scan with the same key discipline as the legacy Python
    oracles (``run_online_*_python``), so the scan reproduces their
    traces.  ``env_params`` is a single scenario pytree (defaults to
    ``env.default_params()``).  Returns ``(agent_state, History)`` with
    ``[T]`` traces."""
    return _run_single(key, env, agent, state, T, updates_per_epoch, explore,
                       env_params=env_params)


def reset_fleet_states(keys: jax.Array, env, env_params=None):
    """Stacked per-lane initial EnvStates: vmapped ``env.reset`` over a
    ``[fleet]`` key array, with per-leaf broadcast handling when
    ``env_params`` is a (possibly broadcast-invariant) stacked scenario
    fleet.  Works for ANY functional env (SchedulingEnv's ``reset_fleet``
    adds DSDPS-specific extras like legacy speed_factors on top of this).

    This is also the structure template
    :meth:`repro.checkpoint.fleet.FleetCheckpoint.restore` needs for the
    ``env_states`` tree when resuming a run (values are ignored — only
    shapes/dtypes/structure matter)."""
    if env_params is None:
        env_params = env.default_params()
        params_axes = None
    else:
        from repro.dsdps.simulator import params_in_axes
        params_axes = params_in_axes(env_params, env.default_params())
    if params_axes is not None:
        return jax.vmap(env.reset, in_axes=(0, params_axes))(keys, env_params)
    return jax.vmap(lambda k: env.reset(k, env_params))(keys)


def run_online_fleet(
    keys: jax.Array,
    env,
    agent: Agent,
    states,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
    env_states=None,
    env_params=None,
    mesh=None,
    checkpoint=None,
    start_epoch: int = 0,
    lifecycle=None,
):
    """Fleet-batched online learning: one XLA program for [fleet] runs.

    ``keys``   — stacked per-lane PRNG keys ([fleet] key array);
    ``agent``  — an api.Agent (make_agent(...));
    ``states`` — per-lane agent states stacked on a leading [fleet] axis
                 (agent.init_fleet / ddpg.init_fleet / dqn.init_fleet,
                 optionally pretrained with ddpg.offline_pretrain_fleet);
    ``env_params`` — a single EnvParams (shared by every lane) or a STACKED
                 EnvParams scenario fleet ([F] leading axis, e.g. from
                 repro.dsdps.scenarios): heterogeneous workload rates,
                 service-time jitter, noise levels, and stragglers then run
                 as one vmapped program.  Defaults to env.default_params().
                 Stacks built with ``stack_env_params(...,
                 broadcast_invariant=True)`` keep scenario-invariant leaves
                 (routing / flow_solve / tuple_bytes) as ONE copy; those
                 leaves ride the vmap with per-leaf ``in_axes=None`` —
                 numerically identical to the fully-stacked run, minus the
                 duplicated memory and batched-matmul FLOPs.
    ``env_states`` — optional stacked EnvState (SchedulingEnv.reset_fleet)
                 for heterogeneous *initial state* lanes: per-lane straggler
                 speed factors, initial assignments, warm workload states.
                 When omitted, every lane resets the env exactly as the
                 single-run API does (so fleet lane i bit-matches a
                 run_online_agent call with the same key, initial state,
                 and params lane).
    ``mesh``   — optional ``jax.sharding.Mesh``: the fleet axis of every
                 carry shards over the mesh's data axes (every axis except
                 "model") via shard_map, so the fleet's memory footprint
                 spreads over the whole mesh instead of one device.  The
                 fleet size must be a multiple of the data-axis device
                 count.  On accelerator meshes the carries are DONATED —
                 don't reuse ``states``/``env_states``/``keys`` buffers
                 after the call; on CPU meshes (launch.mesh.make_host_mesh)
                 nothing is donated and lane i stays bit-comparable to the
                 un-sharded vmap run (modulo the documented broadcast-
                 matmul ulp caveat).
    ``checkpoint`` — optional repro.checkpoint.fleet.FleetCheckpoint: the
                 epoch scan is chunked every ``checkpoint.every`` epochs
                 and the full carries (agent states, env states, keys) are
                 snapshotted asynchronously and atomically after each
                 chunk, tagged with the absolute epoch number.  A chunked
                 run threads the scan carry between chunks, so a run
                 restored from epoch k continues bit-identically to an
                 uninterrupted run with the same cadence.
    ``start_epoch`` — absolute epoch this call starts at (resume offset):
                 only affects checkpoint numbering.  ``T`` is always the
                 number of epochs executed BY THIS CALL.
    ``lifecycle`` — optional :class:`repro.fleet.lifecycle.StopRule`: lanes
                 whose smoothed reward plateaus stop early and the fleet is
                 COMPACTED between chunks so finished lanes stop paying
                 compute (docs/elastic_fleets.md).  Stopped lanes' trace
                 tails are padded with their final value; use
                 :func:`repro.fleet.lifecycle.run_online_fleet_elastic`
                 directly for the per-lane stop epochs and the
                 executed-lane-epoch accounting.

    Returns (stacked agent states, History with [fleet, T] traces)."""
    agent = _require_agent(agent)
    T = int(T)
    if T < 1:
        raise ValueError(f"T must be >= 1, got {T}")
    if lifecycle is not None:
        from repro.fleet.lifecycle import run_online_fleet_elastic
        result = run_online_fleet_elastic(
            keys, env, agent, states, T, rule=lifecycle,
            updates_per_epoch=updates_per_epoch, explore=explore,
            env_states=env_states, env_params=env_params, mesh=mesh,
            checkpoint=checkpoint, start_epoch=start_epoch)
        return result.states, result.history
    keys, states, env_states, env_params, _, params_axes, params_specs = \
        prepare_fleet(keys, env, states, env_states, env_params, mesh)

    every = getattr(checkpoint, "every", None) if checkpoint is not None \
        else None
    epoch = int(start_epoch)
    r_parts, l_parts, m_parts = [], [], []
    for n in chunk_schedule(T, every):
        states, env_states, keys, rewards, lats, moved = run_fleet_chunk(
            keys, states, env_states, env_params, env=env, agent=agent,
            T=n, updates_per_epoch=updates_per_epoch, explore=explore,
            params_axes=params_axes, mesh=mesh, params_specs=params_specs)
        # fleet_host == np.asarray off a spanning mesh; on one it
        # allgathers the trace shards so every process sees the full
        # [fleet, T] history (multi-host runs return identical Histories
        # on every process)
        r_parts.append(fleet_host(rewards))
        l_parts.append(fleet_host(lats))
        m_parts.append(fleet_host(moved))
        epoch += n
        maybe_check_finite((states, rewards), f"run_online_fleet epoch {epoch}")
        if checkpoint is not None:
            checkpoint.save(epoch, states, env_states, keys)
    return states, History(rewards=np.concatenate(r_parts, axis=-1),
                           latencies=np.concatenate(l_parts, axis=-1),
                           moved=np.concatenate(m_parts, axis=-1),
                           final_assignment=fleet_host(env_states.X))


# --------------------------------------------------------------------------
# Legacy per-epoch Python loops — the reference semantics.  Kept unchanged
# as (a) the regression oracle for the scan runners and (b) the sequential
# baseline the fleet microbenchmark measures its speedup against.
# --------------------------------------------------------------------------
def run_online_ddpg_python(
    key: jax.Array,
    env,
    cfg: DDPGConfig,
    state: DDPGState,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
) -> tuple[DDPGState, History]:
    k_env, key = jax.random.split(key)
    env_state = env.reset(k_env)
    rewards, lats, moved = [], [], []

    for t in range(T):
        key, k_act, k_step, k_upd = jax.random.split(key, 4)
        s_vec = env.state_vector(env_state)
        action = ddpg.select_action_jit(k_act, state, cfg, s_vec, explore=explore)
        out = env.step(k_step, env_state, action)
        s_next = env.state_vector(out.state)
        state = ddpg.store(state, s_vec, action.reshape(-1), out.reward, s_next,
                           reward_scale=cfg.reward_scale)
        for k in jax.random.split(k_upd, updates_per_epoch):
            state, _ = ddpg.update_step(k, state, cfg)
        state = ddpg.tick(state)
        env_state = out.state
        rewards.append(float(out.reward))
        lats.append(float(out.latency_ms))
        moved.append(int(out.moved))

    return state, History(
        rewards=np.asarray(rewards),
        latencies=np.asarray(lats),
        moved=np.asarray(moved),
        final_assignment=np.asarray(env_state.X),
    )


def run_online_dqn_python(
    key: jax.Array,
    env,
    cfg: DQNConfig,
    state: DQNState,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
) -> tuple[DQNState, History]:
    k_env, key = jax.random.split(key)
    env_state = env.reset(k_env)
    rewards, lats, moved = [], [], []

    for t in range(T):
        key, k_act, k_step, k_upd = jax.random.split(key, 4)
        s_vec = env.state_vector(env_state)
        move = dqn.select_move(k_act, state, cfg, s_vec, explore=explore)
        action = dqn.apply_move(env_state.X, move, cfg.n_machines)
        out = env.step(k_step, env_state, action)
        s_next = env.state_vector(out.state)
        state = dqn.store(state, s_vec, move, out.reward, s_next,
                          reward_scale=cfg.reward_scale)
        for k in jax.random.split(k_upd, updates_per_epoch):
            state, _ = dqn.update_step(k, state, cfg)
        state = dqn.tick(state)
        env_state = out.state
        rewards.append(float(out.reward))
        lats.append(float(out.latency_ms))
        moved.append(int(out.moved))

    return state, History(
        rewards=np.asarray(rewards),
        latencies=np.asarray(lats),
        moved=np.asarray(moved),
        final_assignment=np.asarray(env_state.X),
    )


def greedy_assignment_ddpg(key, env, cfg: DDPGConfig, state: DDPGState,
                           env_state) -> jnp.ndarray:
    """Deploy-time action of a trained agent (no exploration)."""
    s_vec = env.state_vector(env_state)
    return ddpg.select_action(key, state, cfg, s_vec, explore=False,
                              exact_host_knn=True)
