"""Unified online-learning control loops (the paper's decision-epoch loop).

These drive any environment exposing the SchedulingEnv surface
(reset / step / state_vector / random_assignment) — the DSDPS simulator or
the TPU expert-placement environment — with either the actor-critic method
(Algorithm 1) or the DQN baseline, producing the reward traces of
Figs 7/9/11.

Two execution paths:

  * ``run_online_ddpg`` / ``run_online_dqn`` — ONE online run, executed as
    a single jitted ``jax.lax.scan`` over decision epochs (the fused
    epoch body lives in ddpg.make_epoch_step / dqn.make_epoch_step);

  * ``run_online_fleet`` — MANY independent runs (seeds × workload traces
    × straggler scenarios) executed as one XLA program: ``jax.vmap`` over
    a fleet axis of the same scan.  This is what makes seed-swept reward
    curves (mean ± band, Decima-style averaging) affordable: hundreds of
    300-epoch runs amortize compilation and dispatch to a single call.

The legacy per-epoch Python loops are kept as ``run_online_*_python`` —
they are the bit-exactness reference for the scan runners
(tests/test_fleet_runner.py) and the baseline of benchmarks/fleet_bench.py."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddpg, dqn
from repro.core.ddpg import DDPGConfig, DDPGState
from repro.core.dqn import DQNConfig, DQNState


@dataclasses.dataclass
class History:
    """Reward / latency / movement traces of one run ([T]) or of a fleet of
    runs ([fleet, T]); final_assignment is [N, M] or [fleet, N, M]."""

    rewards: np.ndarray
    latencies: np.ndarray
    moved: np.ndarray
    final_assignment: np.ndarray

    @property
    def fleet(self) -> int | None:
        """Fleet size, or None for a single-run history."""
        return self.rewards.shape[0] if self.rewards.ndim == 2 else None

    def lane(self, i: int) -> "History":
        """The i-th run of a fleet history as a single-run History."""
        if self.fleet is None:
            raise ValueError("lane() on a single-run History")
        return History(rewards=self.rewards[i], latencies=self.latencies[i],
                       moved=self.moved[i],
                       final_assignment=self.final_assignment[i])

    def normalized_rewards(self) -> np.ndarray:
        """(r - r_min)/(r_max - r_min), the paper's normalization —
        per-lane (along the epoch axis) for fleet histories."""
        r = self.rewards
        lo = r.min(axis=-1, keepdims=True)
        hi = r.max(axis=-1, keepdims=True)
        return (r - lo) / np.maximum(hi - lo, 1e-12)

    def smoothed_rewards(self, cutoff: float = 0.05) -> np.ndarray:
        """Forward-backward (zero-phase) low-pass filter, as in the paper
        ([20] Gustafsson filtfilt)."""
        from scipy.signal import butter, filtfilt
        b, a = butter(2, cutoff)
        r = self.normalized_rewards()
        if r.shape[-1] < 15:
            return r
        return filtfilt(b, a, r, axis=-1)

    def seed_band(self, cutoff: float = 0.05) -> tuple[np.ndarray, np.ndarray]:
        """(mean, std) across the fleet axis of the smoothed normalized
        reward curves — the seed-averaged curve + variance band plotted by
        the paper_fig benchmarks."""
        r = np.atleast_2d(self.smoothed_rewards(cutoff))
        return r.mean(axis=0), r.std(axis=0)


# --------------------------------------------------------------------------
# Compiled-runner cache.  SchedulingEnv is an unhashable dataclass (its
# SimParams hold numpy arrays), so it can't be a jit static argument; each
# runner closes over the env instead and is cached by identity.  A live
# entry holds a strong reference to its env, so an id() can only be
# recycled after the entry is evicted — and eviction removes the key, so a
# recycled id can never produce a stale hit.  Bounded FIFO keeps long
# multi-app sweeps from pinning every retired XLA executable forever.
# --------------------------------------------------------------------------
_RUNNER_CACHE: dict[tuple, tuple] = {}
_RUNNER_CACHE_MAX = 16


def _lane_fn(env, cfg, T: int, updates_per_epoch: int, explore: bool):
    """One online run as a pure function (key, agent_state, env_state) ->
    (agent_state, rewards[T], latencies[T], moved[T], final_X)."""
    if isinstance(cfg, DDPGConfig):
        epoch = ddpg.make_epoch_step(env, cfg, updates_per_epoch, explore)
    elif isinstance(cfg, DQNConfig):
        epoch = dqn.make_epoch_step(env, cfg, updates_per_epoch, explore)
    else:
        raise TypeError(f"unknown agent config {type(cfg).__name__}")

    def lane(key, state, env_state):
        (state, env_state, _), (rewards, lats, moved) = jax.lax.scan(
            epoch, (state, env_state, key), None, length=T)
        return state, rewards, lats, moved, env_state.X

    return lane


def _compiled_runner(env, cfg, T: int, updates_per_epoch: int, explore: bool,
                     batched: bool):
    cache_key = (id(env), cfg, int(T), int(updates_per_epoch), bool(explore),
                 bool(batched))
    hit = _RUNNER_CACHE.get(cache_key)
    if hit is not None:
        return hit[1]
    lane = _lane_fn(env, cfg, T, updates_per_epoch, explore)
    fn = jax.jit(jax.vmap(lane) if batched else lane)
    while len(_RUNNER_CACHE) >= _RUNNER_CACHE_MAX:
        _RUNNER_CACHE.pop(next(iter(_RUNNER_CACHE)))
    _RUNNER_CACHE[cache_key] = (env, fn)
    return fn


def _run_single(key, env, cfg, state, T, updates_per_epoch, explore):
    k_env, key = jax.random.split(key)
    env_state = env.reset(k_env)
    run = _compiled_runner(env, cfg, T, updates_per_epoch, explore,
                           batched=False)
    state, rewards, lats, moved, X = run(key, state, env_state)
    return state, History(rewards=np.asarray(rewards),
                          latencies=np.asarray(lats),
                          moved=np.asarray(moved),
                          final_assignment=np.asarray(X))


def run_online_ddpg(
    key: jax.Array,
    env,
    cfg: DDPGConfig,
    state: DDPGState,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
) -> tuple[DDPGState, History]:
    """One online actor-critic run as a single jitted scan over epochs."""
    return _run_single(key, env, cfg, state, T, updates_per_epoch, explore)


def run_online_dqn(
    key: jax.Array,
    env,
    cfg: DQNConfig,
    state: DQNState,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
) -> tuple[DQNState, History]:
    """One online DQN run as a single jitted scan over epochs."""
    return _run_single(key, env, cfg, state, T, updates_per_epoch, explore)


def run_online_fleet(
    keys: jax.Array,
    env,
    cfg,
    states,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
    env_states=None,
):
    """Fleet-batched online learning: one XLA program for [fleet] runs.

    ``keys``   — stacked per-lane PRNG keys ([fleet] key array);
    ``states`` — per-lane agent states stacked on a leading [fleet] axis
                 (ddpg.init_fleet / dqn.init_fleet, optionally pretrained
                 with ddpg.offline_pretrain_fleet);
    ``env_states`` — optional stacked EnvState (SchedulingEnv.reset_fleet)
                 for heterogeneous lanes: per-lane straggler speed factors,
                 initial assignments, warm workload states.  When omitted,
                 every lane resets the env exactly as the single-run API
                 does (so fleet lane i bit-matches a run_online_* call with
                 the same key and initial state).

    Returns (stacked agent states, History with [fleet, T] traces)."""
    keys = jnp.asarray(keys)
    if env_states is None:
        pairs = jax.vmap(jax.random.split)(keys)          # [F, 2] keys
        k_env, keys = pairs[:, 0], pairs[:, 1]
        env_states = jax.vmap(env.reset)(k_env)
    run = _compiled_runner(env, cfg, T, updates_per_epoch, explore,
                           batched=True)
    states, rewards, lats, moved, X = run(keys, states, env_states)
    return states, History(rewards=np.asarray(rewards),
                           latencies=np.asarray(lats),
                           moved=np.asarray(moved),
                           final_assignment=np.asarray(X))


# --------------------------------------------------------------------------
# Legacy per-epoch Python loops — the reference semantics.  Kept unchanged
# as (a) the regression oracle for the scan runners and (b) the sequential
# baseline the fleet microbenchmark measures its speedup against.
# --------------------------------------------------------------------------
def run_online_ddpg_python(
    key: jax.Array,
    env,
    cfg: DDPGConfig,
    state: DDPGState,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
) -> tuple[DDPGState, History]:
    k_env, key = jax.random.split(key)
    env_state = env.reset(k_env)
    rewards, lats, moved = [], [], []

    for t in range(T):
        key, k_act, k_step, k_upd = jax.random.split(key, 4)
        s_vec = env.state_vector(env_state)
        action = ddpg.select_action_jit(k_act, state, cfg, s_vec, explore=explore)
        out = env.step(k_step, env_state, action)
        s_next = env.state_vector(out.state)
        state = ddpg.store(state, s_vec, action.reshape(-1), out.reward, s_next,
                           reward_scale=cfg.reward_scale)
        for k in jax.random.split(k_upd, updates_per_epoch):
            state, _ = ddpg.update_step(k, state, cfg)
        state = ddpg.tick(state)
        env_state = out.state
        rewards.append(float(out.reward))
        lats.append(float(out.latency_ms))
        moved.append(int(out.moved))

    return state, History(
        rewards=np.asarray(rewards),
        latencies=np.asarray(lats),
        moved=np.asarray(moved),
        final_assignment=np.asarray(env_state.X),
    )


def run_online_dqn_python(
    key: jax.Array,
    env,
    cfg: DQNConfig,
    state: DQNState,
    T: int,
    updates_per_epoch: int = 1,
    explore: bool = True,
) -> tuple[DQNState, History]:
    k_env, key = jax.random.split(key)
    env_state = env.reset(k_env)
    rewards, lats, moved = [], [], []

    for t in range(T):
        key, k_act, k_step, k_upd = jax.random.split(key, 4)
        s_vec = env.state_vector(env_state)
        move = dqn.select_move(k_act, state, cfg, s_vec, explore=explore)
        action = dqn.apply_move(env_state.X, move, cfg.n_machines)
        out = env.step(k_step, env_state, action)
        s_next = env.state_vector(out.state)
        state = dqn.store(state, s_vec, move, out.reward, s_next,
                          reward_scale=cfg.reward_scale)
        for k in jax.random.split(k_upd, updates_per_epoch):
            state, _ = dqn.update_step(k, state, cfg)
        state = dqn.tick(state)
        env_state = out.state
        rewards.append(float(out.reward))
        lats.append(float(out.latency_ms))
        moved.append(int(out.moved))

    return state, History(
        rewards=np.asarray(rewards),
        latencies=np.asarray(lats),
        moved=np.asarray(moved),
        final_assignment=np.asarray(env_state.X),
    )


def greedy_assignment_ddpg(key, env, cfg: DDPGConfig, state: DDPGState,
                           env_state) -> jnp.ndarray:
    """Deploy-time action of a trained agent (no exploration)."""
    s_vec = env.state_vector(env_state)
    return ddpg.select_action(key, state, cfg, s_vec, explore=False,
                              exact_host_knn=True)
