"""Decima-style graph policy: message passing over the topology DAG.

The replay agents flatten (X, w) into a fixed-width vector, so one
trained policy is welded to one topology shape.  ``graph_policy``
instead reads the *executor graph* — per-node features plus the edge
index/weight arrays of the routing matrix R (Decima, "Learning
Scheduling Algorithms for Data Processing Clusters") — through a small
segment-sum message-passing network in ``models/nn.py`` param dicts,
with a per-executor placement head: ``q[i, j]`` scores moving executor
``i`` to machine ``j``, the same restricted move space as DQN/Stream Q.

Mask discipline (what makes padding exact, not approximate):

  * node embeddings are multiplied by ``node_mask`` after every layer,
    so padded nodes carry exact zeros;
  * padded edges target the sacrificial segment ``N`` (one past the last
    slot) with zero weight — the segment-sum runs over ``N + 1`` segments
    and the extra one is sliced away, so real-node aggregates are
    bit-identical across padding envelopes;
  * Q rows of padded nodes are ``-inf`` and the ε-greedy draw is a
    categorical over *valid* moves only, so padded executors are never
    acted on.

Graphs arrive from either side of one code path: on a plain
``SchedulingEnv`` the (single) graph is frozen into the hashable config
as tuples (jit constants); on a ``StructuralSchedulingEnv`` fleet the
graph rides the traced :class:`GraphEnvParams` leaves, so every lane may
carry a *different* DAG through one compiled program.  Training is the
replay-free Stream Q(λ) recipe (eligibility traces + ObGD + running
reward normalization) — the carry is a plain param-dict pytree, so
fleets, sharding, checkpointing, and compaction apply unchanged.

``env_params`` is threaded to ``observe`` (which needs the graph for
Q(s')) through ``aux`` — the Agent contract's observe hook does not
receive params directly.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core.dqn import apply_move
from repro.core.exploration import EpsilonSchedule
from repro.core.streaming import (obgd_step, reward_norm_update,
                                  trace_decay_add, trace_zeros_like)
from repro.dsdps.structural import GraphEnvParams
from repro.models import nn


@dataclasses.dataclass(frozen=True)
class GraphPolicyConfig:
    """Hashable spec: sizes + (for plain envs) the static graph as tuples.

    ``static_*`` fields are None on structural envs, where each lane's
    graph arrives as traced GraphEnvParams leaves instead."""

    n_executors: int             # padded envelope size N
    n_machines: int
    n_spouts: int                # padded spout count S
    gamma: float = 0.99
    lam: float = 0.9             # eligibility-trace decay λ
    lr: float = 1.0              # ObGD base stepsize
    kappa: float = 3.0           # ObGD overshoot margin
    hidden: int = 16             # node embedding width
    msg_steps: int = 2           # message-passing rounds
    reward_scale: float = 0.25
    eps: EpsilonSchedule = EpsilonSchedule(decay_epochs=300)
    static_spouts: tuple | None = None       # spout executor ids
    static_edge_src: tuple | None = None     # R edge endpoints ...
    static_edge_dst: tuple | None = None
    static_edge_w: tuple | None = None       # ... and weights R[src, dst]

    @property
    def num_actions(self) -> int:
        return self.n_executors * self.n_machines

    @property
    def n_features(self) -> int:
        # X row + [service, bytes, out_mass, in_mass, spout_rate, is_spout,
        # mask] — per-node widths only, so parameter shapes (and therefore
        # init draws) are identical at every padding envelope.
        return self.n_machines + 7


class GraphPolicyState(NamedTuple):
    qnet: dict                   # {"gnn": {enc, mp0.., head}} param dicts
    z: dict                      # eligibility traces, same pytree
    delta: jnp.ndarray           # pending TD error
    epoch: jnp.ndarray
    r_mean: jnp.ndarray = jnp.zeros(())
    r_var: jnp.ndarray = jnp.ones(())
    r_count: jnp.ndarray = jnp.zeros((), jnp.int32)


# --------------------------------------------------------------------------
# Graph plumbing: one (mask, spouts, edges) view over both param flavors.
# --------------------------------------------------------------------------
class _Graph(NamedTuple):
    node_mask: jnp.ndarray       # [N]
    spout_onehot: jnp.ndarray    # [S, N]
    edge_src: jnp.ndarray        # [E] int32
    edge_dst: jnp.ndarray        # [E] int32
    edge_w: jnp.ndarray          # [E]


def _graph_arrays(cfg: GraphPolicyConfig, env_params) -> _Graph:
    """The graph the policy runs on: traced per-lane leaves on a
    structural fleet, jit constants from the config on a plain env."""
    if isinstance(env_params, GraphEnvParams):
        return _Graph(env_params.node_mask, env_params.spout_onehot,
                      env_params.edge_src, env_params.edge_dst,
                      env_params.edge_w)
    if cfg.static_edge_src is None:
        raise ValueError(
            "graph_policy built without a static graph needs GraphEnvParams "
            "(StructuralSchedulingEnv) at select/observe time")
    n = cfg.n_executors
    sp = np.zeros((cfg.n_spouts, n), np.float32)
    sp[np.arange(len(cfg.static_spouts)), list(cfg.static_spouts)] = 1.0
    return _Graph(
        node_mask=jnp.ones((n,), jnp.float32),
        spout_onehot=jnp.asarray(sp),
        edge_src=jnp.asarray(cfg.static_edge_src, jnp.int32),
        edge_dst=jnp.asarray(cfg.static_edge_dst, jnp.int32),
        edge_w=jnp.asarray(cfg.static_edge_w, jnp.float32),
    )


def _features(cfg: GraphPolicyConfig, s_vec, env_params,
              graph: _Graph) -> jnp.ndarray:
    """Per-node features [N, n_features] from the flat state vector (both
    env families emit concat([X.reshape(-1), w_norm])) + params arrays."""
    n, m = cfg.n_executors, cfg.n_machines
    X = s_vec[: n * m].reshape(n, m)
    w_norm = s_vec[n * m:]                          # [S], 0 on padded spouts
    node_w = graph.spout_onehot.T @ w_norm          # [N]
    is_spout = graph.spout_onehot.sum(0)
    cols = [
        X,
        env_params.service_ms[:, None],
        env_params.tuple_bytes[:, None] / 1024.0,
        env_params.routing.sum(1)[:, None],         # selectivity × fan-out
        env_params.routing.sum(0)[:, None],         # upstream mass
        node_w[:, None],
        is_spout[:, None],
        graph.node_mask[:, None],
    ]
    return jnp.concatenate(cols, axis=1) * graph.node_mask[:, None]


# --------------------------------------------------------------------------
# The Q network: segment-sum message passing + per-executor placement head.
# --------------------------------------------------------------------------
def init_qnet(key: jax.Array, cfg: GraphPolicyConfig) -> dict:
    h = cfg.hidden
    keys = jax.random.split(key, 2 + 3 * cfg.msg_steps)
    gnn = {"enc": nn.linear_init(keys[0], cfg.n_features, h,
                                 dtype=jnp.float32)}
    for t in range(cfg.msg_steps):
        k_s, k_f, k_b = jax.random.split(keys[1 + t], 3)
        gnn[f"mp{t}"] = {
            "self": nn.linear_init(k_s, h, h, dtype=jnp.float32),
            "fwd": nn.linear_init(k_f, h, h, dtype=jnp.float32),
            "bwd": nn.linear_init(k_b, h, h, dtype=jnp.float32),
        }
    gnn["head"] = nn.linear_init(keys[-1], 2 * h + cfg.n_machines,
                                 cfg.n_machines, bias=True, dtype=jnp.float32)
    return {"gnn": gnn}


def apply_qnet(params: dict, feat: jnp.ndarray, graph: _Graph,
               cfg: GraphPolicyConfig) -> jnp.ndarray:
    """Raw per-move scores q[i, j] (unmasked).  Padded nodes stay exact
    zeros through every layer; padded edges deposit into the sacrificial
    segment ``n`` which the ``[:n]`` slice discards."""
    g = params["gnn"]
    mask = graph.node_mask[:, None]
    n = feat.shape[0]
    h = jax.nn.relu(nn.linear(g["enc"], feat)) * mask
    for t in range(cfg.msg_steps):
        mp = g[f"mp{t}"]
        # out-of-range (sacrificial) gather indices clamp; their messages
        # carry zero edge weight and only ever land in the dropped segment
        fwd = jax.ops.segment_sum(graph.edge_w[:, None] * h[graph.edge_src],
                                  graph.edge_dst, num_segments=n + 1)[:n]
        bwd = jax.ops.segment_sum(graph.edge_w[:, None] * h[graph.edge_dst],
                                  graph.edge_src, num_segments=n + 1)[:n]
        h = jax.nn.relu(nn.linear(mp["self"], h) + nn.linear(mp["fwd"], fwd)
                        + nn.linear(mp["bwd"], bwd)) * mask
    n_real = jnp.maximum(graph.node_mask.sum(), 1.0)
    pooled = h.sum(0) / n_real                                    # [H]
    # machine occupancy straight off the assignment columns of the (already
    # masked) features: the placement head sees which machines are loaded
    # without waiting on message passing to carry it around the graph
    occ = feat[:, : cfg.n_machines].sum(0) / n_real               # [M]
    ctx = jnp.concatenate([pooled, occ])
    hg = jnp.concatenate(
        [h, jnp.broadcast_to(ctx[None, :], (h.shape[0], ctx.shape[0]))],
        axis=-1)
    return nn.linear(g["head"], hg)                               # [N, M]


def _masked(q: jnp.ndarray, graph: _Graph) -> jnp.ndarray:
    return jnp.where(graph.node_mask[:, None] > 0.5, q, -jnp.inf)


# --------------------------------------------------------------------------
# Agent-interface adapter (Stream Q(λ) training recipe).
# --------------------------------------------------------------------------
def init_state(key: jax.Array, cfg: GraphPolicyConfig) -> GraphPolicyState:
    q = init_qnet(key, cfg)
    return GraphPolicyState(
        qnet=q,
        z=trace_zeros_like(q),
        delta=jnp.zeros(()),
        epoch=jnp.zeros((), jnp.int32),
    )


def _agent_init(key, cfg: GraphPolicyConfig, env_params=None):
    return init_state(key, cfg)


def _agent_select(key, cfg: GraphPolicyConfig, state, s_vec, env_state,
                  env_params, explore):
    graph = _graph_arrays(cfg, env_params)
    feat = _features(cfg, s_vec, env_params, graph)
    flat = _masked(apply_qnet(state.qnet, feat, graph, cfg), graph).reshape(-1)
    greedy_move = jnp.argmax(flat)
    if explore:
        k_bern, k_rand = jax.random.split(key)
        eps = cfg.eps(state.epoch)
        # masked ε-greedy: uniform over VALID moves only — the stock
        # epsilon_greedy samples the full padded grid
        rand_move = jax.random.categorical(
            k_rand, jnp.where(jnp.isfinite(flat), 0.0, -jnp.inf))
        move = jnp.where(jax.random.bernoulli(k_bern, eps), rand_move,
                         greedy_move)
    else:
        move = greedy_move
    greedy = (move == greedy_move).astype(jnp.float32)
    n, m = cfg.n_executors, cfg.n_machines
    X = s_vec[: n * m].reshape(n, m)
    action = apply_move(X, move, m)
    # aux smuggles env_params to observe (the contract's observe hook is
    # params-free); it lives only within the epoch body, not the carry
    return action, (move, greedy, env_params)


def _agent_observe(cfg: GraphPolicyConfig, state, s_vec, aux, reward, s_next):
    move, greedy, env_params = aux
    graph = _graph_arrays(cfg, env_params)
    r_std, r_mean, r_var, r_count = reward_norm_update(
        reward, state.r_mean, state.r_var, state.r_count,
        scale=cfg.reward_scale)
    feat = _features(cfg, s_vec, env_params, graph)
    feat_next = _features(cfg, s_next, env_params, graph)
    q_next = _masked(apply_qnet(state.qnet, feat_next, graph, cfg),
                     graph).max()
    q_sa, grad = jax.value_and_grad(
        lambda p: apply_qnet(p, feat, graph, cfg).reshape(-1)[move])(
            state.qnet)
    delta = r_std + cfg.gamma * q_next - q_sa
    # Watkins Q(λ): non-greedy moves cut the trace before accumulation
    z = trace_decay_add(state.z, grad, cfg.gamma * cfg.lam * greedy)
    return state._replace(z=z, delta=delta, r_mean=r_mean, r_var=r_var,
                          r_count=r_count)


def _agent_update(key, cfg: GraphPolicyConfig, state):
    qnet = obgd_step(state.qnet, state.z, state.delta, cfg.lr, cfg.kappa)
    return state._replace(qnet=qnet, delta=jnp.zeros(()))


def _agent_tick(cfg: GraphPolicyConfig, state):
    return state._replace(epoch=state.epoch + 1)


def as_agent(cfg: GraphPolicyConfig) -> api.Agent:
    """The graph policy as a pluggable Agent bundle."""
    return api.Agent(name="graph_policy", cfg=cfg, init_fn=_agent_init,
                     select_fn=_agent_select, observe_fn=_agent_observe,
                     update_fn=_agent_update, tick_fn=_agent_tick)


def agent_factory(env, **overrides) -> api.Agent:
    """Registry hook: a structural env contributes its padding envelope;
    a plain SchedulingEnv freezes its (single) graph into the config."""
    cfg = overrides.pop("cfg", None)
    if cfg is None:
        if hasattr(env, "envelope"):           # StructuralSchedulingEnv
            cfg = GraphPolicyConfig(
                n_executors=env.N, n_machines=env.M,
                n_spouts=env.envelope.max_spouts, **overrides)
        elif hasattr(env, "topo"):             # plain SchedulingEnv
            topo = env.topo
            n_edges = int(np.count_nonzero(topo.routing_matrix(env.seed)))
            gobs = topo.to_graph_obs(topo.num_executors, n_edges,
                                     seed=env.seed)
            cfg = GraphPolicyConfig(
                n_executors=env.N, n_machines=env.M,
                n_spouts=env.workload.num_spouts,
                static_spouts=tuple(int(i) for i in topo.spout_executors),
                static_edge_src=tuple(int(i) for i in gobs.edge_src),
                static_edge_dst=tuple(int(i) for i in gobs.edge_dst),
                static_edge_w=tuple(float(x) for x in gobs.edge_w),
                **overrides)
        else:
            raise TypeError(
                "graph_policy needs a topology-bearing env (SchedulingEnv "
                "or StructuralSchedulingEnv); got "
                f"{type(env).__name__}")
    return as_agent(cfg)


api.register_agent("graph_policy", agent_factory, families=("scheduling",))


def init_fleet(key: jax.Array, cfg: GraphPolicyConfig,
               fleet: int) -> GraphPolicyState:
    """Independently-initialized per-lane states stacked on [fleet]."""
    return jax.vmap(lambda k: init_state(k, cfg))(jax.random.split(key, fleet))


def graph_param_specs(params, mesh):
    """PartitionSpecs for a graph-policy param pytree under the repo's
    name-rule sharding policy — GNN layer matrices land on the mesh's
    "model" axis (``fsdp=False``: the data axes carry fleet lanes, not
    parameter shards).  See sharding/policy.py's ``gnn/`` rule."""
    from repro.sharding.policy import ShardingPolicy
    return ShardingPolicy(mesh, None, fsdp=False).params_tree(params)
