"""TPU instantiation of the paper's technique: DRL expert→device placement.

The paper's scheduling problem — assign N threads to M machines to minimize
end-to-end latency — is isomorphic to placing N MoE experts onto M devices
of a TPU slice to minimize per-step time under skewed routing and
stragglers (DESIGN.md §3/§6).  The environment below exposes the exact
functional surface the agent runners (`run_online_agent` /
`run_online_fleet`) expect, with:

  state   (X, w):  expert→device assignment + per-expert token load
  action  one-hot [N_experts, M_devices]
  reward  −(estimated step time) from a roofline-style cost model:
          max-device compute time (load imbalance) + all-to-all time over
          the ICI torus with per-link contention.

The cost model constants match the roofline hardware constants used in
benchmarks/roofline.py (197 TFLOP/s bf16, 50 GB/s/link ICI)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dsdps.simulator import stack_env_params

PEAK_FLOPS = 197e12          # bf16 / chip
ICI_BW = 50e9                # bytes/s per link


class PlacementState(NamedTuple):
    X: jnp.ndarray          # [E, D] expert -> device
    w: jnp.ndarray          # [E] tokens routed to each expert this interval
    epoch: jnp.ndarray
    speed: jnp.ndarray      # [D] device speed factors (straggler model)


class PlacementStep(NamedTuple):
    state: PlacementState
    reward: jnp.ndarray
    latency_ms: jnp.ndarray   # estimated step time (ms) — keeps History API
    moved: jnp.ndarray


class PlacementParams(NamedTuple):
    """Vmappable scenario parameters of the placement env (mirrors
    dsdps.simulator.EnvParams for the TPU instantiation)."""

    base_load: jnp.ndarray    # [E] mean tokens routed to each expert
    speed: jnp.ndarray        # [D] device speed factors
    noise_sigma: jnp.ndarray  # scalar measurement noise
    load_jitter: jnp.ndarray  # scalar per-epoch routing-drift sigma


@dataclasses.dataclass(eq=False)
class ExpertPlacementEnv:
    """MoE expert placement on a (ring) ICI topology.

    ``eq=False`` keeps identity hash/eq so the env is a jit static spec;
    scenario numerics travel in PlacementParams."""

    num_experts: int
    num_devices: int
    flops_per_token: float            # 2 * d_model * d_ff * 3 (gated FFN)
    bytes_per_token: int              # activation bytes moved per routed token
    tokens_per_step: int              # total routed tokens per step
    skew: float = 1.0                 # Zipf exponent of expert popularity
    jitter: float = 0.10              # per-epoch load jitter
    seed: int = 0
    noise_sigma: float = 0.01

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        pop = np.arange(1, self.num_experts + 1, dtype=np.float64) ** (-self.skew)
        self._base_load = jnp.asarray(
            rng.permutation(pop / pop.sum()) * self.tokens_per_step)
        self.N, self.M = self.num_experts, self.num_devices
        self._default_params: PlacementParams | None = None

    # --- SchedulingEnv surface --------------------------------------------
    def default_params(self) -> PlacementParams:
        if self._default_params is None:
            self._default_params = PlacementParams(
                base_load=self._base_load,
                speed=jnp.ones(self.M),
                noise_sigma=jnp.asarray(self.noise_sigma, jnp.float32),
                load_jitter=jnp.asarray(self.jitter, jnp.float32),
            )
        return self._default_params

    @property
    def state_dim(self) -> int:
        return self.N * self.M + self.N

    @property
    def action_dim(self) -> int:
        return self.N * self.M

    def round_robin_assignment(self) -> jnp.ndarray:
        idx = np.arange(self.N) % self.M
        return jnp.asarray(np.eye(self.M)[idx], dtype=jnp.float32)

    def random_assignment(self, key: jax.Array) -> jnp.ndarray:
        idx = jax.random.randint(key, (self.N,), 0, self.M)
        return jax.nn.one_hot(idx, self.M, dtype=jnp.float32)

    def state_vector(self, s: PlacementState,
                     params: PlacementParams | None = None) -> jnp.ndarray:
        p = self.default_params() if params is None else params
        w_norm = s.w / (p.base_load + 1e-9)
        return jnp.concatenate([s.X.reshape(-1), w_norm])

    def reset(self, key: jax.Array, params: PlacementParams | None = None,
              X0: jnp.ndarray | None = None) -> PlacementState:
        p = self.default_params() if params is None else params
        X = self.round_robin_assignment() if X0 is None else X0
        return PlacementState(
            X=X, w=p.base_load,
            epoch=jnp.zeros((), jnp.int32),
            speed=p.speed,
        )

    # --- cost model ----------------------------------------------------------
    def step_time_ms(self, X: jnp.ndarray, w: jnp.ndarray,
                     speed: jnp.ndarray | None = None) -> jnp.ndarray:
        speed = jnp.ones(self.M) if speed is None else speed
        # compute: bottleneck device (experts execute serially per device)
        dev_tokens = (X * w[:, None]).sum(0)                       # [D]
        t_comp = dev_tokens * self.flops_per_token / (PEAK_FLOPS * speed)
        # comm: tokens enter and leave each expert's device uniformly from
        # all devices; ring ICI -> per-link bytes with average hop distance
        cross = (w[:, None] * X * (1.0 - 1.0 / self.M)).sum(0)     # [D] tokens
        bytes_dev = 2.0 * cross * self.bytes_per_token             # in + out
        avg_hops = self.M / 4.0                                    # ring average
        t_comm = bytes_dev * avg_hops / (ICI_BW * 2.0)             # 2 links/dir
        return 1e3 * (jnp.maximum(t_comp, t_comm) + 0.25 * jnp.minimum(t_comp, t_comm)).max()

    def evaluate(self, X: jnp.ndarray, w: jnp.ndarray,
                 speed: jnp.ndarray | None = None,
                 params: "PlacementParams | None" = None) -> jnp.ndarray:
        if speed is None and params is not None:
            speed = params.speed
        return self.step_time_ms(X, w, speed)

    def step(self, key: jax.Array, s: PlacementState, action: jnp.ndarray,
             params: PlacementParams | None = None) -> PlacementStep:
        p = self.default_params() if params is None else params
        k_noise, k_w = jax.random.split(key)
        moved = (jnp.abs(action - s.X).sum(-1) > 0).sum()
        t = self.step_time_ms(action, s.w, s.speed)
        t = t * jnp.exp(jax.random.normal(k_noise, ()) * p.noise_sigma)
        # expert popularity drifts (routing distribution shifts during training)
        z = jax.random.normal(k_w, s.w.shape) * p.load_jitter
        w_next = s.w + 0.3 * (p.base_load * jnp.exp(z) - s.w)
        nxt = PlacementState(X=action, w=w_next, epoch=s.epoch + 1, speed=s.speed)
        return PlacementStep(state=nxt, reward=-t, latency_ms=t, moved=moved)

    def with_straggler(self, s: PlacementState, device: int, factor: float) -> PlacementState:
        return s._replace(speed=s.speed.at[device].set(factor))


# --------------------------------------------------------------------------
# PlacementParams scenario helpers + named fleets (mirrors
# dsdps.scenarios for the TPU instantiation).  Builders return per-lane
# params lists; `build_scenario` stacks them — optionally with
# broadcast-invariant leaves kept single-copy — so the placement env joins
# the heterogeneous-fleet story through the same runner.
# --------------------------------------------------------------------------
def with_device_straggler(params: PlacementParams, device: int,
                          factor) -> PlacementParams:
    """Slow device ``device`` to ``factor`` of nominal speed."""
    return params._replace(speed=params.speed.at[device].set(factor))


def scale_load(params: PlacementParams, factor) -> PlacementParams:
    """Scale every expert's mean routed-token load (traffic surge)."""
    return params._replace(base_load=params.base_load * factor)


def with_placement_noise(params: PlacementParams, sigma) -> PlacementParams:
    """Replace the step-time measurement-noise level."""
    return params._replace(noise_sigma=jnp.asarray(sigma, jnp.float32))


def perturb_skew(params: PlacementParams, key: jax.Array,
                 sigma: float = 0.3) -> PlacementParams:
    """Lognormal (mean-1 corrected) jitter on per-expert popularity —
    samples routing-distribution shifts between training phases."""
    z = jax.random.normal(key, params.base_load.shape)
    mult = jnp.exp(z * sigma - 0.5 * sigma ** 2)
    return params._replace(base_load=params.base_load * mult)


def _pl_uniform(env, fleet: int) -> list:
    return [env.default_params()] * fleet


def _pl_one_slow_device(env, fleet: int, factor: float = 0.5) -> list:
    p = env.default_params()
    return [with_device_straggler(p, i % env.M, factor) for i in range(fleet)]


def _pl_skewed_routing(env, fleet: int, sigma: float = 0.3,
                       seed: int = 0) -> list:
    p = env.default_params()
    key = jax.random.PRNGKey(seed)
    return [perturb_skew(p, jax.random.fold_in(key, i), sigma)
            for i in range(fleet)]


def _pl_traffic_surge(env, fleet: int, amplitude: float = 0.5) -> list:
    p = env.default_params()
    return [scale_load(p, 1.0 + amplitude * i / max(fleet - 1, 1))
            for i in range(fleet)]


def _pl_mixed(env, fleet: int, seed: int = 0) -> list:
    p = env.default_params()
    key = jax.random.PRNGKey(seed)
    lanes = []
    for i in range(fleet):
        lane = perturb_skew(p, jax.random.fold_in(key, i), 0.2)
        kind = i % 3
        if kind == 1:
            lane = with_device_straggler(lane, i % env.M, 0.5)
        elif kind == 2:
            lane = with_placement_noise(scale_load(lane, 1.3), 0.05)
        lanes.append(lane)
    return lanes


PLACEMENT_SCENARIOS = {
    "uniform": _pl_uniform,
    "one_slow_device": _pl_one_slow_device,
    "skewed_routing": _pl_skewed_routing,
    "traffic_surge": _pl_traffic_surge,
    "mixed": _pl_mixed,
}


def build_scenario(name: str, env: ExpertPlacementEnv, fleet: int,
                   broadcast_invariant: bool = False,
                   **kwargs) -> PlacementParams:
    """Stacked PlacementParams for a named placement scenario fleet."""
    try:
        builder = PLACEMENT_SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown placement scenario {name!r}; "
                       f"known: {sorted(PLACEMENT_SCENARIOS)}") from None
    return stack_env_params(builder(env, fleet, **kwargs),
                            broadcast_invariant=broadcast_invariant)


def jamba_placement_env(num_devices: int = 16) -> ExpertPlacementEnv:
    """Jamba-1.5-large's 16 experts on the 16-way model axis (DESIGN.md §6)."""
    d_model, d_ff = 8192, 24576
    return ExpertPlacementEnv(
        num_experts=16,
        num_devices=num_devices,
        flops_per_token=2.0 * 3 * d_model * d_ff,
        bytes_per_token=2 * d_model,         # bf16 activations in+out handled in model
        tokens_per_step=4096 * 8 * 2,        # per-pod microbatch tokens × top-2
        skew=0.9,
    )
