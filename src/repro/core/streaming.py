"""Shared machinery for the replay-free streaming agents (arXiv 2410.14606).

Stream Q(λ) / Stream AC(λ) replace the replay buffer + target network +
Adam state of the DQN/DDPG lanes with three small pieces, all of which
live in the scan carry and are implemented here:

  * :class:`ObsNorm` — a running Welford mean/variance observation
    normalizer, updated once per transition *inside* the fused epoch body
    (no host round-trips, no warm-up pass);
  * eligibility traces — a pytree shaped like the network parameters,
    decayed by γλ and accumulated with the current transition's gradient
    (:func:`trace_decay_add`), which is what makes one-transition TD(λ)
    updates carry multi-step credit without storing transitions;
  * ObGD (:func:`obgd_step`) — overshoot-bounded gradient descent, the
    stepsize rule that keeps single-sample updates stable without Adam:
    the effective stepsize is throttled so one update cannot overshoot
    the TD target, which also keeps every carry leaf finite for the
    chunk-boundary ``maybe_check_finite`` sweeps.

Reward standardization (:func:`reward_norm_update`) mirrors the running
r_mean/r_var scheme the replay agents keep in DDPGState/DQNState — it is
already a streaming statistic, so the streaming lanes reuse it verbatim.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ObsNorm(NamedTuple):
    """Welford running mean/variance over observation vectors."""

    mean: jnp.ndarray    # [dim]
    m2: jnp.ndarray      # [dim] sum of squared deviations
    count: jnp.ndarray   # scalar float32


def norm_init(dim: int) -> ObsNorm:
    return ObsNorm(mean=jnp.zeros((dim,), jnp.float32),
                   m2=jnp.zeros((dim,), jnp.float32),
                   count=jnp.zeros((), jnp.float32))


def norm_update(norm: ObsNorm, x: jnp.ndarray) -> ObsNorm:
    """Fold one observation into the running statistics (Welford)."""
    count = norm.count + 1.0
    delta = x - norm.mean
    mean = norm.mean + delta / count
    m2 = norm.m2 + delta * (x - mean)
    return ObsNorm(mean=mean, m2=m2, count=count)


def norm_apply(norm: ObsNorm, x: jnp.ndarray) -> jnp.ndarray:
    """Standardize ``x`` under the current statistics (clipped ±10).

    Until two observations have been folded in the variance estimate is
    degenerate; fall back to unit variance so the first decision epochs
    see finite, merely-centered inputs."""
    var = jnp.where(norm.count > 1.0,
                    norm.m2 / jnp.maximum(norm.count, 1.0),
                    jnp.ones_like(norm.m2))
    return jnp.clip((x - norm.mean) / jnp.sqrt(var + 1e-8), -10.0, 10.0)


def reward_norm_update(r, mean, var, count, scale: float = 1.0):
    """Running reward standardization (same scheme as ddpg/dqn ``store``).

    Returns ``(r_std, mean, var, count)`` — the standardized reward plus
    the advanced statistics to put back in the carry."""
    r = r * scale
    cnt = count + 1
    alpha = jnp.maximum(0.02, 1.0 / cnt.astype(jnp.float32))
    new_mean = mean + alpha * (r - mean)
    new_var = (1 - alpha) * var + alpha * jnp.square(r - new_mean)
    r_std = jnp.clip((r - new_mean) / jnp.maximum(jnp.sqrt(new_var), 1e-4),
                     -10.0, 10.0)
    return r_std, new_mean, new_var, cnt


def trace_decay_add(traces, grads, decay):
    """z ← decay·z + g, leafwise.  ``decay`` is a traced scalar — γλ, or
    γλ·1{greedy} for the Watkins cut in Stream Q(λ)."""
    return jax.tree.map(lambda z, g: decay * z + g, traces, grads)


def trace_zeros_like(params):
    return jax.tree.map(jnp.zeros_like, params)


def obgd_step(params, traces, delta, lr: float, kappa: float):
    """Overshoot-bounded gradient descent (arXiv 2410.14606, Algorithm 3).

    One TD update ``w ← w + α_eff·δ·z`` where the effective stepsize is
    bounded so the update cannot cross the TD target:

        δ̄    = max(|δ|, 1)
        M    = α·κ·δ̄·‖z‖₁
        α_eff = α / max(M, 1)

    ``κ > 1`` leaves safety margin.  δ = 0 (a consumed pending update)
    makes this an exact no-op, so calling it more than once per
    transition — e.g. ``updates_per_epoch > 1`` in the fused epoch body —
    applies the TD step exactly once."""
    z_l1 = sum(jnp.abs(z).sum() for z in jax.tree_util.tree_leaves(traces))
    delta_bar = jnp.maximum(jnp.abs(delta), 1.0)
    bound = lr * kappa * delta_bar * z_l1
    step = lr / jnp.maximum(bound, 1.0)
    return jax.tree.map(lambda p, z: p + step * delta * z, params, traces)
