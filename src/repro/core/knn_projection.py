"""Exact replacement for the paper's MIQP-NN optimizer (DESIGN.md §2).

The paper finds the K nearest feasible assignments to a continuous
proto-action â ∈ R^{N×M} by solving K Mixed-Integer Quadratic Programs with
Gurobi.  Because the feasible set is a product of independent row simplices
({0,1} rows summing to 1), the squared distance decomposes per row:

    ||a − â||² = Σ_i (1 − 2·â[i, j_i] + ||â_i||²)

so the 1-NN is the row-wise argmax of â, and the k-th NN differs from the
1-NN by "flipping" some rows to lower-ranked columns, paying per-row regret

    Δ[i, c] = 2·(â[i, (1)] − â[i, (c)])      (sorted descending per row).

Finding the K nearest assignments is then the classic *k-smallest sums*
problem over N independent regret ladders, solved exactly with a best-first
heap (host path), or with a vectorized candidate beam (JAX path used inside
the jitted DDPG update).  Both are validated against brute force in tests."""
from __future__ import annotations

import heapq
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# Host path: exact best-first k-best enumeration (replaces Gurobi loop).
# --------------------------------------------------------------------------
def knn_assignments_exact(proto: np.ndarray, k: int) -> np.ndarray:
    """Exact K nearest one-hot assignments to ``proto`` ([N, M]).

    Returns ranks ``[k, N]`` of chosen columns, ordered by distance."""
    proto = np.asarray(proto, dtype=np.float64)
    n, m = proto.shape
    order = np.argsort(-proto, axis=1)                   # [N, M] cols by desc value
    sorted_vals = np.take_along_axis(proto, order, axis=1)
    # regret ladder: cost of moving row i from rank 0 to rank c
    regret = 2.0 * (sorted_vals[:, :1] - sorted_vals)    # [N, M], col 0 = 0

    # best-first search over rank vectors
    start = (0.0, tuple([0] * n))
    heap = [start]
    seen = {start[1]}
    out = []
    while heap and len(out) < k:
        cost, ranks = heapq.heappop(heap)
        out.append(ranks)
        for i in range(n):
            c = ranks[i] + 1
            if c >= m:
                continue
            nxt = list(ranks)
            nxt[i] = c
            nxt_t = tuple(nxt)
            if nxt_t in seen:
                continue
            seen.add(nxt_t)
            heapq.heappush(heap, (cost - regret[i, ranks[i]] + regret[i, c], nxt_t))

    cols = np.stack([
        order[np.arange(n), np.asarray(ranks)] for ranks in out
    ])                                                    # [k', N]
    if cols.shape[0] < k:                                 # degenerate tiny spaces
        cols = np.concatenate([cols, np.repeat(cols[-1:], k - cols.shape[0], 0)])
    return cols


def knn_actions_exact(proto: np.ndarray, k: int) -> np.ndarray:
    """One-hot action set [k, N, M] (host / numpy)."""
    proto = np.asarray(proto)
    n, m = proto.shape
    cols = knn_assignments_exact(proto, k)
    return np.eye(m, dtype=np.float32)[cols]              # [k, N, M]


# --------------------------------------------------------------------------
# JAX path: vectorized candidate beam used inside jit (DDPG target values).
#
# Candidates: the 1-NN, all single-row flips ranked by regret, plus pair and
# triple combinations of the cheapest single flips.  For continuous protos
# this recovers the exact top-K with overwhelming probability (tests check
# equality against the host path); by construction it always contains the
# exact 1-NN and only feasible actions.
#
# ``use_pallas=True`` computes the per-row top-2/regret reduction with the
# kernels-layer Pallas kernel (kernels/knn_topk) instead of lax.top_k —
# compiled on TPU, interpret-mode everywhere else (automatic fallback) —
# so the DDPG select hot path exercises the kernel.
# --------------------------------------------------------------------------
def _row_top2(proto: jnp.ndarray, use_pallas: bool):
    """(best_col [N] i32, second_col [N] i32, flip_regret [N] f32)."""
    if use_pallas:
        from repro.kernels.knn_topk import row_top2_regret
        return row_top2_regret(
            proto, interpret=jax.default_backend() != "tpu")
    top2_vals, top2_idx = jax.lax.top_k(proto, 2)         # [N, 2]
    flip_regret = 2.0 * (top2_vals[:, 0] - top2_vals[:, 1])   # [N]
    return top2_idx[:, 0], top2_idx[:, 1], flip_regret


@partial(jax.jit,
         static_argnames=("k", "pair_pool", "triple_pool", "use_pallas"))
def knn_actions_jax(
    proto: jnp.ndarray, k: int, pair_pool: int = 8, triple_pool: int = 4,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """[k, N, M] one-hot candidate actions, ordered by distance to proto."""
    n, m = proto.shape
    # best / 2nd-best machine per row + single-flip regret to the 2nd-best
    best_col, second_col, flip_regret = _row_top2(proto, use_pallas)

    pool = min(max(pair_pool, triple_pool, k), n)
    cheap_cost, cheap_rows = jax.lax.top_k(-flip_regret, pool)
    cheap_cost = -cheap_cost                              # ascending regrets

    # candidate flip masks over the `pool` cheapest rows
    masks = [jnp.zeros((pool,), jnp.bool_)]
    costs = [jnp.zeros(())]
    for i in range(pool):                                 # singles
        masks.append(jnp.zeros((pool,), jnp.bool_).at[i].set(True))
        costs.append(cheap_cost[i])
    for i in range(min(pair_pool, pool)):                 # pairs
        for j in range(i + 1, min(pair_pool, pool)):
            masks.append(jnp.zeros((pool,), jnp.bool_).at[i].set(True).at[j].set(True))
            costs.append(cheap_cost[i] + cheap_cost[j])
    for i in range(min(triple_pool, pool)):               # triples
        for j in range(i + 1, min(triple_pool, pool)):
            for l in range(j + 1, min(triple_pool, pool)):
                masks.append(
                    jnp.zeros((pool,), jnp.bool_).at[i].set(True).at[j].set(True).at[l].set(True)
                )
                costs.append(cheap_cost[i] + cheap_cost[j] + cheap_cost[l])
    cand_masks = jnp.stack(masks)                         # [C, pool]
    cand_costs = jnp.stack(costs)                         # [C]

    kk = min(k, cand_costs.shape[0])
    _, sel = jax.lax.top_k(-cand_costs, kk)               # k cheapest candidates

    def build(mask_row):
        # rows in `cheap_rows` flagged by mask flip to their 2nd-best column
        flip_full = jnp.zeros((n,), jnp.bool_).at[cheap_rows].set(mask_row)
        cols = jnp.where(flip_full, second_col, best_col)
        return jax.nn.one_hot(cols, m, dtype=jnp.float32)

    actions = jax.vmap(build)(cand_masks[sel])            # [kk, N, M]
    if kk < k:
        actions = jnp.concatenate(
            [actions, jnp.repeat(actions[-1:], k - kk, axis=0)], axis=0
        )
    return actions


def nearest_assignment(proto: jnp.ndarray) -> jnp.ndarray:
    """Exact 1-NN: row-wise argmax, one-hot."""
    return jax.nn.one_hot(jnp.argmax(proto, axis=-1), proto.shape[-1],
                          dtype=jnp.float32)


def distance_to(proto: jnp.ndarray, action: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.square(action - proto), axis=(-2, -1))
