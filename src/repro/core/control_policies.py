"""Non-placement control policies: rate control and auto-tuning agents.

The serving control plane dispatches three decision kinds (see
``core/spaces.py``); placement is served by the learned DDPG/DQN agents,
and these two deterministic policies serve the other kinds through the
SAME :class:`~repro.core.api.Agent` contract — module-level pure
functions over a frozen, hashable config, so they ride the fleet runner,
the batched serving path, and jit static arguments exactly like the
learned agents do.

* ``rate_control`` — a feedback throttle (the "Generalised Rate Control"
  decision kind): from the normalized spout rates in the state vector it
  picks, per spout, the LARGEST admission level that keeps the admitted
  load under ``cfg.utilization_cap`` × the cluster's declared base rate —
  admit as much as possible, backpressure only what overloads.
* ``auto_tune`` — a model-grounded knob search (the "Auto-tuning ...
  using RL" decision kind): decodes (X, w) from the state vector, then
  evaluates every ``TUNE_GRID`` operating point under the CLUSTER'S OWN
  EnvParams (``repro.dsdps.actions.apply_config_action``) through the
  queueing model and returns the argmin — a heterogeneous cluster fleet
  gets per-cluster tunings from one vmapped program.

Both policies decide from ``(s_vec, env_params)`` alone (``env_state`` is
ignored), which is the serving contract — see serve/control.py."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import api
from repro.dsdps.actions import RATE_LEVELS, TUNE_GRID, decode_state
from repro.dsdps.env import SchedulingEnv
from repro.dsdps.simulator import average_tuple_time_from_params


# --------------------------------------------------------------------------
# rate_control
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RateControlConfig:
    n_spouts: int
    levels: tuple[float, ...] = RATE_LEVELS     # ascending admission grid
    utilization_cap: float = 1.0                # max admitted / base rate


def _rate_init(key, cfg: RateControlConfig, env_params=None):
    return jnp.zeros((), jnp.int32)


def _rate_select(key, cfg: RateControlConfig, state, s_vec, env_state,
                 env_params, explore):
    # the state vector's tail is w / base_rates (SchedulingEnv.state_vector)
    w_norm = s_vec[-cfg.n_spouts:]                               # [S]
    levels = jnp.asarray(cfg.levels, jnp.float32)                # [L]
    admitted = levels[None, :] * w_norm[:, None]                 # [S, L]
    fits = (admitted <= cfg.utilization_cap).astype(jnp.int32)
    # largest fitting level; all-overloaded spouts fall back to levels[0]
    idx = jnp.maximum(fits.sum(axis=1) - 1, 0)
    action = jax.nn.one_hot(idx, len(cfg.levels), dtype=jnp.float32)
    return action, idx


def _noop_observe(cfg, state, s_vec, aux, reward, s_next):
    return state


def _noop_update(key, cfg, state):
    return state


def _tick(cfg, state):
    return state + 1


def rate_control_agent(cfg: RateControlConfig) -> api.Agent:
    return api.Agent(name="rate_control", cfg=cfg, init_fn=_rate_init,
                     select_fn=_rate_select, observe_fn=_noop_observe,
                     update_fn=_noop_update, tick_fn=_tick)


def rate_control_factory(env, **overrides) -> api.Agent:
    cfg = overrides.pop("cfg", None)
    if cfg is None:
        cfg = RateControlConfig(n_spouts=env.workload.num_spouts,
                                **overrides)
    return rate_control_agent(cfg)


# serving-only: rate actions are [S, L] level choices, not executor→machine
# placements — they never reach env.step (families=())
api.register_agent("rate_control", rate_control_factory, families=())


# --------------------------------------------------------------------------
# auto_tune
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AutoTuneConfig:
    env: SchedulingEnv          # hashable by identity (static spec)
    grid: tuple[tuple[float, float], ...] = TUNE_GRID


def _tune_init(key, cfg: AutoTuneConfig, env_params=None):
    return jnp.zeros((), jnp.int32)


def _tune_select(key, cfg: AutoTuneConfig, state, s_vec, env_state,
                 env_params, explore):
    env = cfg.env
    p = env.default_params() if env_params is None else env_params
    X, w = decode_state(env, s_vec, p)
    # the grid is static and small: unroll the candidate evaluations
    lats = jnp.stack([
        average_tuple_time_from_params(
            X, w,
            p._replace(acker_ms=p.acker_ms * acker_scale,
                       tuple_bytes=p.tuple_bytes * batch_scale),
            env.params, env.cluster)
        for acker_scale, batch_scale in cfg.grid
    ])
    action = jax.nn.one_hot(jnp.argmin(lats), len(cfg.grid),
                            dtype=jnp.float32)
    return action, lats


def auto_tune_agent(cfg: AutoTuneConfig) -> api.Agent:
    return api.Agent(name="auto_tune", cfg=cfg, init_fn=_tune_init,
                     select_fn=_tune_select, observe_fn=_noop_observe,
                     update_fn=_noop_update, tick_fn=_tick)


def auto_tune_factory(env, **overrides) -> api.Agent:
    cfg = overrides.pop("cfg", None)
    if cfg is None:
        cfg = AutoTuneConfig(env=env, **overrides)
    return auto_tune_agent(cfg)


# serving-only, like rate_control: actions index the tuning grid
api.register_agent("auto_tune", auto_tune_factory, families=())
