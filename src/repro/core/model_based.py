"""Model-based predictive scheduler — the state-of-the-art baseline [25]
(Li et al., "Performance modeling and predictive scheduling for distributed
stream data processing", IEEE TBD 2016).

[25] fits supervised regressors (SVR) for per-component processing and
per-pair transfer delays, combines them into an end-to-end latency
prediction for a candidate schedule, and searches assignments under the
model's guidance.  We reproduce that architecture: a ridge regressor over
hand-crafted per-machine load/traffic features (the information [25]
collects from runtime statistics) + greedy move-based local search.  Its
characteristic weakness — model bias: the feature model cannot represent
every interaction in the real system — is exactly what the paper exploits."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.dsdps.env import SchedulingEnv


def features(env: SchedulingEnv, X: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Per-machine load & traffic statistics visible to [25]'s collectors.

    Utilization is speed-adjusted: [25] measures *per-machine delays*, so
    its model implicitly knows which machines are slow."""
    p = env.params
    n = env.N
    w_full = jnp.zeros(n).at[jnp.asarray(p.spout_ids)].set(w)
    lam = jnp.asarray(p.flow_solve) @ w_full
    # component-level profiled means — the per-executor reality deviates
    # (SimParams.service_ms), which is precisely the model bias the paper
    # exploits (§1: "prediction for each individual component may not be
    # accurate")
    c_ms = jnp.asarray(p.nominal_service_ms)
    demand = (X * (lam * c_ms / 1e3)[:, None]).sum(0)          # [M]
    same = X @ X.T
    bytes_per_s = (lam[:, None] * jnp.asarray(p.routing)) * \
        jnp.asarray(p.tuple_bytes)[:, None]
    cross = bytes_per_s * (1.0 - same)
    out_load = (X * cross.sum(1)[:, None]).sum(0) / 1e8         # [M]
    in_load = (X * cross.sum(0)[:, None]).sum(0) / 1e8          # [M]
    speed = jnp.asarray(env.cluster.speed_factors())
    util = demand / (env.cluster.cores_per_machine * speed)
    feats = jnp.concatenate([
        util, util ** 2, util ** 3,
        out_load, in_load,
        jnp.array([
            util.max(), util.mean(),
            out_load.max(), in_load.max(),
            cross.sum() / 1e8,
            w.mean() / 1e3, w.sum() / 1e4,
        ]),
    ])
    return feats


@dataclasses.dataclass
class ModelBasedScheduler:
    env: SchedulingEnv
    ridge_lambda: float = 1e-3
    theta: jnp.ndarray | None = None

    # -- model fitting ------------------------------------------------------
    def fit(self, key: jax.Array, n_samples: int = 400) -> "ModelBasedScheduler":
        """Collect (random schedule, measured latency) pairs and fit ridge."""
        env = self.env
        keys = jax.random.split(key, n_samples)

        speed = jnp.asarray(env.cluster.speed_factors())

        @jax.jit
        def sample_one(k):
            k_a, k_n = jax.random.split(k)
            X = env.random_assignment(k_a)
            w = env.workload.init()
            from repro.dsdps.simulator import measured_latency_ms
            y = measured_latency_ms(k_n, X, w, env.params, env.cluster,
                                    speed=speed, noise_sigma=env.noise_sigma)
            return features(env, X, w), y

        F, Y = jax.vmap(sample_one)(keys)
        F = jnp.concatenate([F, jnp.ones((F.shape[0], 1))], axis=1)
        A = F.T @ F + self.ridge_lambda * jnp.eye(F.shape[1])
        self.theta = jnp.linalg.solve(A, F.T @ Y)
        return self

    def predict(self, X: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        f = features(self.env, X, w)
        f = jnp.concatenate([f, jnp.ones(1)])
        return f @ self.theta

    # -- model-guided greedy local search ------------------------------------
    def schedule(self, w: jnp.ndarray, X0: jnp.ndarray | None = None,
                 sweeps: int = 3) -> jnp.ndarray:
        env = self.env
        n, m = env.N, env.M
        X = env.round_robin_assignment() if X0 is None else X0
        theta = self.theta

        @jax.jit
        def best_move_for(X, i):
            def try_machine(j):
                Xj = X.at[i].set(jax.nn.one_hot(j, m, dtype=X.dtype))
                f = features(env, Xj, w)
                f = jnp.concatenate([f, jnp.ones(1)])
                return f @ theta
            preds = jax.vmap(try_machine)(jnp.arange(m))
            j = jnp.argmin(preds)
            return X.at[i].set(jax.nn.one_hot(j, m, dtype=X.dtype)), preds.min()

        for _ in range(sweeps):
            for i in range(n):
                X, _ = best_move_for(X, jnp.asarray(i))
        return X
