"""Model-based predictive scheduler — the state-of-the-art baseline [25]
(Li et al., "Performance modeling and predictive scheduling for distributed
stream data processing", IEEE TBD 2016).

[25] fits supervised regressors (SVR) for per-component processing and
per-pair transfer delays, combines them into an end-to-end latency
prediction for a candidate schedule, and searches assignments under the
model's guidance.  We reproduce that architecture: a ridge regressor over
hand-crafted per-machine load/traffic features (the information [25]
collects from runtime statistics) + greedy move-based local search.  Its
characteristic weakness — model bias: the feature model cannot represent
every interaction in the real system — is exactly what the paper exploits.

Everything here is EnvParams-aware: ``features`` / ``fit_theta`` /
``predict`` / the sweep search all take the scenario the baseline actually
controls (lane-correct machine speeds, service means, arrival rates, and
measurement noise), defaulting to the env's nominal profile.  In a
heterogeneous scenario fleet each model-based lane therefore profiles,
fits, and searches ITS cluster — a straggler lane fits a straggler model —
which is what makes the paper's latency comparison against [25] credible.
The greedy local search is a single jitted ``lax.scan`` over executors ×
``vmap`` over machines (no per-call re-jitting), so a fleet of model-based
lanes searches in one XLA program."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import api
from repro.dsdps.env import SchedulingEnv
from repro.dsdps.simulator import (EnvParams, measured_latency_from_params,
                                   params_in_axes)


def features(env: SchedulingEnv, X: jnp.ndarray, w: jnp.ndarray,
             params: EnvParams | None = None) -> jnp.ndarray:
    """Per-machine load & traffic statistics visible to [25]'s collectors,
    computed from the scenario ``params`` actually in effect (the env's
    nominal profile when None).

    Utilization is speed-adjusted: [25] measures *per-machine delays*, so
    its model implicitly knows which machines are slow — including the
    lane's stragglers when ``params`` carries a perturbed speed vector."""
    p = env.default_params() if params is None else params
    n = env.N
    w_full = jnp.zeros(n).at[jnp.asarray(env.params.spout_ids)].set(w)
    lam = p.flow_solve @ w_full
    # component-level profiled means — the per-executor reality deviates
    # (EnvParams.service_ms), which is precisely the model bias the paper
    # exploits (§1: "prediction for each individual component may not be
    # accurate")
    c_ms = p.nominal_service_ms
    demand = (X * (lam * c_ms / 1e3)[:, None]).sum(0)          # [M]
    same = X @ X.T
    bytes_per_s = (lam[:, None] * p.routing) * p.tuple_bytes[:, None]
    cross = bytes_per_s * (1.0 - same)
    out_load = (X * cross.sum(1)[:, None]).sum(0) / 1e8         # [M]
    in_load = (X * cross.sum(0)[:, None]).sum(0) / 1e8          # [M]
    util = demand / (env.cluster.cores_per_machine * p.speed)
    feats = jnp.concatenate([
        util, util ** 2, util ** 3,
        out_load, in_load,
        jnp.array([
            util.max(), util.mean(),
            out_load.max(), in_load.max(),
            cross.sum() / 1e8,
            w.mean() / 1e3, w.sum() / 1e4,
        ]),
    ])
    return feats


def predict_latency(env: SchedulingEnv, theta: jnp.ndarray, X: jnp.ndarray,
                    w: jnp.ndarray,
                    params: EnvParams | None = None) -> jnp.ndarray:
    """The fitted model's end-to-end latency prediction for one schedule."""
    f = jnp.concatenate([features(env, X, w, params), jnp.ones(1)])
    return f @ theta


def fit_theta(key: jax.Array, env: SchedulingEnv, n_samples: int = 400,
              ridge_lambda: float = 1e-3,
              params: EnvParams | None = None) -> jnp.ndarray:
    """Collect (random schedule, measured latency) pairs and fit the ridge
    regressor — [25]'s offline profiling phase as one pure jax function.

    Profiling measures the cluster described by ``params`` (speeds, true
    service costs, arrival rates, telemetry noise), so a fleet of
    model-based lanes can each fit its own scenario's model in one vmapped
    program (jit/vmap-safe)."""
    p = env.default_params() if params is None else params
    keys = jax.random.split(key, n_samples)

    def sample_one(k):
        k_a, k_n = jax.random.split(k)
        X = env.random_assignment(k_a)
        w = p.base_rates
        y = measured_latency_from_params(k_n, X, w, p, env.params,
                                         env.cluster)
        return features(env, X, w, p), y

    F, Y = jax.vmap(sample_one)(keys)
    F = jnp.concatenate([F, jnp.ones((F.shape[0], 1))], axis=1)
    A = F.T @ F + ridge_lambda * jnp.eye(F.shape[1])
    return jnp.linalg.solve(A, F.T @ Y)


# Module-level cached jit: `ModelBasedScheduler.fit` used to build a fresh
# `jax.jit(fit_theta, ...)` wrapper inside the method — a retrace on every
# call.  One wrapper, jit's own cache keyed on (env, n_samples).
_fit_theta_jit = jax.jit(fit_theta, static_argnums=(1, 2))


@partial(jax.jit, static_argnames=("env", "sweeps"))
def sweep_schedule(X0: jnp.ndarray, w: jnp.ndarray, theta: jnp.ndarray,
                   env: SchedulingEnv, params: EnvParams | None = None,
                   sweeps: int = 3) -> jnp.ndarray:
    """[25]'s model-guided greedy local search as ONE jitted program:
    ``lax.scan`` over executors (each step re-places one executor at the
    model's argmin machine), ``vmap`` over candidate machines, scanned over
    ``sweeps`` passes.  Replaces the per-call-jitted Python sweeps×N loop —
    repeated calls with the same (env, sweeps) never retrace, and the whole
    search vmaps over a fleet of (X0, w, theta, params) lanes."""
    m = env.M

    def place_one(X, i):
        def try_machine(j):
            Xj = X.at[i].set(jax.nn.one_hot(j, m, dtype=X.dtype))
            return predict_latency(env, theta, Xj, w, params)

        preds = jax.vmap(try_machine)(jnp.arange(m))
        j = jnp.argmin(preds)
        return X.at[i].set(jax.nn.one_hot(j, m, dtype=X.dtype)), preds.min()

    def one_sweep(X, _):
        X, _ = jax.lax.scan(place_one, X, jnp.arange(env.N))
        return X, None

    X, _ = jax.lax.scan(one_sweep, X0, None, length=sweeps)
    return X


def sweep_schedule_fleet(X0s: jnp.ndarray, ws: jnp.ndarray,
                         thetas: jnp.ndarray, env: SchedulingEnv,
                         params: EnvParams, sweeps: int = 3) -> jnp.ndarray:
    """A fleet of model-based searches in one XLA program: vmap of
    :func:`sweep_schedule` over stacked (X0, w, theta) lanes and a stacked
    (possibly broadcast-invariant) EnvParams scenario fleet."""
    axes = params_in_axes(params, env.default_params())
    return jax.vmap(
        lambda X0, w, th, p: sweep_schedule(X0, w, th, env, p, sweeps),
        in_axes=(0, 0, 0, axes))(X0s, ws, thetas, params)


@dataclasses.dataclass
class ModelBasedScheduler:
    env: SchedulingEnv
    ridge_lambda: float = 1e-3
    theta: jnp.ndarray | None = None
    env_params: EnvParams | None = None   # scenario the baseline controls

    def _params(self) -> EnvParams:
        return (self.env.default_params() if self.env_params is None
                else self.env_params)

    # -- model fitting ------------------------------------------------------
    def fit(self, key: jax.Array, n_samples: int = 400) -> "ModelBasedScheduler":
        """Collect (random schedule, measured latency) pairs and fit ridge
        under this scheduler's scenario params."""
        self.theta = _fit_theta_jit(key, self.env, n_samples,
                                    self.ridge_lambda, self._params())
        return self

    def predict(self, X: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        return predict_latency(self.env, self.theta, X, w, self._params())

    # -- model-guided greedy local search ------------------------------------
    def schedule(self, w: jnp.ndarray, X0: jnp.ndarray | None = None,
                 sweeps: int = 3) -> jnp.ndarray:
        X = self.env.round_robin_assignment() if X0 is None else X0
        return sweep_schedule(X, w, self.theta, self.env, self._params(),
                              sweeps)


# --------------------------------------------------------------------------
# Agent-interface adapter: [25] as a non-learning Agent.  ``init`` runs the
# offline profiling + ridge fit under the LANE's EnvParams (the agent state
# IS the fitted theta — in a heterogeneous fleet every lane fits its own
# scenario's model); ``select`` applies one step of model-guided local
# search per decision epoch — the best single-executor move under the
# model's latency prediction for the lane's scenario (the no-op move is a
# candidate, so "stay" is always allowed).
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelBasedAgentConfig:
    env: SchedulingEnv          # hashable by identity (static spec)
    fit_samples: int = 400
    ridge_lambda: float = 1e-3


def _agent_init(key, cfg: ModelBasedAgentConfig, env_params=None):
    return fit_theta(key, cfg.env, cfg.fit_samples, cfg.ridge_lambda,
                     env_params)


def _agent_select(key, cfg: ModelBasedAgentConfig, theta, s_vec, env_state,
                  env_params, explore):
    env = cfg.env
    n, m = env.N, env.M
    X, w = env_state.X, env_state.w

    def predict_move(move):
        i, j = move // m, move % m
        Xj = X.at[i].set(jax.nn.one_hot(j, m, dtype=X.dtype))
        return predict_latency(env, theta, Xj, w, env_params)

    preds = jax.vmap(predict_move)(jnp.arange(n * m))
    best = jnp.argmin(preds)
    i, j = best // m, best % m
    X_new = X.at[i].set(jax.nn.one_hot(j, m, dtype=X.dtype))
    return X_new, jnp.zeros(())


def _agent_observe(cfg, theta, s_vec, aux, reward, s_next):
    return theta


def _agent_update(key, cfg, theta):
    return theta


def _agent_tick(cfg, theta):
    return theta


def as_agent(cfg: ModelBasedAgentConfig) -> api.Agent:
    return api.Agent(name="model_based", cfg=cfg, init_fn=_agent_init,
                     select_fn=_agent_select, observe_fn=_agent_observe,
                     update_fn=_agent_update, tick_fn=_agent_tick)


def agent_factory(env, **overrides) -> api.Agent:
    cfg = overrides.pop("cfg", None)
    if cfg is None:
        cfg = ModelBasedAgentConfig(env=env, **overrides)
    return as_agent(cfg)


# scheduling-only: the analytic queueing model it profiles/searches is the
# DSDPS simulator's — it has no placement-env counterpart
api.register_agent("model_based", agent_factory, families=("scheduling",))
