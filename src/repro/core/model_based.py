"""Model-based predictive scheduler — the state-of-the-art baseline [25]
(Li et al., "Performance modeling and predictive scheduling for distributed
stream data processing", IEEE TBD 2016).

[25] fits supervised regressors (SVR) for per-component processing and
per-pair transfer delays, combines them into an end-to-end latency
prediction for a candidate schedule, and searches assignments under the
model's guidance.  We reproduce that architecture: a ridge regressor over
hand-crafted per-machine load/traffic features (the information [25]
collects from runtime statistics) + greedy move-based local search.  Its
characteristic weakness — model bias: the feature model cannot represent
every interaction in the real system — is exactly what the paper exploits."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.dsdps.env import SchedulingEnv
from repro.dsdps.simulator import measured_latency_ms


def features(env: SchedulingEnv, X: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Per-machine load & traffic statistics visible to [25]'s collectors.

    Utilization is speed-adjusted: [25] measures *per-machine delays*, so
    its model implicitly knows which machines are slow."""
    p = env.params
    n = env.N
    w_full = jnp.zeros(n).at[jnp.asarray(p.spout_ids)].set(w)
    lam = jnp.asarray(p.flow_solve) @ w_full
    # component-level profiled means — the per-executor reality deviates
    # (SimParams.service_ms), which is precisely the model bias the paper
    # exploits (§1: "prediction for each individual component may not be
    # accurate")
    c_ms = jnp.asarray(p.nominal_service_ms)
    demand = (X * (lam * c_ms / 1e3)[:, None]).sum(0)          # [M]
    same = X @ X.T
    bytes_per_s = (lam[:, None] * jnp.asarray(p.routing)) * \
        jnp.asarray(p.tuple_bytes)[:, None]
    cross = bytes_per_s * (1.0 - same)
    out_load = (X * cross.sum(1)[:, None]).sum(0) / 1e8         # [M]
    in_load = (X * cross.sum(0)[:, None]).sum(0) / 1e8          # [M]
    speed = jnp.asarray(env.cluster.speed_factors())
    util = demand / (env.cluster.cores_per_machine * speed)
    feats = jnp.concatenate([
        util, util ** 2, util ** 3,
        out_load, in_load,
        jnp.array([
            util.max(), util.mean(),
            out_load.max(), in_load.max(),
            cross.sum() / 1e8,
            w.mean() / 1e3, w.sum() / 1e4,
        ]),
    ])
    return feats


def fit_theta(key: jax.Array, env: SchedulingEnv, n_samples: int = 400,
              ridge_lambda: float = 1e-3) -> jnp.ndarray:
    """Collect (random schedule, measured latency) pairs and fit the ridge
    regressor — [25]'s offline profiling phase as one pure jax function
    (jit/vmap-safe, so a fleet of model-based lanes can each fit its own
    model in one program)."""
    keys = jax.random.split(key, n_samples)
    speed = jnp.asarray(env.cluster.speed_factors())

    def sample_one(k):
        k_a, k_n = jax.random.split(k)
        X = env.random_assignment(k_a)
        w = env.workload.init()
        y = measured_latency_ms(k_n, X, w, env.params, env.cluster,
                                speed=speed, noise_sigma=env.noise_sigma)
        return features(env, X, w), y

    F, Y = jax.vmap(sample_one)(keys)
    F = jnp.concatenate([F, jnp.ones((F.shape[0], 1))], axis=1)
    A = F.T @ F + ridge_lambda * jnp.eye(F.shape[1])
    return jnp.linalg.solve(A, F.T @ Y)


@dataclasses.dataclass
class ModelBasedScheduler:
    env: SchedulingEnv
    ridge_lambda: float = 1e-3
    theta: jnp.ndarray | None = None

    # -- model fitting ------------------------------------------------------
    def fit(self, key: jax.Array, n_samples: int = 400) -> "ModelBasedScheduler":
        """Collect (random schedule, measured latency) pairs and fit ridge."""
        self.theta = jax.jit(fit_theta, static_argnums=(1, 2))(
            key, self.env, n_samples, self.ridge_lambda)
        return self

    def predict(self, X: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        f = features(self.env, X, w)
        f = jnp.concatenate([f, jnp.ones(1)])
        return f @ self.theta

    # -- model-guided greedy local search ------------------------------------
    def schedule(self, w: jnp.ndarray, X0: jnp.ndarray | None = None,
                 sweeps: int = 3) -> jnp.ndarray:
        env = self.env
        n, m = env.N, env.M
        X = env.round_robin_assignment() if X0 is None else X0
        theta = self.theta

        @jax.jit
        def best_move_for(X, i):
            def try_machine(j):
                Xj = X.at[i].set(jax.nn.one_hot(j, m, dtype=X.dtype))
                f = features(env, Xj, w)
                f = jnp.concatenate([f, jnp.ones(1)])
                return f @ theta
            preds = jax.vmap(try_machine)(jnp.arange(m))
            j = jnp.argmin(preds)
            return X.at[i].set(jax.nn.one_hot(j, m, dtype=X.dtype)), preds.min()

        for _ in range(sweeps):
            for i in range(n):
                X, _ = best_move_for(X, jnp.asarray(i))
        return X


# --------------------------------------------------------------------------
# Agent-interface adapter: [25] as a non-learning Agent.  ``init`` runs the
# offline profiling + ridge fit (the agent state IS the fitted theta);
# ``select`` applies one step of model-guided local search per decision
# epoch — the best single-executor move under the model's latency
# prediction (the no-op move is a candidate, so "stay" is always allowed).
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelBasedAgentConfig:
    env: SchedulingEnv          # hashable by identity (static spec)
    fit_samples: int = 400
    ridge_lambda: float = 1e-3


def _agent_init(key, cfg: ModelBasedAgentConfig):
    return fit_theta(key, cfg.env, cfg.fit_samples, cfg.ridge_lambda)


def _agent_select(key, cfg: ModelBasedAgentConfig, theta, s_vec, env_state,
                  explore):
    env = cfg.env
    n, m = env.N, env.M
    X, w = env_state.X, env_state.w

    def predict_move(move):
        i, j = move // m, move % m
        Xj = X.at[i].set(jax.nn.one_hot(j, m, dtype=X.dtype))
        f = jnp.concatenate([features(env, Xj, w), jnp.ones(1)])
        return f @ theta

    preds = jax.vmap(predict_move)(jnp.arange(n * m))
    best = jnp.argmin(preds)
    i, j = best // m, best % m
    X_new = X.at[i].set(jax.nn.one_hot(j, m, dtype=X.dtype))
    return X_new, jnp.zeros(())


def _agent_observe(cfg, theta, s_vec, aux, reward, s_next):
    return theta


def _agent_update(key, cfg, theta):
    return theta


def _agent_tick(cfg, theta):
    return theta


def as_agent(cfg: ModelBasedAgentConfig) -> api.Agent:
    return api.Agent(name="model_based", cfg=cfg, init_fn=_agent_init,
                     select_fn=_agent_select, observe_fn=_agent_observe,
                     update_fn=_agent_update, tick_fn=_agent_tick)


def agent_factory(env, **overrides) -> api.Agent:
    cfg = overrides.pop("cfg", None)
    if cfg is None:
        cfg = ModelBasedAgentConfig(env=env, **overrides)
    return as_agent(cfg)


api.register_agent("model_based", agent_factory)
