"""Algorithm 1 — the actor-critic-based method for scheduling (paper §3.2.1).

Faithful hyper-parameters: 2×(64,32,tanh) nets, τ=0.01, γ=0.99, |B|=1000,
H=32, ε-decayed uniform exploration noise, 10k random offline samples before
online learning.  The MIQP-NN optimizer is replaced by the exact k-best
projection (core/knn_projection.py, DESIGN.md §2)."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core import networks as nets
from repro.core.exploration import EpsilonSchedule, perturb_proto
from repro.core.knn_projection import knn_actions_exact, knn_actions_jax
from repro.core.replay import Replay, replay_add, replay_init, replay_sample
from repro.train.optimizer import adam, apply_updates


@dataclasses.dataclass(frozen=True)
class DDPGConfig:
    n_executors: int
    n_machines: int
    state_dim: int
    gamma: float = 0.99          # paper
    tau: float = 0.01            # paper
    k_nn: int = 12               # K nearest feasible actions
    batch: int = 32              # paper H
    buffer: int = 1000           # paper |B|
    # actor lr < critic lr: the deterministic-policy-gradient actor drifts
    # into critic-extrapolation regions over long online runs otherwise
    lr_actor: float = 2e-4
    lr_critic: float = 1e-3
    # rewards are negative milliseconds; an affine rescale (no change to the
    # optimal policy) keeps critic targets O(1) for stable training
    reward_scale: float = 0.25
    eps: EpsilonSchedule = EpsilonSchedule()
    # route the K-NN projection's top-2/regret reduction through the Pallas
    # kernel (kernels/knn_topk): compiled on TPU, interpret-mode fallback on
    # CPU — flips the select/target hot path onto the kernels layer
    use_pallas_knn: bool = False

    @property
    def action_dim(self) -> int:
        return self.n_executors * self.n_machines


class DDPGState(NamedTuple):
    actor: nets.MLPParams
    critic: nets.MLPParams
    target_actor: nets.MLPParams
    target_critic: nets.MLPParams
    opt_actor: object
    opt_critic: object
    replay: Replay
    epoch: jnp.ndarray
    # running reward statistics: rewards are stored STANDARDIZED
    # ((r−mean)/std).  Latency differences between schedules are a few
    # percent of the mean, so raw centered rewards are ~1e-2 — far too
    # small a regression target for the (paper-faithful, 64/32) critic.
    # An affine reward transform never changes the optimal policy.
    r_mean: jnp.ndarray = jnp.zeros(())
    r_var: jnp.ndarray = jnp.ones(())
    r_count: jnp.ndarray = jnp.zeros((), jnp.int32)


def init_state(key: jax.Array, cfg: DDPGConfig) -> DDPGState:
    ka, kc = jax.random.split(key)
    actor = nets.init_actor(ka, cfg.state_dim, cfg.action_dim)
    critic = nets.init_critic(kc, cfg.state_dim, cfg.action_dim)
    opt_a = adam(cfg.lr_actor)
    opt_c = adam(cfg.lr_critic)
    return DDPGState(
        actor=actor,
        critic=critic,
        target_actor=actor,
        target_critic=critic,
        opt_actor=opt_a.init(actor),
        opt_critic=opt_c.init(critic),
        replay=replay_init(cfg.buffer, cfg.state_dim, cfg.action_dim),
        epoch=jnp.zeros((), jnp.int32),
    )


# --------------------------------------------------------------------------
# Action selection (lines 8-11): proto -> explore -> K-NN -> critic argmax
# --------------------------------------------------------------------------
def select_action(
    key: jax.Array,
    state: DDPGState,
    cfg: DDPGConfig,
    s_vec: jnp.ndarray,
    explore: bool = True,
    exact_host_knn: bool = False,
    k_override: int | None = None,
) -> jnp.ndarray:
    """Returns a one-hot assignment [N, M].

    ``k_override`` widens the K-NN set (deploy time uses a much larger K
    than the per-epoch loop — the exact k-best enumeration makes K=256
    essentially free, unlike the paper's per-neighbour Gurobi solve)."""
    k = k_override or cfg.k_nn
    proto = nets.apply_actor(state.actor, s_vec).reshape(
        cfg.n_executors, cfg.n_machines)
    if explore:
        eps = cfg.eps(state.epoch)
        proto = perturb_proto(key, proto, eps)
    if exact_host_knn:
        cands = jnp.asarray(knn_actions_exact(np.asarray(proto), k))
    else:
        cands = knn_actions_jax(proto, k, use_pallas=cfg.use_pallas_knn)
    q = jax.vmap(
        lambda a: nets.apply_critic(state.critic, s_vec, a.reshape(-1))
    )(cands)
    return cands[jnp.argmax(q)]


@partial(jax.jit, static_argnames=("cfg", "explore"))
def select_action_jit(key, state: DDPGState, cfg: DDPGConfig, s_vec, explore: bool = True):
    return select_action(key, state, cfg, s_vec, explore=explore,
                         exact_host_knn=False)


# --------------------------------------------------------------------------
# One learning update (lines 13-18)
# --------------------------------------------------------------------------
def _target_values(state: DDPGState, cfg: DDPGConfig, r, s_next):
    """y_i = r_i + γ max_{a∈A_K(f'(s'))} Q'(s', a)   (line 15)."""
    def per_sample(sv):
        proto = nets.apply_actor(state.target_actor, sv).reshape(
            cfg.n_executors, cfg.n_machines)
        cands = knn_actions_jax(proto, cfg.k_nn,
                                use_pallas=cfg.use_pallas_knn)
        q = jax.vmap(
            lambda a: nets.apply_critic(state.target_critic, sv, a.reshape(-1))
        )(cands)
        return q.max()
    q_next = jax.vmap(per_sample)(s_next)
    return r + cfg.gamma * q_next


@partial(jax.jit, static_argnames=("cfg",))
def update_step(key: jax.Array, state: DDPGState, cfg: DDPGConfig) -> tuple:
    s, a, r, s_next = replay_sample(key, state.replay, cfg.batch)
    y = _target_values(state, cfg, r, s_next)

    def critic_loss(cp):
        q = jax.vmap(lambda sv, av: nets.apply_critic(cp, sv, av))(s, a)
        return jnp.mean(jnp.square(y - q))

    c_loss, c_grads = jax.value_and_grad(critic_loss)(state.critic)
    opt_c = adam(cfg.lr_critic)
    c_upd, opt_c_state = opt_c.update(c_grads, state.opt_critic, state.critic)
    critic = apply_updates(state.critic, c_upd)

    def actor_loss(ap):
        # deterministic policy gradient (line 17): ascend Q(s, f(s))
        protos = jax.vmap(lambda sv: nets.apply_actor(ap, sv))(s)
        q = jax.vmap(lambda sv, pv: nets.apply_critic(critic, sv, pv))(s, protos)
        return -jnp.mean(q)

    a_loss, a_grads = jax.value_and_grad(actor_loss)(state.actor)
    opt_a = adam(cfg.lr_actor)
    a_upd, opt_a_state = opt_a.update(a_grads, state.opt_actor, state.actor)
    actor = apply_updates(state.actor, a_upd)

    new_state = DDPGState(
        actor=actor,
        critic=critic,
        target_actor=nets.soft_update(state.target_actor, actor, cfg.tau),
        target_critic=nets.soft_update(state.target_critic, critic, cfg.tau),
        opt_actor=opt_a_state,
        opt_critic=opt_c_state,
        replay=state.replay,
        epoch=state.epoch,
    )
    return new_state, {"critic_loss": c_loss, "actor_loss": a_loss}


def store(state: DDPGState, s, a, r, s_next,
          reward_scale: float = 1.0) -> DDPGState:
    r = r * reward_scale
    cnt = state.r_count + 1
    alpha = jnp.maximum(0.02, 1.0 / cnt.astype(jnp.float32))
    mean = state.r_mean + alpha * (r - state.r_mean)
    var = (1 - alpha) * state.r_var + alpha * jnp.square(r - mean)
    r_std = (r - mean) / jnp.maximum(jnp.sqrt(var), 1e-4)
    return state._replace(
        replay=replay_add(state.replay, s, a, jnp.clip(r_std, -10, 10),
                          s_next),
        r_mean=mean, r_var=var, r_count=cnt)


def tick(state: DDPGState) -> DDPGState:
    return state._replace(epoch=state.epoch + 1)


# --------------------------------------------------------------------------
# The Agent-interface adapter (functional core API v1).  The fused online
# epoch — select → env.step → store → update×U → tick as ONE scan body —
# now lives in the generic api.make_epoch_step; these module-level pure
# functions implement its per-agent hooks.  The running reward-
# standardization statistics (r_mean/r_var/r_count) live in DDPGState and
# therefore ride the scan carry automatically.
# --------------------------------------------------------------------------
def _agent_init(key, cfg: DDPGConfig, env_params=None):
    return init_state(key, cfg)


def _agent_select(key, cfg: DDPGConfig, state, s_vec, env_state, env_params,
                  explore):
    a = select_action(key, state, cfg, s_vec, explore=explore,
                      exact_host_knn=False)
    return a, a.reshape(-1)


def _agent_observe(cfg: DDPGConfig, state, s_vec, aux, reward, s_next):
    return store(state, s_vec, aux, reward, s_next,
                 reward_scale=cfg.reward_scale)


def _agent_update(key, cfg: DDPGConfig, state):
    state, _ = update_step(key, state, cfg)
    return state


def _agent_tick(cfg: DDPGConfig, state):
    return tick(state)


def as_agent(cfg: DDPGConfig) -> api.Agent:
    """The actor-critic method as a pluggable Agent bundle."""
    return api.Agent(name="ddpg", cfg=cfg, init_fn=_agent_init,
                     select_fn=_agent_select, observe_fn=_agent_observe,
                     update_fn=_agent_update, tick_fn=_agent_tick)


def agent_factory(env, **overrides) -> api.Agent:
    """Registry hook: size a DDPGConfig for ``env`` (or pass ``cfg=``)."""
    cfg = overrides.pop("cfg", None)
    if cfg is None:
        cfg = DDPGConfig(n_executors=env.N, n_machines=env.M,
                         state_dim=env.state_dim, **overrides)
    return as_agent(cfg)


api.register_agent("ddpg", agent_factory)


def make_epoch_step(env, cfg: DDPGConfig, updates_per_epoch: int = 1,
                    explore: bool = True, env_params=None):
    """Scan body over decision epochs (compat wrapper over the generic
    api.make_epoch_step; key discipline matches run_online_ddpg_python)."""
    return api.make_epoch_step(env, as_agent(cfg), env_params=env_params,
                               updates_per_epoch=updates_per_epoch,
                               explore=explore)


def init_fleet(key: jax.Array, cfg: DDPGConfig, fleet: int) -> DDPGState:
    """Independently-initialized per-lane states, stacked on a leading
    [fleet] axis (the shape run_online_fleet expects)."""
    return jax.vmap(lambda k: init_state(k, cfg))(jax.random.split(key, fleet))


def offline_pretrain_fleet(
    keys: jax.Array,
    states: DDPGState,
    cfg: DDPGConfig,
    env,
    n_samples: int = 10_000,
    n_updates: int = 2_000,
    env_params=None,
) -> DDPGState:
    """vmap of offline_pretrain over stacked lanes: every lane collects its
    own random-action transitions and pretrains its own nets, all in one
    XLA program.  ``env_params`` may be a single EnvParams or a stacked
    scenario fleet (each lane then pretrains under its own scenario;
    per-leaf broadcast stacks ride with in_axes=None on shared leaves)."""
    if env_params is not None:
        from repro.dsdps.simulator import params_in_axes
        axes = params_in_axes(env_params, env.default_params())
        if axes is not None:
            return jax.vmap(
                lambda k, s, p: offline_pretrain(k, s, cfg, env,
                                                 n_samples=n_samples,
                                                 n_updates=n_updates,
                                                 env_params=p),
                in_axes=(0, 0, axes)
            )(keys, states, env_params)
    return jax.vmap(
        lambda k, s: offline_pretrain(k, s, cfg, env,
                                      n_samples=n_samples,
                                      n_updates=n_updates,
                                      env_params=env_params)
    )(keys, states)


# --------------------------------------------------------------------------
# Offline training (line 4): fill buffer with random-action transitions,
# then run gradient updates — paper: 10,000 samples per setup.
# --------------------------------------------------------------------------
def offline_pretrain(
    key: jax.Array,
    state: DDPGState,
    cfg: DDPGConfig,
    env,
    n_samples: int = 10_000,
    n_updates: int = 2_000,
    env_params=None,
) -> DDPGState:
    params = env.default_params() if env_params is None else env_params
    k_env, k_upd = jax.random.split(key)

    # scan bodies: lax.scan traces these inline — a per-call @jax.jit here
    # would only rebuild a never-reused wrapper every pretrain call
    def collect(carry, k):
        env_state = carry
        k_a, k_step = jax.random.split(k)
        action = env.random_assignment(k_a)
        out = env.step(k_step, env_state, action, params)
        s_vec = env.state_vector(env_state, params)
        s_next_vec = env.state_vector(out.state, params)
        return out.state, (s_vec, action.reshape(-1),
                           out.reward * cfg.reward_scale, s_next_vec)

    env_state = env.reset(k_env, params)
    keys = jax.random.split(k_env, n_samples)
    env_state, (S, A, R, SN) = jax.lax.scan(collect, env_state, keys)

    # keep the newest `capacity` samples (ring buffer semantics),
    # standardized over the offline distribution
    cap = state.replay.states.shape[0]
    take = min(n_samples, cap)
    r_mean = R.mean()
    r_std = jnp.maximum(R.std(), 1e-4)

    def fill(replay, xs):
        s, a, r, sn = xs
        return replay_add(replay, s, a,
                          jnp.clip((r - r_mean) / r_std, -10, 10), sn), None

    replay, _ = jax.lax.scan(
        fill, state.replay, (S[-take:], A[-take:], R[-take:], SN[-take:])
    )
    state = state._replace(replay=replay, r_mean=r_mean,
                           r_var=jnp.square(r_std),
                           r_count=jnp.asarray(n_samples, jnp.int32))

    def train(st, k):
        st, aux = update_step(k, st, cfg)
        return st, aux["critic_loss"]

    state, _ = jax.lax.scan(train, state, jax.random.split(k_upd, n_updates))
    return state
