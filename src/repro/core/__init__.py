# The paper's primary contribution: DRL-based model-free control for
# distributed stream data processing (and its TPU instantiation).
from repro.core.api import (Agent, agent_families, agent_names, make_agent,
                            make_epoch_step, register_agent)
from repro.core.ddpg import DDPGConfig, DDPGState, init_state as ddpg_init
from repro.core.dqn import DQNConfig, DQNState, init_state as dqn_init
from repro.core.stream_q import (StreamQConfig, StreamQState,
                                 init_state as stream_q_init)
from repro.core.stream_ac import (StreamACConfig, StreamACState,
                                  init_state as stream_ac_init)
from repro.core.graph_policy import (GraphPolicyConfig, GraphPolicyState,
                                     graph_param_specs,
                                     init_state as graph_policy_init)
from repro.core.agent import (History, reset_fleet_states, run_online_agent,
                              run_online_ddpg_python, run_online_dqn_python,
                              run_online_fleet)
from repro.core.knn_projection import (
    knn_actions_exact,
    knn_actions_jax,
    knn_assignments_exact,
    nearest_assignment,
)
from repro.core.control_policies import (AutoTuneConfig, RateControlConfig,
                                         auto_tune_agent, rate_control_agent)
from repro.core.model_based import ModelBasedScheduler
from repro.core.placement import (ExpertPlacementEnv, PlacementParams,
                                  jamba_placement_env)
from repro.core.round_robin import round_robin
from repro.core import spaces

__all__ = [
    "Agent", "agent_families", "agent_names", "make_agent",
    "make_epoch_step", "register_agent",
    "DDPGConfig", "DDPGState", "ddpg_init",
    "DQNConfig", "DQNState", "dqn_init",
    "StreamQConfig", "StreamQState", "stream_q_init",
    "StreamACConfig", "StreamACState", "stream_ac_init",
    "GraphPolicyConfig", "GraphPolicyState", "graph_policy_init",
    "graph_param_specs",
    "History", "reset_fleet_states", "run_online_agent", "run_online_fleet",
    "run_online_ddpg_python", "run_online_dqn_python",
    "knn_actions_exact", "knn_actions_jax", "knn_assignments_exact",
    "nearest_assignment", "ModelBasedScheduler",
    "AutoTuneConfig", "RateControlConfig",
    "auto_tune_agent", "rate_control_agent",
    "ExpertPlacementEnv", "PlacementParams", "jamba_placement_env",
    "round_robin", "spaces",
]
