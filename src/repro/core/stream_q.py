"""Stream Q(λ) — replay-free online control (arXiv 2410.14606).

Same restricted move space as the DQN baseline — action (i, j) re-assigns
executor i to machine j, |A| = N·M — but the per-lane carry holds NO
replay buffer, NO target network, and NO Adam state.  What rides the scan
instead:

  * eligibility traces ``z`` shaped like the Q-net (γλ-decayed, Watkins
    cut on non-greedy moves),
  * a Welford observation normalizer updated inside the epoch body,
  * one pending TD error ``delta`` between observe and update.

``observe`` folds the transition into the traces immediately; ``update``
applies the λ-return TD step with ObGD (overshoot-bounded stepsizes —
the streaming paper's replacement for target-network stabilization).
Sparse init (:func:`networks.sparse_init`) protects the single-sample
updates from early interference.  The carry is a plain pytree of arrays,
so the fleet stack — vmap/shard_map runners, heterogeneous EnvParams,
lifecycle compaction, FleetCheckpoint — applies unchanged."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core import networks as nets
from repro.core.dqn import apply_move
from repro.core.exploration import EpsilonSchedule, epsilon_greedy
from repro.core.streaming import (ObsNorm, norm_apply, norm_init,
                                  norm_update, obgd_step, reward_norm_update,
                                  trace_decay_add, trace_zeros_like)


@dataclasses.dataclass(frozen=True)
class StreamQConfig:
    n_executors: int
    n_machines: int
    state_dim: int
    gamma: float = 0.99
    lam: float = 0.9             # eligibility-trace decay λ
    lr: float = 1.0              # ObGD base stepsize α (self-throttling)
    kappa: float = 3.0           # ObGD overshoot margin
    # Much leaner than the replay agents' paper-faithful (64, 32) nets:
    # trace-based single-sample TD(λ) holds reward parity with DQN on the
    # paper workloads at (8, 8) (pinned in tests/test_streaming.py), and
    # the lean net IS the fleet-width story — the per-lane carry drops
    # ~66× vs the DQN lane (fleet_bench --streaming).  At fan-in 8 the
    # paper's 0.9 zero fraction leaves 1-2 live weights per unit, so the
    # sparse init backs off to 0.5.
    sparsity: float = 0.5        # sparse-init zero fraction
    hidden: tuple = (8, 8)
    reward_scale: float = 0.25   # same affine rescale as the replay agents
    # faster ε decay than the replay DQN (decay_epochs=800): traces give
    # TD(λ) multi-step credit from the first transition, so exploitation
    # can start earlier — validated by the pinned cq_small parity test
    eps: EpsilonSchedule = EpsilonSchedule(decay_epochs=300)

    @property
    def num_actions(self) -> int:
        return self.n_executors * self.n_machines


class StreamQState(NamedTuple):
    qnet: nets.MLPParams
    z: nets.MLPParams            # eligibility traces, same pytree as qnet
    norm: ObsNorm
    delta: jnp.ndarray           # pending TD error (consumed by update)
    epoch: jnp.ndarray
    r_mean: jnp.ndarray = jnp.zeros(())
    r_var: jnp.ndarray = jnp.ones(())
    r_count: jnp.ndarray = jnp.zeros((), jnp.int32)


def init_state(key: jax.Array, cfg: StreamQConfig) -> StreamQState:
    q = nets.sparse_init(key, (cfg.state_dim, *cfg.hidden, cfg.num_actions),
                         sparsity=cfg.sparsity)
    return StreamQState(
        qnet=q,
        z=trace_zeros_like(q),
        norm=norm_init(cfg.state_dim),
        delta=jnp.zeros(()),
        epoch=jnp.zeros((), jnp.int32),
    )


def select_move(key, state: StreamQState, cfg: StreamQConfig, s_vec,
                explore: bool = True):
    """ε-greedy move over normalized observations.

    Returns ``(move, greedy)`` — the greedy flag feeds the Watkins trace
    cut in :func:`observe`: an exploratory move that happens to coincide
    with argmax Q still counts as greedy."""
    x = norm_apply(state.norm, s_vec)
    q = nets.apply_qnet(state.qnet, x)
    eps = cfg.eps(state.epoch) if explore else jnp.zeros(())
    move = epsilon_greedy(key, q, eps)
    greedy = (move == jnp.argmax(q)).astype(jnp.float32)
    return move, greedy


def observe(cfg: StreamQConfig, state: StreamQState, s_vec, aux, reward,
            s_next) -> StreamQState:
    """Fold one transition into the traces; stash the TD error.

    Both endpoints are normalized under the statistics ``select`` saw, and
    only afterwards is ``s_vec`` folded into the Welford stats — one fold
    per observation over the lifetime (``s_next`` is next epoch's
    ``s_vec``)."""
    move, greedy = aux
    r_std, r_mean, r_var, r_count = reward_norm_update(
        reward, state.r_mean, state.r_var, state.r_count,
        scale=cfg.reward_scale)
    x = norm_apply(state.norm, s_vec)
    x_next = norm_apply(state.norm, s_next)
    q_next = nets.apply_qnet(state.qnet, x_next).max()
    q_sa, grad = jax.value_and_grad(
        lambda p: nets.apply_qnet(p, x)[move])(state.qnet)
    delta = r_std + cfg.gamma * q_next - q_sa
    # Watkins Q(λ): a non-greedy move cuts the trace before accumulation
    z = trace_decay_add(state.z, grad, cfg.gamma * cfg.lam * greedy)
    return state._replace(
        z=z, delta=delta, norm=norm_update(state.norm, s_vec),
        r_mean=r_mean, r_var=r_var, r_count=r_count)


def update(state: StreamQState, cfg: StreamQConfig) -> StreamQState:
    """Apply the pending ObGD TD step, then consume it — with δ = 0 the
    step is an exact no-op, so ``updates_per_epoch > 1`` in the fused
    epoch body applies each transition exactly once."""
    qnet = obgd_step(state.qnet, state.z, state.delta, cfg.lr, cfg.kappa)
    return state._replace(qnet=qnet, delta=jnp.zeros(()))


def tick(state: StreamQState) -> StreamQState:
    return state._replace(epoch=state.epoch + 1)


# --------------------------------------------------------------------------
# Agent-interface adapter — hooks for the generic api.make_epoch_step.
# --------------------------------------------------------------------------
def _agent_init(key, cfg: StreamQConfig, env_params=None):
    return init_state(key, cfg)


def _agent_select(key, cfg: StreamQConfig, state, s_vec, env_state,
                  env_params, explore):
    move, greedy = select_move(key, state, cfg, s_vec, explore=explore)
    return apply_move(env_state.X, move, cfg.n_machines), (move, greedy)


def _agent_observe(cfg: StreamQConfig, state, s_vec, aux, reward, s_next):
    return observe(cfg, state, s_vec, aux, reward, s_next)


def _agent_update(key, cfg: StreamQConfig, state):
    return update(state, cfg)


def _agent_tick(cfg: StreamQConfig, state):
    return tick(state)


def as_agent(cfg: StreamQConfig) -> api.Agent:
    """Stream Q(λ) as a pluggable Agent bundle."""
    return api.Agent(name="stream_q", cfg=cfg, init_fn=_agent_init,
                     select_fn=_agent_select, observe_fn=_agent_observe,
                     update_fn=_agent_update, tick_fn=_agent_tick)


def agent_factory(env, **overrides) -> api.Agent:
    """Registry hook: size a StreamQConfig for ``env`` (or pass ``cfg=``)."""
    cfg = overrides.pop("cfg", None)
    if cfg is None:
        cfg = StreamQConfig(n_executors=env.N, n_machines=env.M,
                            state_dim=env.state_dim, **overrides)
    return as_agent(cfg)


api.register_agent("stream_q", agent_factory)


def init_fleet(key: jax.Array, cfg: StreamQConfig, fleet: int) -> StreamQState:
    """Independently-initialized per-lane states stacked on [fleet]."""
    return jax.vmap(lambda k: init_state(k, cfg))(jax.random.split(key, fleet))
