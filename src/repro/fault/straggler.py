"""Straggler detection + DRL-driven mitigation.

Detection: per-worker step-time EWMA; a worker whose smoothed step time
exceeds ``threshold`` × the cluster median is flagged.

Mitigation: this is exactly the paper's control problem — re-assign work
away from the slow machine.  For MoE models the DRL placement agent
(core/placement.py) re-solves expert→device placement with the straggler's
speed factor in the environment; the same DDPG machinery the paper uses
for Storm executors re-schedules TPU experts (DESIGN.md §6)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerDetector:
    num_workers: int
    alpha: float = 0.2            # EWMA smoothing
    threshold: float = 1.5        # × median => straggler

    def __post_init__(self):
        self.ewma = np.zeros(self.num_workers)
        self.count = np.zeros(self.num_workers, np.int64)

    def observe(self, worker: int, step_time_s: float) -> None:
        if self.count[worker] == 0:
            self.ewma[worker] = step_time_s
        else:
            self.ewma[worker] = (self.alpha * step_time_s
                                 + (1 - self.alpha) * self.ewma[worker])
        self.count[worker] += 1

    def stragglers(self) -> list[int]:
        seen = self.count > 0
        if seen.sum() < max(3, self.num_workers // 2):
            return []
        med = float(np.median(self.ewma[seen]))
        return [w for w in range(self.num_workers)
                if seen[w] and self.ewma[w] > self.threshold * med]

    def speed_factors(self) -> np.ndarray:
        """Relative speed estimate per worker (1.0 = median) — feeds the
        DRL placement environment's ``speed`` vector."""
        seen = self.count > 0
        med = float(np.median(self.ewma[seen])) if seen.any() else 1.0
        f = np.ones(self.num_workers)
        f[seen] = med / np.maximum(self.ewma[seen], 1e-9)
        return f


def mitigate_with_drl(detector: StragglerDetector, placement_env,
                      agent_state, agent_cfg, key):
    """Re-run the trained DDPG placement agent against the environment with
    observed speed factors; returns the re-assignment (one-hot [E, D])."""
    import jax.numpy as jnp
    from repro.core import ddpg

    speeds = jnp.asarray(detector.speed_factors()[: placement_env.M])
    state = placement_env.reset(key)
    state = state._replace(speed=speeds)
    s_vec = placement_env.state_vector(state)
    return ddpg.select_action(key, agent_state, agent_cfg, s_vec,
                              explore=False, exact_host_knn=True)
