"""Elastic re-meshing: when workers die, pick the best surviving mesh and
resume from the latest checkpoint (restore is device-count-independent —
checkpoint/checkpointer.py stores full arrays and re-places them).

Policy: keep the model axis intact if possible (TP groups span a pod's
fast ICI; losing a chip inside a TP group forces the whole host group
out), shrink the data axis to the largest value that fits the survivors.
This mirrors how production jobs degrade: FSDP width shrinks, per-step
global batch shrinks with it, and training resumes."""
from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def device_count(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def plan_mesh(alive_devices: int, model_parallel: int = 16,
              multi_pod: bool = False) -> MeshPlan:
    """Largest (data, model) grid that fits the survivors."""
    if alive_devices < model_parallel:
        # degrade TP too (rare: an entire pod's worth of failures)
        mp = 1
        while mp * 2 <= alive_devices:
            mp *= 2
        model_parallel = mp
    data = alive_devices // model_parallel
    if multi_pod and data % 2 == 0 and data >= 2:
        return MeshPlan((2, data // 2, model_parallel),
                        ("pod", "data", "model"))
    return MeshPlan((data, model_parallel), ("data", "model"))


def make_mesh(plan: MeshPlan):
    return jax.make_mesh(plan.shape, plan.axes)


def resume_after_failure(checkpointer, abstract_state, policy_cls, cfg,
                         alive_devices: int, model_parallel: int = 16):
    """Full elastic-restart path: plan mesh -> build shardings -> restore."""
    plan = plan_mesh(alive_devices, model_parallel)
    mesh = make_mesh(plan)
    policy = policy_cls(mesh, cfg)
    shardings = policy.params_sharding(abstract_state)
    state = checkpointer.restore(abstract_state, shardings=shardings)
    return mesh, state, plan
