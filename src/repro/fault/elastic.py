"""Elastic re-meshing: when workers die, pick the best surviving mesh and
resume from the latest checkpoint (restore is device-count-independent —
checkpoint/checkpointer.py stores full arrays and re-places them).

Two restore families share the mesh-planning policy here:

* **LM training** (``train/trainer.py``): keep the model axis intact if
  possible (TP groups span a pod's fast ICI; losing a chip inside a TP
  group forces the whole host group out), shrink the data axis to the
  largest value that fits the survivors — FSDP width shrinks, per-step
  global batch shrinks with it, and training resumes.  Plan with
  :func:`plan_mesh` and restore through the trainer's sharding policy.

* **Fleet control runs** (``core/agent.run_online_fleet``):
  :func:`resume_after_failure` plans a data-only mesh over the survivors
  and restores the fleet carries — agent states built by
  ``make_agent(...).init_fleet``, env states, and evolved PRNG keys —
  through :meth:`repro.checkpoint.fleet.FleetCheckpoint.restore`, which
  re-places every lane against the NEW mesh (replication fallback when
  the fleet no longer divides the device count).  Elastic-lifecycle runs
  (repro/fleet/lifecycle.py) checkpoint a COMPACTED fleet with a lane
  map; pass ``with_lane_map=True`` to recover which original lanes the
  surviving rows are.  The walkthrough lives in docs/elastic_fleets.md.
"""
from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def device_count(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def plan_mesh(alive_devices: int, model_parallel: int = 16,
              multi_pod: bool = False) -> MeshPlan:
    """Largest (data, model) grid that fits the survivors.

    ``model_parallel=1`` plans the data-only ``(n, 1)`` grid fleet
    control runs use (``launch.mesh.make_fleet_mesh``); the multi-host
    driver (``repro.launch.multihost``) calls it that way to size the
    reduced mesh after a worker process dies."""
    alive_devices = int(alive_devices)
    if alive_devices < 1:
        raise ValueError(
            f"cannot plan a mesh over {alive_devices} alive device(s)")
    if alive_devices < model_parallel:
        # degrade TP too (rare: an entire pod's worth of failures)
        mp = 1
        while mp * 2 <= alive_devices:
            mp *= 2
        model_parallel = mp
    data = alive_devices // model_parallel
    if multi_pod and data % 2 == 0 and data >= 2:
        return MeshPlan((2, data // 2, model_parallel),
                        ("pod", "data", "model"))
    return MeshPlan((data, model_parallel), ("data", "model"))


def make_mesh(plan: MeshPlan):
    return jax.make_mesh(plan.shape, plan.axes)


def resume_after_failure(checkpoint, env, agent, keys, states,
                         env_states=None, env_params=None,
                         alive_devices: int | None = None,
                         with_lane_map: bool = False):
    """Full elastic-restart path for a fleet control run: plan a data-only
    mesh over the survivors, restore the fleet carries re-placed against
    it, and hand back everything ``run_online_fleet`` needs to continue.

    ``checkpoint`` — a :class:`repro.checkpoint.fleet.FleetCheckpoint`
    over the dead run's directory; ``agent`` — the same
    ``make_agent(...)`` bundle the run trained (its ``init_fleet`` builds
    the agent-state structure template via ``states``); ``keys`` /
    ``states`` / ``env_states`` — structure templates for the carries
    (freshly-initialized values; shapes/dtypes/structure are what
    matters, see ``reset_fleet_states``); ``env_params`` — the run's
    scenario fleet, needed to rebuild the env-state template when
    ``env_states`` is None; ``alive_devices`` — surviving device count
    (default: every device jax still sees).  ``with_lane_map=True`` reads
    an elastic-lifecycle snapshot and appends the original-lane index
    array to the return.

    Returns ``(mesh, epoch, states, env_states, keys[, lane_map])`` —
    feed them to ``run_online_fleet(..., mesh=mesh, start_epoch=epoch,
    T=remaining)`` (the launcher's ``--resume`` flag is this function as
    a CLI)."""
    from repro.core.agent import reset_fleet_states
    from repro.core.api import Agent
    from repro.launch.mesh import make_fleet_mesh
    if not isinstance(agent, Agent):
        raise TypeError(
            f"expected an api.Agent (make_agent(...)), got "
            f"{type(agent).__name__} — the pre-v1 policy_cls/cfg call "
            f"style was removed with the PR-2 deprecation window")
    n = len(jax.devices()) if alive_devices is None else int(alive_devices)
    mesh = make_fleet_mesh(n)
    if env_states is None:
        env_states = reset_fleet_states(keys, env, env_params)
    out = checkpoint.restore(states, env_states, keys, mesh=mesh,
                             with_lane_map=with_lane_map)
    return (mesh, *out)
