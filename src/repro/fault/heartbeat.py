"""Heartbeat-based failure detection (master-side view, as in Storm §2.1:
"the master monitors heartbeat signals from all worker processes
periodically; it re-schedules them when it discovers a failure").

Works on an injected clock so tests are deterministic; in production the
clock is time.monotonic and beats arrive from worker RPCs."""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass
class HeartbeatMonitor:
    num_workers: int
    timeout_s: float = 10.0
    clock: Callable[[], float] = None  # type: ignore

    def __post_init__(self):
        if self.clock is None:
            import time
            self.clock = time.monotonic
        now = self.clock()
        self.last_beat = {w: now for w in range(self.num_workers)}
        self._known_dead: set[int] = set()

    def beat(self, worker: int) -> None:
        self.last_beat[worker] = self.clock()
        self._known_dead.discard(worker)

    def dead_workers(self) -> set[int]:
        now = self.clock()
        dead = {w for w, t in self.last_beat.items()
                if now - t > self.timeout_s}
        return dead

    def newly_dead(self) -> set[int]:
        dead = self.dead_workers()
        new = dead - self._known_dead
        self._known_dead |= new
        return new

    @property
    def alive(self) -> list[int]:
        dead = self.dead_workers()
        return [w for w in range(self.num_workers) if w not in dead]
