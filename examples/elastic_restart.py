"""Fault tolerance end-to-end: train, checkpoint asynchronously, lose
workers (heartbeat detection), re-plan the mesh, resume from the latest
checkpoint — the 1000-node degradation path at demo scale.

  PYTHONPATH=src python examples/elastic_restart.py
"""
import tempfile

import jax

from repro.checkpoint.checkpointer import AsyncCheckpointer
from repro.configs import get_config
from repro.fault.elastic import plan_mesh
from repro.fault.heartbeat import HeartbeatMonitor
from repro.train.trainer import TrainSetup, init_train_state, make_train_step
from repro.data.pipeline import DataConfig, batch_at


def main() -> None:
    cfg = get_config("llama3-8b", smoke=True)
    setup = TrainSetup(micro_batches=2, learning_rate=1e-3, warmup_steps=5,
                       total_steps=100)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)

    with tempfile.TemporaryDirectory() as d:
        ckpt = AsyncCheckpointer(d, keep=2)
        state = init_train_state(cfg, setup, jax.random.PRNGKey(0))
        step_fn = jax.jit(make_train_step(cfg, setup))

        print("training 10 steps with async checkpoints every 5 ...")
        for step in range(10):
            state, m = step_fn(state, batch_at(data, step))
            if (step + 1) % 5 == 0:
                ckpt.save_async(step + 1, state)
        ckpt.wait()
        print(f"checkpoints on disk: {ckpt.all_steps()}, "
              f"loss {float(m['loss']):.3f}")

        # --- failure: 16 of 512 workers stop heartbeating -----------------
        t = [0.0]
        mon = HeartbeatMonitor(512, timeout_s=10.0, clock=lambda: t[0])
        t[0] = 5.0
        for w in range(512):
            if w % 32 != 7:                      # host 7 of each pod row dies
                mon.beat(w)
        t[0] = 20.0
        dead = mon.dead_workers()
        print(f"\nheartbeat monitor: {len(dead)} dead workers detected")

        plan = plan_mesh(512 - len(dead), model_parallel=16, multi_pod=True)
        print(f"elastic re-plan: {plan.shape} over {plan.axes} "
              f"({plan.device_count} devices)")

        # --- resume from latest checkpoint ---------------------------------
        state2 = ckpt.restore(state)
        resumed = int(state2.step)
        print(f"restored step {resumed}; continuing training ...")
        for step in range(resumed, resumed + 5):
            state2, m = step_fn(state2, batch_at(data, step))
        print(f"resumed cleanly; loss {float(m['loss']):.3f}")
        ckpt.close()


if __name__ == "__main__":
    main()
