"""Scenario fleets: heterogeneous operating regimes in ONE XLA program.

The functional core API makes the environment's numeric parameters a
vmappable EnvParams pytree, so a fleet of online-learning runs can differ
not just by seed but by SCENARIO — per-lane workload rates, service-time
jitter, telemetry noise, and straggler machines — while still executing as
a single jitted, vmapped scan.  This script trains an actor-critic fleet
over the "mixed" scenario distribution and reports per-lane results, then
re-runs the same compiled program under a +50% global rate shift (a traced
parameter change: zero recompilation).

  PYTHONPATH=src python examples/scenario_fleet.py [--fleet 8] [--epochs 150]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import make_agent, run_online_fleet
from repro.dsdps import (SchedulingEnv, apps, lane_params, scale_rates,
                         scenarios)
from repro.dsdps.apps import default_workload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=150)
    ap.add_argument("--scenario", default="mixed",
                    choices=list(scenarios.SCENARIOS))
    ap.add_argument("--broadcast-invariant", action="store_true",
                    help="share scenario-invariant params leaves across "
                         "lanes (per-leaf in_axes=None broadcast)")
    args = ap.parse_args()

    topo = apps.continuous_queries("small")
    env = SchedulingEnv(topo, default_workload(topo))
    agent = make_agent("ddpg", env, k_nn=8)

    params = scenarios.build(args.scenario, env, args.fleet,
                             broadcast_invariant=args.broadcast_invariant)
    states = agent.init_fleet(jax.random.PRNGKey(0), args.fleet,
                              env_params=params, env=env)
    keys = jax.random.split(jax.random.PRNGKey(1), args.fleet)

    print(f"training {args.fleet} heterogeneous '{args.scenario}' lanes x "
          f"{args.epochs} epochs as one program ...")
    t0 = time.perf_counter()
    states, hist = run_online_fleet(keys, env, agent, states, T=args.epochs,
                                    env_params=params)
    dt = time.perf_counter() - t0
    print(f"  {args.fleet * args.epochs} lane-epochs in {dt:.1f}s "
          f"(incl. compile)\n")
    print("lane  mean-latency(ms)  final-latency(ms)")
    for f in range(args.fleet):
        lane_p = lane_params(params, env.default_params(), f)
        final = float(env.evaluate(jnp.asarray(hist.final_assignment[f]),
                                   lane_p.base_rates, params=lane_p))
        print(f"  {f:2d}  {hist.latencies[f].mean():16.3f}  {final:17.3f}")

    # a workload shift is just a parameter edit — same executable, no
    # recompile: the warm re-run timing shows it
    shifted = scale_rates(params, 1.5)
    t0 = time.perf_counter()
    _, hist2 = run_online_fleet(keys, env, agent, states, T=args.epochs,
                                env_params=shifted)
    dt2 = time.perf_counter() - t0
    print(f"\n+50% rate shift re-run: {dt2:.1f}s (no recompilation) — "
          f"mean latency {hist.latencies.mean():.2f} -> "
          f"{hist2.latencies.mean():.2f} ms")


if __name__ == "__main__":
    main()
