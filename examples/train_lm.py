"""Train a ~100M-parameter llama-family model for a few hundred steps on
the deterministic synthetic pipeline, with async checkpointing and a
mid-run restart to prove exact resume.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]
"""
import argparse
import dataclasses
import shutil
import tempfile

from repro.models.config import ModelConfig
from repro.launch.train import run_training
from repro.train.trainer import TrainSetup


def hundred_m_config(tiny: bool) -> ModelConfig:
    if tiny:    # CI-scale variant (~2M params)
        return ModelConfig(name="demo-2m", family="dense", num_layers=2,
                           d_model=128, num_heads=4, num_kv_heads=2,
                           d_ff=256, vocab_size=2048)
    return ModelConfig(                 # ~100M params
        name="demo-100m", family="dense",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
        d_ff=2048, vocab_size=32768,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    cfg = hundred_m_config(args.tiny)
    setup = TrainSetup(micro_batches=2, learning_rate=3e-4,
                       warmup_steps=20, total_steps=args.steps)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        half = args.steps // 2
        print(f"training {cfg.name} ({cfg.param_count() / 1e6:.0f}M params) "
              f"for {half} steps, then restarting from checkpoint ...")
        out1 = run_training(cfg, setup, half, args.batch, args.seq,
                            ckpt_dir=ckpt_dir, ckpt_every=max(half // 2, 1),
                            log_every=10)
        print("\n-- simulated preemption: restarting from checkpoint --\n")
        out2 = run_training(cfg, setup, args.steps, args.batch, args.seq,
                            ckpt_dir=ckpt_dir, ckpt_every=50, resume=True,
                            log_every=10)
        print(f"\nloss {out1['losses'][0]:.3f} -> {out2['losses'][-1]:.3f} "
              f"over {args.steps} steps (resumed mid-run)")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
