"""End-to-end driver — the paper's full control loop (its "kind" is
cluster control, so this is the e2e example): offline training on 10k-
scale random transitions, online learning on the large-scale topology,
comparison against default / model-based / DQN, and a +50% workload-shift
stress (Fig 12).

  PYTHONPATH=src python examples/drl_storm_control.py [--app cq_large]
                 [--quick]
"""
import argparse

from benchmarks.paper_common import Budget, compare_all
from benchmarks.paper_fig12 import run as run_shift


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="cq_large")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    budget = Budget.quick() if args.quick else Budget.paper()
    print(f"== scheduler comparison on {args.app} ==")
    out = compare_all(args.app, budget)
    print(f"\n== +50% workload shift (Fig 12) ==")
    shift = run_shift(args.app, Budget.quick() if args.quick else budget)
    print(f"actor-critic after shift : {shift['ac_after_shift']:.2f} ms")
    print(f"model-based after shift  : {shift['mb_after_shift']:.2f} ms")


if __name__ == "__main__":
    main()
