"""Serve a small model with batched requests: static-batch generation plus
the continuous-batching scheduler (slots recycle as requests finish).

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.serve.continuous import ContinuousBatcher, Request
from repro.serve.engine import Engine, SamplingParams


def main() -> None:
    cfg = get_config("llama3-8b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    print("== static batched generation ==")
    eng = Engine(cfg, params, max_seq=96, batch_size=4)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 1,
                                 cfg.vocab_size)
    t0 = time.time()
    out = eng.generate(jax.random.PRNGKey(2), prompts, max_new_tokens=16,
                       sp=SamplingParams(temperature=0.8, top_k=40))
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.1f}s "
          f"({out.size / dt:.1f} tok/s incl. compile)")
    print(out[:, :8].tolist())

    print("\n== continuous batching: 8 requests through 3 slots ==")
    cb = ContinuousBatcher(cfg, params, max_seq=96, n_slots=3, eos_id=-1,
                           sp=SamplingParams(temperature=0.7, top_k=20))
    for rid in range(8):
        cb.submit(Request(rid=rid, prompt=[1 + rid, 5, 9],
                          max_new_tokens=4 + rid % 3))
    done = cb.run(jax.random.PRNGKey(3), max_steps=200)
    for r in done:
        print(f"  request {r.rid}: {len(r.out)} tokens -> {r.out}")
    print(f"served {len(done)} requests with 3 slots")


if __name__ == "__main__":
    main()
