"""The paper's technique on TPU: DRL expert->device placement for the
Jamba MoE under skewed routing, plus straggler mitigation (DESIGN.md §6).

  PYTHONPATH=src python examples/expert_placement.py
"""
import jax
import jax.numpy as jnp

from repro.core import DDPGConfig, ddpg_init, jamba_placement_env, \
    make_agent, run_online_agent
from repro.core.ddpg import offline_pretrain
from repro.core.exploration import EpsilonSchedule
from repro.fault.straggler import StragglerDetector, mitigate_with_drl


def main() -> None:
    env = jamba_placement_env()
    print(f"placing {env.N} Jamba experts on {env.M} devices "
          f"(skewed token routing, zipf {env.skew})")

    cfg = DDPGConfig(n_executors=env.N, n_machines=env.M,
                     state_dim=env.state_dim, k_nn=8, reward_scale=1.0,
                     eps=EpsilonSchedule(decay_epochs=150))
    key = jax.random.PRNGKey(0)
    agent = ddpg_init(key, cfg)
    agent = offline_pretrain(jax.random.fold_in(key, 1), agent, cfg, env,
                             n_samples=800, n_updates=300)
    agent, hist = run_online_agent(jax.random.fold_in(key, 2), env,
                                   make_agent("ddpg", env, cfg=cfg),
                                   agent, T=200, updates_per_epoch=2)

    s = env.reset(key)
    rr = float(env.step_time_ms(env.round_robin_assignment(), s.w))
    learned = float(env.step_time_ms(jnp.asarray(hist.final_assignment), s.w))
    print(f"\nround-robin placement : {rr:.3f} ms/step (MoE layer)")
    print(f"DRL placement         : {learned:.3f} ms/step "
          f"({1 - learned / rr:+.1%})")

    print("\n== straggler mitigation ==")
    det = StragglerDetector(env.M)
    for step in range(8):
        for w in range(env.M):
            det.observe(w, 1.0 if w != 5 else 2.2)   # device 5 runs slow
    print("detected stragglers:", det.stragglers())
    X = mitigate_with_drl(det, env, agent, cfg, jax.random.PRNGKey(9))
    moved = int((X.argmax(-1) != hist.final_assignment.argmax(-1)).sum())
    slow = jnp.asarray(det.speed_factors()[: env.M])
    before = float(env.step_time_ms(jnp.asarray(hist.final_assignment),
                                    s.w, slow))
    after = float(env.step_time_ms(X, s.w, slow))
    print(f"re-assigned {moved} experts; step time with straggler: "
          f"{before:.3f} -> {after:.3f} ms")


if __name__ == "__main__":
    main()
