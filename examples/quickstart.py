"""Quickstart: model-free DRL scheduling of a Storm topology in ~2 minutes.

Trains the paper's actor-critic agent (Algorithm 1) on the small
continuous-queries topology and compares the learned schedule against
Storm's default round-robin scheduler.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import make_agent, run_online_agent
from repro.core.ddpg import offline_pretrain
from repro.core.exploration import EpsilonSchedule
from repro.dsdps import SchedulingEnv, apps
from repro.dsdps.apps import default_workload


def main() -> None:
    topo = apps.continuous_queries("small")
    print(topo.describe(), "\n")
    env = SchedulingEnv(topo, default_workload(topo))

    # any registered policy plugs into the same control loop:
    # "ddpg" (Algorithm 1), "dqn", "round_robin", "model_based"
    agent = make_agent("ddpg", env, k_nn=8,
                       eps=EpsilonSchedule(decay_epochs=120))
    key = jax.random.PRNGKey(0)
    state = agent.init(key)

    print("offline pretraining on random-action transitions ...")
    state = offline_pretrain(jax.random.fold_in(key, 1), state, agent.cfg,
                             env, n_samples=800, n_updates=300)

    print("online learning (180 decision epochs) ...")
    state, hist = run_online_agent(jax.random.fold_in(key, 2), env, agent,
                                   state, T=180, updates_per_epoch=2)

    w = env.workload.init()
    Xd, mask, nproc = env.storm_default_assignment()
    default = float(env.evaluate(Xd, w, same_proc=mask, n_procs=nproc))
    learned = float(env.evaluate(jnp.asarray(hist.final_assignment), w))
    print(f"\nStorm default scheduler : {default:.2f} ms avg tuple time")
    print(f"DRL-learned schedule    : {learned:.2f} ms avg tuple time")
    print(f"improvement             : {1 - learned / default:.1%}")
    print("\nexecutor -> machine:",
          hist.final_assignment.argmax(-1).tolist())


if __name__ == "__main__":
    main()
